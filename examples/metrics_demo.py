"""Service observability demo: /metrics scrape + /trace export.

Starts ``repro.service`` on a port-0 HTTP server, drives one seeded
chaos load run through ``/run``, then exercises the two observability
surfaces end to end:

* ``/trace?id=N`` — the run's merged flight-recorder timeline as
  Chrome trace-event JSON, checked against the Perfetto schema;
* ``/metrics`` — Prometheus text exposition format 0.0.4, re-read with
  the strict parser (cumulative buckets, ``+Inf``/``_count`` match).

    PYTHONPATH=src python examples/metrics_demo.py

This is also what CI's ``obs-smoke`` job runs: every assert here is a
contract, not an illustration.
"""
import json
import os
import sys
import threading
import time
import urllib.request

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path[:0] = [os.path.join(_ROOT, 'src'), _ROOT]

from repro.obs.metrics import parse_promtext
from repro.obs.trace import validate_trace
from repro.service.http import make_server


def _get(base, path):
    with urllib.request.urlopen(base + path, timeout=30) as r:
        body = r.read()
        ctype = r.headers.get("Content-Type", "")
    return body, ctype


def _get_json(base, path):
    body, _ = _get(base, path)
    return json.loads(body)


def main():
    server = make_server(port=0)          # port 0: pick a free one
    host, port = server.server_address[:2]
    base = f"http://{host}:{port}"
    t = threading.Thread(target=server.serve_forever, daemon=True)
    t.start()
    print(f"serving on {base}")

    try:
        run = _get_json(base, "/run?scenario=serving_traffic&p_n_requests=3"
                        "&process=poisson&rate_hz=20&n=12&seed=11"
                        "&workers=2&kill_every=5&max_faults=1&chaos_seed=3"
                        "&slo_ms=100&window_s=0.5")
        rid = run["id"]
        print(f"started run {rid}")
        deadline = time.monotonic() + 180
        while True:
            st = _get_json(base, f"/status?id={rid}")
            if st["state"] in ("done", "failed"):
                break
            assert time.monotonic() < deadline, "run did not finish"
            time.sleep(0.5)
        assert st["state"] == "done", st.get("error")
        report = st["report"]
        assert report["schema"] == 1
        assert report["fleet"]["schema"] == 1
        assert report["n_ok"] >= 1
        assert st["trace"] == f"/trace?id={rid}"
        print(f"run done: {report['n_ok']} ok, "
              f"{report['fleet']['recovery'].get('worker_deaths', 0)} "
              "worker death(s)")

        trace = _get_json(base, st["trace"])
        validate_trace(trace)
        n_spans = sum(1 for e in trace["traceEvents"] if e.get("ph") == "X")
        assert n_spans > 0, "trace must carry bundle spans"
        print(f"trace: {len(trace['traceEvents'])} events "
              f"({n_spans} spans) — Perfetto-schema valid")

        body, ctype = _get(base, "/metrics")
        assert ctype.startswith("text/plain"), ctype
        fams = parse_promtext(body.decode())     # strict: raises on any
        samples = fams["repro_service_runs_total"]["samples"]
        assert samples[("repro_service_runs_total",
                        '{state="done"}')] == 1.0
        req = fams["repro_service_requests_total"]["samples"]
        assert req[("repro_service_requests_total",
                    '{outcome="ok"}')] >= 1.0
        lat = fams["repro_service_request_latency_seconds"]["samples"]
        assert lat[("repro_service_request_latency_seconds_count",
                    "")] >= 1.0
        assert fams["repro_service_runs_active"]["samples"][
            ("repro_service_runs_active", "")] == 0.0
        print(f"metrics: {len(fams)} families, strict parse ok")
    finally:
        server.shutdown()
    print("OK")


if __name__ == "__main__":
    main()
