"""End-to-end driver (deliverable b): train an LM for a few hundred steps
under full fault-tolerance (checkpoints, injected failure + restart,
straggler watch), with Synapse profiling the steady state and validating
its TTC prediction against reality — the paper's Exp 3 on a live train job.

PYTHONPATH=src python examples/train_with_synapse.py [--steps 200] [--big]
"""
import os, sys
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path[:0] = [os.path.join(_ROOT, 'src'), _ROOT]

import argparse
import tempfile
import time

import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.run import RunConfig
from repro.data.pipeline import DataConfig
from repro.optim.adamw import OptConfig
from repro.runtime.supervisor import FailurePlan, SupervisorConfig
from repro.train.loop import make_job, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--big", action="store_true",
                    help="~100M params (slow on 1 CPU core)")
    args = ap.parse_args()

    if args.big:  # ~100M param configuration
        cfg = ModelConfig(name="lm-100m", family="dense", num_layers=8,
                          d_model=768, num_heads=12, num_kv_heads=4,
                          head_dim=64, d_ff=2048, vocab_size=32768,
                          tie_embeddings=True)
        data = DataConfig(vocab_size=32768, seq_len=256, global_batch=8)
    else:
        cfg = ModelConfig(name="lm-3m", family="dense", num_layers=4,
                          d_model=128, num_heads=4, num_kv_heads=2,
                          head_dim=32, d_ff=512, vocab_size=4096,
                          tie_embeddings=True)
        data = DataConfig(vocab_size=4096, seq_len=128, global_batch=8)

    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    remat="none", loss_chunk=0)
    job = make_job(cfg, run, opt=OptConfig(lr=1e-2, warmup_steps=20,
                                           decay_steps=args.steps * 2,
                                           weight_decay=0.0),
                   data_cfg=data, ckpt_dir=tempfile.mkdtemp(),
                   sup_cfg=SupervisorConfig(ckpt_every=50,
                                            straggler_tolerance=4.0))
    plan = FailurePlan(fail_at_steps={args.steps // 2: "injected_node_loss"})
    t0 = time.time()
    out = train(job, args.steps, resume=False, failure_plan=plan)
    wall = time.time() - t0
    rep = out["report"]
    print(f"\nmodel={cfg.name} params={job.model.num_params()/1e6:.1f}M")
    print(f"loss: {np.mean(out['losses'][:5]):.3f} -> "
          f"{np.mean(out['losses'][-5:]):.3f} over {len(out['losses'])} steps")
    print(f"wall={wall:.1f}s restarts={rep.restarts} "
          f"restored_from={rep.restored_from} "
          f"stragglers={len(rep.straggler_events)}")
    assert rep.restarts == 1 and np.mean(out["losses"][-5:]) < \
        np.mean(out["losses"][:5])
    print("OK: survived failure, resumed from checkpoint, converged.")


if __name__ == "__main__":
    main()
