"""Flight-recorder demo: a chaos storm, exported as a Perfetto trace.

Replays a small scenario batch on a 2-worker process fleet with a
seeded ``ChaosPolicy`` killing a worker every 5th dispatch, then writes
the merged flight-recorder timeline as Chrome trace-event JSON and
re-runs the same seed to show the event sequence is deterministic.

    PYTHONPATH=src python examples/trace_demo.py [out.json]

Open the written file at https://ui.perfetto.dev (or chrome://tracing):

* the ``coordinator`` track shows one ``queue b<idx>`` span per bundle
  (enqueue -> dispatch wait);
* each ``worker:N`` track shows ``replay b<idx>`` spans — the bundle the
  kill interrupted appears TWICE, its second span on the rescue worker;
* ``fault_opened`` / ``fault_repaired`` instants bracket the respawn
  (their gap is the MTTR the SLO layer charges);
* ``segments b<idx>`` spans are worker-side, shipped home piggybacked
  on results and rebased through per-peer clock-offset estimation.
"""
import os, sys
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path[:0] = [os.path.join(_ROOT, 'src'), _ROOT]

from repro.fleet import FleetConfig
from repro.fleet.chaos import ChaosPolicy
from repro.obs.recorder import Event, event_sequence
from repro.obs.trace import to_chrome_trace, write_trace
from repro.scenarios import run_fleet

JOBS = [("serving_traffic", {"n_requests": 3})] * 8


def storm():
    config = FleetConfig.process(
        max_workers=2, window=1,     # window=1: deterministic dispatch
        chaos=ChaosPolicy(seed=3, kill_every=5, max_faults=1),
        liveness_timeout=5.0, on_failure="skip", max_respawns=8,
        timeout=600.0)
    out = run_fleet(JOBS, config=config, collect="totals")
    return out.fleet


def main():
    out_path = sys.argv[1] if len(sys.argv) > 1 else "fleet_trace.json"
    fleet = storm()
    events = [Event.from_dict(d)
              for d in fleet.obs.get("events", ())]
    kinds = sorted({e.kind for e in events})
    print(f"storm: {len(events)} events, kinds: {', '.join(kinds)}")
    rec = fleet.recovery
    print(f"chaos: {rec['worker_deaths']} worker death(s), "
          f"{rec['requeued']} requeue(s)")
    assert rec["worker_deaths"] >= 1, "the seeded kill must fire"
    assert any(e.kind == "fault_opened" for e in events)

    trace = to_chrome_trace(events, meta={"demo": "chaos storm"})
    replay_spans = [t for t in trace["traceEvents"]
                    if t.get("cat") == "replay"]
    per_idx = {}
    for t in replay_spans:
        per_idx.setdefault(t["args"]["idx"], []).append(t)
    rescued = {i: s for i, s in per_idx.items() if len(s) > 1}
    print(f"trace: {len(replay_spans)} replay spans; bundle(s) "
          f"{sorted(rescued)} dispatched twice (killed, then rescued)")
    assert rescued, "the killed bundle must show a second dispatch span"
    write_trace(out_path, trace)
    print(f"wrote {out_path} — load it at https://ui.perfetto.dev")

    # same seed, same fleet shape => same event sequence (identity only;
    # every timestamp differs run to run)
    fleet2 = storm()
    events2 = [Event.from_dict(d)
               for d in fleet2.obs.get("events", ())]
    assert event_sequence(events) == event_sequence(events2)
    print("re-ran the storm: event sequence identical (deterministic)")


if __name__ == "__main__":
    main()
