"""Quickstart: profile once, emulate anywhere — in 40 lines.

Profiles a real (tiny) LM training step on this host, stores the profile,
replays it through the emulation atoms, and predicts its TTC on a TPU v5e
chip we don't have.  PYTHONPATH=src python examples/quickstart.py
"""
import os, sys
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path[:0] = [os.path.join(_ROOT, 'src'), _ROOT]

import tempfile
import time

from benchmarks.common import tiny_train_workload
from benchmarks.bench_profiling_consistency import (_abstract_batch,
                                                    _abstract_state)
from repro.core import (Emulator, ProfileStore, RuntimeProfiler, TPU_V5E,
                        calibrate, predict, profile_compiled)


def main():
    run_fn, meta = tiny_train_workload(steps=4)

    # 1. profile (runtime watchers observe the black-box run)
    prof = RuntimeProfiler(sample_rate=20).profile_callable(
        run_fn, command="quickstart-lm", tags={"steps": "4"},
        flops_per_cpu_s=calibrate().flops_per_s)
    print(f"profiled: wall={prof.meta['wall_s']:.3f}s "
          f"samples={len(prof.samples)} peak_mem="
          f"{prof.totals.peak_mem_bytes/1e6:.0f}MB")

    # ... and statically from the compiled step (exact resource counts)
    compiled = meta["step"].lower(_abstract_state(meta["model"]),
                                  _abstract_batch(meta)).compile()
    sprof = profile_compiled(compiled, command="quickstart-lm-static")
    print(f"static:   flops/step={sprof.totals.flops:.3e} "
          f"ici={sprof.totals.ici_total:.3e}B samples={len(sprof.samples)}")

    # 2. store (tagged, statistical over repeats)
    store = ProfileStore(tempfile.mkdtemp())
    store.add(prof)
    print(f"stored:   {store.keys()}")

    # 3. emulate anywhere (same host here)
    rep = Emulator().emulate(sprof)
    print(f"emulated: ttc={rep.ttc_s:.3f}s flops={rep.consumed.flops:.3e}")

    # 4. predict TTC on hardware we don't have
    pred = predict(sprof, TPU_V5E)
    print(f"tpu v5e:  step={pred.ttc_max*1e6:.1f}us "
          f"dominant={pred.terms.dominant}")


if __name__ == "__main__":
    main()
