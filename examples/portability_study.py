"""Paper Fig. 3 live: one profile, three machines, dominant resource flips.

PYTHONPATH=src python examples/portability_study.py
"""
import os, sys
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path[:0] = [os.path.join(_ROOT, 'src'), _ROOT]

from benchmarks.bench_emulation_portability import _mixed_profile
from repro.core import (HOST_ARCHER_NODE, HOST_I7_M620, HOST_STAMPEDE_NODE,
                        TPU_V5E, calibrate, compare, predict)


def main():
    prof = _mixed_profile(calibrate(), steps=2)
    print(f"profile: {len(prof.samples)} samples, "
          f"flops={prof.totals.flops:.2e}, "
          f"write={prof.totals.storage_write_bytes/1e6:.0f}MB")
    out = compare(prof, [HOST_I7_M620, HOST_STAMPEDE_NODE, HOST_ARCHER_NODE,
                         TPU_V5E])
    print(f"{'machine':20s} {'ttc_max':>10s} {'ttc_sum':>10s} "
          f"{'dominant':>10s}  per-sample dominance")
    for hw, v in out.items():
        print(f"{hw:20s} {v['ttc_max']:10.4f} {v['ttc_sum']:10.4f} "
              f"{v['dominant_total']:>10s}  {v['dominant_histogram']}")
    doms = {v["dominant_total"] for v in out.values()}
    assert len(doms) > 1, "expected the dominant resource to flip"
    print("\nOK: dominant resource flips across machines (paper Fig. 3).")


if __name__ == "__main__":
    main()
