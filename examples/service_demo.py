"""Live traffic emulation demo: a Poisson storm with a kill mid-run.

Open-loop load (seeded Poisson arrivals) is driven against a standing
1-worker process fleet while a seeded ``ChaosPolicy`` kills the worker
partway through the storm.  Requests keep *arriving* during the outage —
that's the open-loop point — so by the time the respawned worker is
warm, the queue has a backlog whose wait-time is the fault's MTTR.  The
SLO report makes that visible: the windows the fault overlaps carry a
p999 on the order of the MTTR, while clean windows sit at millisecond
replay latency.

    PYTHONPATH=src python examples/service_demo.py
"""
import os, sys
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path[:0] = [os.path.join(_ROOT, 'src'), _ROOT]

from repro.core import Emulator
from repro.fleet import ChaosPolicy, FleetConfig
from repro.service import PoissonArrivals, SLO, run_load


def main():
    em = Emulator()
    # ~25 req/s for 40 requests; each worker dies on its 15th dispatch
    arrivals = PoissonArrivals(rate_hz=25.0, n_requests=40,
                               scenario="serving_traffic",
                               params={"n_requests": 2, "n_params": 2e6,
                                       "prefill_tokens": 64,
                                       "decode_tokens": 8},
                               seed=11)
    config = FleetConfig.process(
        max_workers=1,
        chaos=ChaosPolicy(seed=3, kill_every=15, max_faults=1),
        liveness_timeout=5.0, max_respawns=8, timeout=600.0)
    print("driving a Poisson storm (seed 11) against a 1-worker standing "
          "fleet;\nchaos kills the worker on its 15th dispatch (seed 3) ...")
    report = run_load(em, arrivals, config=config,
                      slo=SLO(target_ms=250.0, percentile=0.99),
                      window_s=0.5)

    s = report.slo
    rec = report.serve.recovery
    print(f"\n{report.n_arrivals} arrivals, {report.serve.n_ok} completed, "
          f"{rec.get('worker_deaths', 0)} worker death(s), "
          f"MTTR {rec.get('mttr_s') or 0:.2f}s")
    print(f"overall: p50={s['p50'] * 1e3:8.1f}ms  "
          f"p99={s['p99'] * 1e3:8.1f}ms  p999={s['p999'] * 1e3:8.1f}ms  "
          f"goodput={s['goodput_hz']:.1f}/s of {s['offered_hz']:.1f}/s "
          f"offered")
    print(f"\n{'window':>8s} {'offered':>8s} {'done':>6s} {'p999_ms':>10s} "
          f"{'SLO viol':>9s}  fault?")
    for w in s["windows"]:
        marker = "  <-- kill window" if w["faults"] else ""
        print(f"{w['t0']:7.1f}s {w['offered']:8d} {w['completed']:6d} "
              f"{w['p999'] * 1e3:10.1f} {w['violations']:9d}{marker}")
    spike = max((w["p999"] for w in s["windows"] if w["faults"]),
                default=0.0)
    # "clean" = windows with live offered load and no fault overlap (the
    # offered==0 tail is backlog drain, still paying for the outage)
    clean = [w["p999"] for w in s["windows"]
             if not w["faults"] and w["offered"]]
    print(f"\np999 spike in faulted windows: {spike * 1e3:.0f}ms"
          + (f" vs {max(clean) * 1e3:.0f}ms in clean ones" if clean else ""))


if __name__ == "__main__":
    main()
