"""DAG workload demo: a fork-join diamond replayed on the frontier
scheduler, with critical-path accounting and a Perfetto trace whose
flow arrows draw the dependency edges.

Builds a ``dag_diamond_workload`` (source -> 4 branches -> sink, one
branch a seeded 3x straggler), replays it on a 2-worker process fleet
through ``Emulator.emulate_many``, and shows what the structure buys:

* exact totals — the index-order fold is bit-identical to the
  workload's analytic expectation, edges or no edges;
* ``FleetReport.dag`` — critical path vs makespan vs summed work, the
  parallelism ratio, and per-node slack (the straggler branch carries
  zero slack; its siblings absorb the wait);
* a trace-event JSON with ``ph:"s"/"f"`` flow arrows along every edge,
  from each parent's ``done`` on its serving worker's track to the
  child's first dispatch on *its* track.

    PYTHONPATH=src python examples/dag_demo.py [out.json]

Open the written file at https://ui.perfetto.dev (or chrome://tracing)
and enable "Flow events" to see the diamond drawn across the two worker
tracks: the sink's three in-arrows all converge on its dispatch, and
the arrow from the straggler branch is the one that gates it.
"""
import os, sys
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path[:0] = [os.path.join(_ROOT, 'src'), _ROOT]

from repro.core import Emulator
from repro.fleet import FleetConfig
from repro.obs.recorder import Event
from repro.obs.trace import to_chrome_trace, validate_trace, write_trace
from repro.scenarios.dag import dag_diamond_workload

TILE, BLOCK = 64, 1 << 18


def main():
    out_path = sys.argv[1] if len(sys.argv) > 1 else "dag_trace.json"
    dag = dag_diamond_workload(fanout=4, work_flops=500 * 2.0 * TILE ** 3,
                               work_hbm=2.0 * BLOCK, samples_per=2,
                               straggler_index=1, straggler_factor=3.0)
    print(f"diamond: {len(dag)} nodes, {dag.n_edges} edges, "
          f"parents {dict(dag.parents_map)}")

    em = Emulator(compute_tile=TILE, mem_block=BLOCK)
    out = em.emulate_many(dag, config=FleetConfig.process(max_workers=2,
                                                          timeout=600.0))
    assert out.totals == dag.totals, "fold must match the analytic totals"
    print(f"replayed {out.n_replayed} nodes, totals exact: "
          f"{out.totals == dag.totals}")

    cp = out.dag
    print(f"critical path: {cp['critical_path_s']:.3f}s through nodes "
          f"{cp['critical_nodes']} (makespan {cp['makespan_s']:.3f}s, "
          f"summed work {cp['sum_work_s']:.3f}s, "
          f"parallelism {cp['parallelism']:.2f}x)")
    for idx, slack in sorted(cp["slack_s"].items()):
        label = dag.nodes[idx].profile.command
        tag = " <- critical" if idx in cp["critical_nodes"] else ""
        print(f"  node {idx} ({label}): slack {slack:.3f}s{tag}")

    events = [Event.from_dict(d) for d in out.obs.get("events", ())]
    trace = to_chrome_trace(events, meta={"demo": "dag diamond"})
    validate_trace(trace)
    arrows = [t for t in trace["traceEvents"]
              if t.get("cat") == "dag" and t["ph"] == "s"]
    assert len(arrows) == dag.n_edges, \
        f"expected {dag.n_edges} flow arrows, got {len(arrows)}"
    path = write_trace(out_path, trace)
    print(f"{len(arrows)} dependency flow arrows -> {path}")
    print("open at https://ui.perfetto.dev (enable flow events)")


if __name__ == "__main__":
    main()
