"""Batched serving of a small LM: prefill + greedy decode over request waves,
profiled by the Synapse runtime watchers.

PYTHONPATH=src python examples/serve_batched.py
"""
import os, sys
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path[:0] = [os.path.join(_ROOT, 'src'), _ROOT]

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.configs.run import RunConfig
from repro.core import RuntimeProfiler
from repro.models.model_zoo import build_model
from repro.serve.engine import Engine, Request


def main():
    cfg = reduced_config(get_config("qwen2-1.5b"))
    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    cache_dtype="float32", remat="none")
    model = build_model(cfg, run)
    params = model.init(jax.random.key(0))
    engine = Engine(model, params, batch_slots=4, max_len=64)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(0, cfg.vocab_size, size=n)),
                    max_new_tokens=8)
            for n in (5, 9, 3, 7, 4, 6)]

    prof = RuntimeProfiler(sample_rate=20).profile_callable(
        lambda: engine.serve(reqs), command="serve-batched")
    for i, r in enumerate(reqs):
        assert len(r.out_tokens) == r.max_new_tokens
        print(f"req{i}: prompt_len={len(r.prompt)} out={r.out_tokens}")
    print(f"\nserved {len(reqs)} requests in {prof.meta['wall_s']:.2f}s "
          f"({len(prof.samples)} profile samples)")


if __name__ == "__main__":
    main()
