"""Dispatch-overhead benchmark: fused schedule compiler vs per-sample replay.

The headline number for the schedule compiler (ISSUE 2): per-sample
emulator overhead on a fine-grained storage-free profile.  The profile
alternates between two distinct resource vectors so ``_collapse`` cannot
merge consecutive samples — the worst case for the per-sample path (one
Python→XLA round trip per atom per sample) and the case the fused path
lowers to ONE ``lax.scan`` dispatch for the whole profile.  Amounts are
kept near the one-iteration atom minimum so wall time is dominated by
dispatch overhead, which is what we are measuring.

Both paths are warmed first (plans built, programs traced) and must report
bit-identical consumed totals; the acceptance bar is a >=3x lower
per-sample overhead for the fused path.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import (Emulator, PlanCache, ResourceVector, Sample,
                        SynapseProfile)

TILE = 64                 # 1 compute iter = 2*64^3 = 524288 flops
BLOCK = 1 << 18           # 1 memory iter = 2*2^18  = 524288 bytes


def synthetic_profile(n_samples: int) -> SynapseProfile:
    """Storage-free profile alternating 1- and 2-iteration samples."""
    fpi = 2.0 * TILE ** 3
    bpi = 2.0 * BLOCK
    samples = [Sample(index=i, resources=ResourceVector(
        flops=(1 + i % 2) * fpi, hbm_bytes=(1 + i % 2) * bpi))
        for i in range(n_samples)]
    return SynapseProfile(command="bench:dispatch", samples=samples,
                          tags={"bench": "dispatch"})


def main(fast: bool = False):
    n = 256 if fast else 1024
    reps = 5
    em = Emulator(compute_tile=TILE, mem_block=BLOCK,
                  plan_cache=PlanCache())
    prof = synthetic_profile(n)

    legacy_rep = em.emulate(prof, fused=False)       # warm: builds plans
    fused_rep = em.emulate(prof, fused=True)         # warm: traces segment
    assert legacy_rep.consumed == fused_rep.consumed, \
        "fused and per-sample paths must consume identical totals"

    legacy_s = min(em.emulate(prof, fused=False).ttc_s
                   for _ in range(reps))
    fused_s = min(em.emulate(prof, fused=True).ttc_s
                  for _ in range(reps))
    ratio = legacy_s / fused_s if fused_s else float("inf")

    rows = [{
        "n_samples": n,
        "legacy_ttc_s": legacy_s,
        "fused_ttc_s": fused_s,
        "legacy_us_per_sample": legacy_s / n * 1e6,
        "fused_us_per_sample": fused_s / n * 1e6,
        "overhead_ratio": ratio,
        "legacy_dispatches": legacy_rep.n_dispatches,
        "fused_dispatches": fused_rep.n_dispatches,
        "consumed_flops": legacy_rep.consumed.flops,
        "consumed_hbm_bytes": legacy_rep.consumed.hbm_bytes,
        "consumed_identical": legacy_rep.consumed == fused_rep.consumed,
    }]
    emit("dispatch", rows)
    # Regression guard only: an idle host measures >=3x (the recorded
    # headline in experiments/results/dispatch.json); 2x keeps the CI smoke
    # job stable on noisy shared runners while still catching a real
    # regression to per-sample dispatch behavior.
    assert ratio >= 2.0, \
        f"fused path must cut per-sample overhead (got {ratio:.2f}x)"
    return rows


if __name__ == "__main__":
    main()
