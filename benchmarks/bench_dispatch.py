"""Dispatch-overhead benchmark: fused schedule compiler vs per-sample replay.

The headline number for the schedule compiler (ISSUE 2): per-sample
emulator overhead on a fine-grained storage-free profile.  The profile
alternates between two distinct resource vectors so ``_collapse`` cannot
merge consecutive samples — the worst case for the per-sample path (one
Python→XLA round trip per atom per sample) and the case the fused path
lowers to ONE ``lax.scan`` dispatch for the whole profile.  Amounts are
kept near the one-iteration atom minimum so wall time is dominated by
dispatch overhead, which is what we are measuring.

The collective scenario (ISSUE 5) is the same experiment on a
communication-heavy profile: every sample carries wire bytes, which the
pre-fused-collectives emulator lowered to one ``BarrierStep`` per sample
(``keep_collectives=True`` — still available as the meshless fallback)
while mesh-bound segments now fuse the whole profile into ONE scan whose
body runs the shard_map'd collective.  It re-execs python with two forced
host devices (XLA fixes the device count at first init, so the parent
process can't build the mesh itself).  Dispatch counts are asserted
EXACTLY; wall-clock gets a loose regression guard only (shared runners
swing ~2x run-to-run).

Both paths are warmed first (plans built, programs traced) and must report
bit-identical consumed totals.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit
from repro.core import (Emulator, PlanCache, ResourceVector, Sample,
                        SynapseProfile)

TILE = 64                 # 1 compute iter = 2*64^3 = 524288 flops
BLOCK = 1 << 18           # 1 memory iter = 2*2^18  = 524288 bytes


def synthetic_profile(n_samples: int) -> SynapseProfile:
    """Storage-free profile alternating 1- and 2-iteration samples."""
    fpi = 2.0 * TILE ** 3
    bpi = 2.0 * BLOCK
    samples = [Sample(index=i, resources=ResourceVector(
        flops=(1 + i % 2) * fpi, hbm_bytes=(1 + i % 2) * bpi))
        for i in range(n_samples)]
    return SynapseProfile(command="bench:dispatch", samples=samples,
                          tags={"bench": "dispatch"})


def collective_profile(n_samples: int) -> SynapseProfile:
    """Collective-heavy profile: every sample burns a little compute and
    moves alternating wire amounts (so no two consecutive samples
    collapse) — the shape that used to force one barrier per sample.
    Amounts sit at 1–2 collective-quantization iterations, the wire
    analogue of the near-minimum compute/memory amounts above: wall time
    is dominated by dispatch overhead, which is what we measure."""
    from repro.core.atoms import COLL_BLOCK_ELEMS, collective_factor
    fpi = 2.0 * TILE ** 3
    wpi = collective_factor("all-reduce", 2) * 4.0 * COLL_BLOCK_ELEMS
    samples = [Sample(index=i, resources=ResourceVector(
        flops=fpi, ici_bytes={"all-reduce": (1 + i % 2) * wpi}))
        for i in range(n_samples)]
    return SynapseProfile(command="bench:dispatch-collective",
                          samples=samples,
                          tags={"bench": "dispatch", "kind": "collective"})


def _collective_child(fast: bool) -> None:
    """Runs inside the forced-2-device subprocess: measure barrier-step
    replay (the old lowering) vs mesh-bound fused segments, assert the
    contracts, print one JSON row on the last stdout line."""
    import jax
    n = 256 if fast else 1024
    reps = 5
    mesh = jax.make_mesh((2,), ("model",))
    em = Emulator(compute_tile=TILE, mem_block=BLOCK, mesh=mesh,
                  plan_cache=PlanCache())
    prof = collective_profile(n)
    barrier_sched = em.compile(prof, keep_collectives=True)
    fused_sched = em.compile(prof)

    barrier_rep = em.replay(barrier_sched, command=prof.command)   # warm
    fused_rep = em.replay(fused_sched, command=prof.command)       # warm
    assert fused_rep.consumed == barrier_rep.consumed == prof.totals, \
        "fused and barrier collective replay must consume identical totals"
    # dispatch counts are exact, not a distribution: one fused scan for the
    # whole profile vs per-sample compute+wire launches on the barrier path
    assert fused_rep.n_dispatches == 1, fused_rep.n_dispatches
    assert barrier_rep.n_dispatches == 2 * n, barrier_rep.n_dispatches
    assert fused_rep.n_collective_dispatches == \
        barrier_rep.n_collective_dispatches == n

    barrier_s = min(em.replay(barrier_sched, command=prof.command).ttc_s
                    for _ in range(reps))
    fused_s = min(em.replay(fused_sched, command=prof.command).ttc_s
                  for _ in range(reps))
    ratio = barrier_s / fused_s if fused_s else float("inf")
    # loose wall-clock guard only (see module docstring)
    assert ratio >= 2.0, \
        f"fused collectives must cut per-sample overhead (got {ratio:.2f}x)"
    print(json.dumps({
        "n_samples": n,
        "barrier_ttc_s": barrier_s,
        "fused_ttc_s": fused_s,
        "barrier_us_per_sample": barrier_s / n * 1e6,
        "fused_us_per_sample": fused_s / n * 1e6,
        "overhead_ratio": ratio,
        "barrier_dispatches": barrier_rep.n_dispatches,
        "fused_dispatches": fused_rep.n_dispatches,
        "collective_dispatches": fused_rep.n_collective_dispatches,
        "consumed_ici_bytes": fused_rep.consumed.ici_total,
        "emulated_ici_bytes": fused_rep.emulated_ici_bytes,
        "consumed_identical": fused_rep.consumed == barrier_rep.consumed,
    }))


def run_collective_scenario(fast: bool) -> dict:
    """Spawn the forced-device child and collect its JSON row."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS")
    env["XLA_FLAGS"] = ((f"{flags} " if flags else "")
                        + "--xla_force_host_platform_device_count=2")
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    old = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = src + (os.pathsep + old if old else "")
    args = [sys.executable, "-m", "benchmarks.bench_dispatch",
            "--collective-child"] + (["--fast"] if fast else [])
    out = subprocess.run(args, capture_output=True, text=True, env=env,
                         timeout=560, cwd=os.path.dirname(src))
    if out.returncode != 0:
        raise RuntimeError("collective dispatch child failed:\n"
                           + out.stdout + "\n" + out.stderr)
    return json.loads(out.stdout.strip().splitlines()[-1])


def main(fast: bool = False):
    n = 256 if fast else 1024
    reps = 5
    em = Emulator(compute_tile=TILE, mem_block=BLOCK,
                  plan_cache=PlanCache())
    prof = synthetic_profile(n)

    legacy_rep = em.emulate(prof, fused=False)       # warm: builds plans
    fused_rep = em.emulate(prof, fused=True)         # warm: traces segment
    assert legacy_rep.consumed == fused_rep.consumed, \
        "fused and per-sample paths must consume identical totals"

    legacy_s = min(em.emulate(prof, fused=False).ttc_s
                   for _ in range(reps))
    fused_s = min(em.emulate(prof, fused=True).ttc_s
                  for _ in range(reps))
    ratio = legacy_s / fused_s if fused_s else float("inf")

    rows = [{
        "n_samples": n,
        "legacy_ttc_s": legacy_s,
        "fused_ttc_s": fused_s,
        "legacy_us_per_sample": legacy_s / n * 1e6,
        "fused_us_per_sample": fused_s / n * 1e6,
        "overhead_ratio": ratio,
        "legacy_dispatches": legacy_rep.n_dispatches,
        "fused_dispatches": fused_rep.n_dispatches,
        "consumed_flops": legacy_rep.consumed.flops,
        "consumed_hbm_bytes": legacy_rep.consumed.hbm_bytes,
        "consumed_identical": legacy_rep.consumed == fused_rep.consumed,
    }]
    coll_row = run_collective_scenario(fast)
    rows.append({"scenario": "collective", **coll_row})
    emit("dispatch", rows)
    # Regression guard only: an idle host measures >=3x (the recorded
    # headline in experiments/results/dispatch.json); 2x keeps the CI smoke
    # job stable on noisy shared runners while still catching a real
    # regression to per-sample dispatch behavior.
    assert ratio >= 2.0, \
        f"fused path must cut per-sample overhead (got {ratio:.2f}x)"
    return rows


if __name__ == "__main__":
    if "--collective-child" in sys.argv:
        _collective_child(fast="--fast" in sys.argv)
    else:
        main(fast="--fast" in sys.argv)
