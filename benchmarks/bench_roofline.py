"""Roofline table over the dry-run artifacts (assignment deliverable g).

Per (arch × shape × mesh): the three per-chip roofline terms against TPU v5e
(197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link ICI), the dominant term,
MODEL_FLOPS = 6·N(_active)·D vs trip-count-aware HLO FLOPs, and a
recommendation string for the dominant bottleneck.  This is the Synapse
predictor applied to our own workloads.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit
from repro.core import TPU_V5E, from_dryrun_artifact, predict_resources

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "artifacts")


def _advice(dom: str, rec: dict) -> str:
    w = rec["walker"]
    if dom == "compute":
        ratio = rec.get("useful_flops_ratio") or 0
        if ratio < 0.5:
            return ("compute-bound with %.0f%% useful flops: cut remat/causal "
                    "waste (block skipping, dots-saveable remat)" % (100 * ratio))
        return "compute-bound near peak: increase arithmetic efficiency (bf16 everywhere, fuse)"
    if dom == "memory":
        return ("HBM-bound: keep attention/probability blocks VMEM-resident "
                "(Pallas flash kernel), fuse elementwise chains, bf16 weights")
    if dom == "collective":
        ax = w.get("collective_by_axis", {})
        top = max(ax, key=ax.get) if ax else "?"
        return (f"collective-bound on '{top}': overlap with compute, shrink "
                "payload (bf16/int8 collectives), reorder sharding")
    return "storage-bound: async checkpoint, larger write blocks"


def main(fast: bool = False, mesh_tag: str = "16x16"):
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS,
                                              f"*__{mesh_tag}.json"))):
        rec = json.load(open(path))
        if not rec.get("ok"):
            continue
        if rec.get("skipped"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": mesh_tag, "status": "SKIP",
                         "note": rec["skip_reason"]})
            continue
        rv = from_dryrun_artifact(rec)
        pred = predict_resources(rv, TPU_V5E)
        t = pred.terms
        n_dev = rec["n_devices"]
        w = rec["walker"]
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": mesh_tag,
            "status": "ok",
            "compute_s": t.compute_s,
            "memory_s": t.memory_s,
            "collective_s": t.collective_s,
            "dominant": t.dominant,
            "t_step_s": t.t_max,
            "model_flops": rec["model_flops"],
            "hlo_flops_total": w["flops"] * n_dev,
            "useful_ratio": rec.get("useful_flops_ratio"),
            "mfu_at_roofline": (rec["model_flops"] /
                                (n_dev * TPU_V5E.peak_flops) / t.t_max)
            if t.t_max else None,
            "mem_gb_per_chip": rec["memory"]["per_device_total"] / 1e9,
            "hbm_bytes_upper": w["hbm_bytes"],
            "note": _advice(t.dominant, rec),
        })
    emit(f"roofline_{mesh_tag}", rows,
         keys=["arch", "shape", "status", "compute_s", "memory_s",
               "collective_s", "dominant", "t_step_s", "useful_ratio",
               "mfu_at_roofline", "mem_gb_per_chip"])
    return rows


if __name__ == "__main__":
    main()
