"""Scenario engine + fleet emulation benchmark.

Part 1 drives every registered scenario through the full
generate -> predict -> emulate -> store lifecycle and reports per-stage
timings.  Part 2 is the fleet experiment: K profiles replayed concurrently
through ``Emulator.emulate_many`` with a shared plan cache, against (a)
serial cold replay with per-profile caches — the compile-dedup win — and
(b) the sum of per-profile TTCs — the concurrency win.
"""
from __future__ import annotations

import tempfile
import time

from benchmarks.common import emit
from repro.core import Emulator, PlanCache, ProfileStore
from repro.scenarios import generate, list_scenarios, run_scenario

FAST_PARAMS = {
    "training_scan": dict(n_steps=6, ckpt_every=3, flops_per_step=2e7,
                          hbm_per_step=8e6, ckpt_bytes=2 << 20),
    "serving_traffic": dict(n_requests=6, n_params=2e6, prefill_tokens=64,
                            decode_tokens=8),
    "fanout_straggler": dict(n_workers=4, work_flops=2e7, work_hbm=4e6),
    "retry_storm": dict(n_tasks=4, work_flops=2e7, work_hbm=2e6),
    "mixed_fleet": dict(total_samples=8),
}


def _params(name: str, fast: bool) -> dict:
    # .get: scenarios registered after this file keep defaults in --fast
    return FAST_PARAMS.get(name, {}) if fast else {}


def main(fast: bool = False):
    store = ProfileStore(tempfile.mkdtemp(prefix="synapse_bench_store_"))
    rows = []
    for name in list_scenarios():
        params = _params(name, fast)
        t0 = time.perf_counter()
        prof = generate(name, **params)
        gen_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = run_scenario(name, store=store, **params)
        run_s = time.perf_counter() - t0
        rows.append({"scenario": name, "n_samples": len(prof.samples),
                     "gflops": prof.totals.flops / 1e9,
                     "generate_s": gen_s, "run_scenario_s": run_s,
                     "emulate_ttc_s": res.report.ttc_s})
    emit("scenarios", rows)

    # --- fleet: shared plan cache vs cold per-profile replay ---------------
    k = 4 if fast else 8
    profiles = [generate("training_scan", **_params("training_scan", True))
                for _ in range(k)]
    shared = Emulator(plan_cache=PlanCache())
    t0 = time.perf_counter()
    from repro.fleet import FleetConfig
    fleet = shared.emulate_many(
        profiles, config=FleetConfig.thread(max_workers=min(k, 4)))
    fleet_wall = time.perf_counter() - t0

    # true serial replay, warm shared cache: the honest concurrency baseline
    # (FleetReport.serial_s sums TTCs measured under contention)
    t0 = time.perf_counter()
    for p in profiles:
        shared.emulate(p)
    warm_serial = time.perf_counter() - t0

    cold_plans = 0
    t0 = time.perf_counter()
    for p in profiles:
        em = Emulator(plan_cache=PlanCache())
        em.emulate(p)
        cold_plans += em.plan_cache.plans_built
    cold_total = time.perf_counter() - t0

    emit("scenario_fleet", [{
        "k_profiles": k,
        "fleet_wall_s": fleet_wall,
        "fleet_serial_s": warm_serial,
        "fleet_speedup": warm_serial / fleet_wall if fleet_wall else 0.0,
        "fleet_speedup_estimate": fleet.speedup,
        "fleet_total_s": fleet.wall_s,
        "cold_total_s": cold_total,
        "shared_plans_built": fleet.cache_stats["plans_built"],
        "shared_plan_hits": fleet.cache_stats["hits"],
        "cold_plans_built": cold_plans,
    }])
    assert fleet.cache_stats["plans_built"] < cold_plans, \
        "shared plan cache must build fewer plans than K cold replays"
    return rows


if __name__ == "__main__":
    main()
