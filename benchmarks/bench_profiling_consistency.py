"""Paper Experiment 2 (Figs. 5, 6) — profiling consistency.

(a) Repeated profiling of the same application yields low-variance metrics
    (requirement P.4), across app sizes and sampling rates.
(b) Fig. 6 effect: metrics needing multiple samples (resident memory) are
    underestimated when the rate allows ~1 sample per run, and stabilize
    with more samples.
(c) The static watcher is *exactly* consistent: same compiled step -> same
    FLOPs, byte and collective counts, bit-for-bit (the determinism the
    paper could only approximate with hardware counters).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, tiny_train_workload
from repro.core import RuntimeProfiler, analyze_hlo, profile_compiled


def main(fast: bool = False):
    rows = []
    repeats = 3 if fast else 5
    for steps in ([2] if fast else [1, 4]):
        run_fn, meta = tiny_train_workload(steps=steps)
        for rate in ([20] if fast else [5, 20, 100]):
            walls, cpus, peaks = [], [], []
            for _ in range(repeats):
                p = RuntimeProfiler(sample_rate=rate).profile_callable(
                    run_fn, command="bench-lm", tags={"s": str(steps)})
                walls.append(p.meta["wall_s"])
                cpus.append(p.meta["watcher_results"]["cpu"].get("cpu_s", 0))
                peaks.append(p.totals.peak_mem_bytes)
            rows.append({
                "metric": "repeat", "app_steps": steps, "sample_rate": rate,
                "wall_mean_s": float(np.mean(walls)),
                "wall_std_pct": 100 * float(np.std(walls) / np.mean(walls)),
                "cpu_mean_s": float(np.mean(cpus)),
                "cpu_std_pct": 100 * float(np.std(cpus) /
                                           max(np.mean(cpus), 1e-9)),
                "peakmem_mean_mb": float(np.mean(peaks)) / 1e6,
            })

    # (b) Fig 6: resident memory under-estimation at ~1 sample/run
    run_fn, meta = tiny_train_workload(steps=2)
    slow = RuntimeProfiler(sample_rate=1).profile_callable(
        run_fn, command="m", tags={})
    fast_p = RuntimeProfiler(sample_rate=100).profile_callable(
        run_fn, command="m", tags={})
    max_rss_slow = max((s.resources.host_mem_bytes for s in slow.samples),
                       default=0)
    max_rss_fast = max((s.resources.host_mem_bytes for s in fast_p.samples),
                       default=0)
    rows.append({"metric": "fig6_rss_underestimate",
                 "rss_1persec_mb": max_rss_slow / 1e6,
                 "rss_100persec_mb": max_rss_fast / 1e6,
                 "n_samples_slow": len(slow.samples),
                 "n_samples_fast": len(fast_p.samples)})

    # (c) static watcher: bit-identical across repeated analyses
    import jax
    from repro.train.step import abstract_train_state
    model, step = meta["model"], meta["step"]
    compiled = step.lower(
        jax.eval_shape(lambda: None) if False else
        _abstract_state(model), _abstract_batch(meta)).compile()
    c1 = analyze_hlo(compiled.as_text())
    c2 = analyze_hlo(compiled.as_text())
    rows.append({"metric": "static_determinism",
                 "flops": c1.flops, "flops_repeat": c2.flops,
                 "identical": c1.flops == c2.flops and
                 c1.hbm_bytes == c2.hbm_bytes})
    emit("profiling_consistency", rows)
    return rows


def _abstract_state(model):
    from repro.train.step import abstract_train_state
    return abstract_train_state(model)


def _abstract_batch(meta):
    import jax
    import jax.numpy as jnp
    cfg = meta["cfg"]
    B, S = 4, 64
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "targets": jax.ShapeDtypeStruct((B, S), jnp.int32)}


if __name__ == "__main__":
    main()
