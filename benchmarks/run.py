"""Benchmark harness — one entry per paper experiment/table + the roofline
table for the assigned architectures (deliverable d).

``python -m benchmarks.run``          full set
``python -m benchmarks.run --fast``   reduced sizes (CI)
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from benchmarks import (bench_atoms, bench_dispatch,
                            bench_emulation_portability,
                            bench_emulation_same_host, bench_fleet,
                            bench_profiling_consistency,
                            bench_profiling_overhead, bench_roofline,
                            bench_scenarios, bench_service)
    suite = [
        ("atoms", bench_atoms.main),
        ("dispatch", bench_dispatch.main),
        ("profiling_overhead", bench_profiling_overhead.main),
        ("profiling_consistency", bench_profiling_consistency.main),
        ("emulation_same_host", bench_emulation_same_host.main),
        ("emulation_portability", bench_emulation_portability.main),
        ("roofline", bench_roofline.main),
        ("scenarios", bench_scenarios.main),
        ("fleet", bench_fleet.main),
        # substring --only matching: keep these names free of "fleet" so
        # `--only fleet` doesn't drag the soak/chaos legs along
        ("soak", bench_fleet.soak),
        ("chaos", bench_fleet.chaos),
        ("dag", bench_fleet.dag),
        ("service", bench_service.main),
    ]
    for name, fn in suite:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            fn(fast=args.fast)
            print(f"## {name}: done in {time.time()-t0:.1f}s\n", flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"## {name}: FAILED {type(e).__name__}: {e}", flush=True)
            raise


if __name__ == '__main__':
    main()
