"""Paper Experiment 4 (Figs. 8, 9) + Fig. 3 — profile once, emulate anywhere.

The profile taken on this host is replayed under emulated "other machines"
(CPU 25% faster / disk 50% slower — the exact Fig. 3 scenario — plus
Stampede/Archer-like scalings), and TTC is *predicted* for hardware we
cannot run (TPU v5e chip).  Checks: consumption totals are invariant, TTC
scales with the hardware, and the dominant resource flips.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, tiny_train_workload
from repro.core import (Emulator, HOST_ARCHER_NODE, HOST_I7_M620,
                        HOST_STAMPEDE_NODE, TPU_V5E, calibrate, compare,
                        predict, profile_compiled)
from repro.core.metrics import ResourceVector, Sample, SynapseProfile


def _mixed_profile(calib, io_mb: float = 16.0, steps: int = 4):
    """A profile with both compute and storage so dominance can flip."""
    run_fn, meta = tiny_train_workload(steps=steps)
    from benchmarks.bench_profiling_consistency import (_abstract_batch,
                                                        _abstract_state)
    compiled = meta["step"].lower(_abstract_state(meta["model"]),
                                  _abstract_batch(meta)).compile()
    prof = profile_compiled(compiled, command="bench-lm", granularity="scan")
    samples = []
    for i in range(steps):
        for s in prof.samples:
            samples.append(Sample(index=len(samples), resources=s.resources,
                                  label=s.label))
        # checkpoint-like write after each step
        samples.append(Sample(
            index=len(samples),
            resources=ResourceVector(
                storage_write_bytes=io_mb * 1e6 / steps),
            label="ckpt"))
    return SynapseProfile(command="bench-lm+io", samples=samples)


def main(fast: bool = False):
    calib = calibrate()
    prof = _mixed_profile(calib, steps=2 if fast else 4)
    rows = []

    # --- emulate under scaled hosts (Fig. 3 scenario) -----------------------
    scenarios = [
        ("this_host", 1.0, 1.0),
        ("cpu_25pct_faster", 1 / 1.25, 1.0),
        ("disk_50pct_slower", 1.0, 2.0),
        ("fig3_both", 1 / 1.25, 2.0),
    ]
    emulator = Emulator(calib)
    base_ttc = None
    for name, fscale, sscale in (scenarios[:2] if fast else scenarios):
        rep = emulator.emulate(prof, flops_scale=fscale,
                               storage_scale=sscale)
        if base_ttc is None:
            base_ttc = rep.ttc_s
        rows.append({"kind": "emulated", "target": name,
                     "ttc_s": rep.ttc_s,
                     "vs_host_pct": 100 * (rep.ttc_s - base_ttc) / base_ttc,
                     "flops": rep.consumed.flops,
                     "write_bytes": rep.consumed.storage_write_bytes})

    # --- predict on machines we cannot run (incl. TPU) ----------------------
    comparison = compare(prof, [HOST_I7_M620, HOST_STAMPEDE_NODE,
                                HOST_ARCHER_NODE, TPU_V5E])
    for hw, v in comparison.items():
        rows.append({"kind": "predicted", "target": hw,
                     "ttc_s": v["ttc_max"], "ttc_serial_s": v["ttc_sum"],
                     "dominant": v["dominant_total"],
                     "dominant_histogram": str(v["dominant_histogram"])})
    emit("emulation_portability", rows)
    return rows


if __name__ == "__main__":
    main()
