"""Live traffic service benchmark: offered-load sweep → the goodput knee.

Two legs, both against one warm 2-worker standing fleet (the pool spawn
is paid once and amortized across every rate — exactly the pattern a
long-lived service runs):

  * smoke — a short constant-rate run whose asserts are exact and
    noise-free: every arrival completes, consumed totals equal the
    analytic request count x per-request amounts bit-for-bit, the SLO
    report carries non-empty windows/percentiles, and the standing
    fleet shuts down clean.  This is the CI gate.
  * sweep — constant-rate runs at multiples of the measured capacity
    (workers / median replay time, calibrated from the smoke run so the
    knee lands inside the sweep on any machine).  Below the knee
    goodput tracks offered load and the tail stays at replay latency;
    past it the queue grows for the whole run and p99 blows up — the
    open-loop signature a closed-loop replayer structurally cannot
    show.

Rows merge into ``experiments/results/service.json`` keyed on a
``scenario`` field.  Wall-clock guards are deliberately absent: the
sweep's *shape* (goodput saturates, tail inflates) is asserted instead,
which container-speed swings don't touch.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import Emulator, ResourceVector, Sample, SynapseProfile
from repro.fleet import FleetConfig
from repro.scenarios import register
from repro.scenarios.base import _REGISTRY
from repro.service import SLO, ConstantArrivals, StandingFleet, run_load

TILE, BLOCK = 64, 1 << 18
FPI, BPI = 2.0 * TILE ** 3, 2.0 * BLOCK
UNITS = 4                  # samples per request: totals stay analytic
ITERS = 128                # compute iterations per sample: heavy enough
                           # that worker replay, not parent admission,
                           # is the capacity limit the sweep measures
SCENARIO = "svc_bench_probe"
WORKERS = 2


def _probe(units=UNITS):
    """Exact-amount request: ``units`` samples of ``ITERS`` compute
    iterations + one memory iteration each, so folded totals are
    integer-exact in float64."""
    return SynapseProfile(
        command="svc-bench-probe",
        samples=[Sample(index=i,
                        resources=ResourceVector(flops=ITERS * FPI,
                                                 hbm_bytes=BPI))
                 for i in range(units)])


def _arrivals(rate_hz, n):
    return ConstantArrivals(rate_hz=rate_hz, n_requests=n,
                            scenario=SCENARIO)


def _run(em, standing, rate_hz, n, window_s=0.5):
    return run_load(em, _arrivals(rate_hz, n), standing=standing,
                    slo=SLO(target_ms=200.0, percentile=0.99),
                    window_s=window_s)


def _row(tag, rep, **extra):
    s = rep.slo
    return {"scenario": tag, "n": rep.n_arrivals, "n_ok": rep.serve.n_ok,
            "offered_hz": s["offered_hz"], "goodput_hz": s["goodput_hz"],
            "p50_ms": s["p50"] * 1e3, "p99_ms": s["p99"] * 1e3,
            "p999_ms": s["p999"] * 1e3, "mean_ms": s["mean"] * 1e3,
            "violations": s["n_violations"], **extra}


def main(fast: bool = False) -> None:
    register(SCENARIO, "exact-amount service bench probe", units=UNITS)(
        _probe)
    em = Emulator(compute_tile=TILE, mem_block=BLOCK)
    standing = StandingFleet(
        em, FleetConfig.process(max_workers=WORKERS, timeout=300.0))
    rows = []
    try:
        standing.warmup()

        # -- CI smoke: exact totals, non-empty report, clean shutdown ----
        n = 6 if fast else 16
        rep = _run(em, standing, rate_hz=20.0, n=n)
        assert rep.n_arrivals == n and rep.serve.n_ok == n, \
            f"smoke lost requests: {rep.serve.n_ok}/{n}"
        assert rep.serve.n_skipped == 0
        assert rep.serve.totals.flops == n * UNITS * ITERS * FPI
        assert rep.serve.totals.hbm_bytes == n * UNITS * BPI
        assert rep.slo["windows"], "percentile report must be non-empty"
        assert rep.slo["p50"] > 0.0 and rep.slo["p999"] >= rep.slo["p50"]
        rows.append(_row("smoke", rep, rate_hz=20.0))

        # -- calibrate: capacity == drain rate under a saturating burst --
        # (measured dispatch-to-done over the whole backlog, so it covers
        # the full pipeline — parent admission + IPC + worker replay —
        # and the sweep's knee lands on any machine)
        burst = _run(em, standing, rate_hz=300.0, n=40 if fast else 80)
        stamps = [r.timing for r in burst.serve.records
                  if r.timing is not None and r.timing.ok]
        drain_s = (max(t.done for t in stamps)
                   - min(t.dispatched for t in stamps))
        capacity = max(len(stamps) / max(drain_s, 1e-3), 4.0)
        rows.append(_row("burst", burst, rate_hz=300.0))
        print(f"# calibration: saturated drain ~{capacity:.0f}/s")

        # -- sweep: the goodput knee -------------------------------------
        factors = (0.5, 2.0) if fast else (0.25, 0.5, 1.0, 2.0, 4.0)
        span_s = 1.0 if fast else 2.0        # offered window per run
        for f in factors:
            rate = min(max(capacity * f, 2.0), 300.0)
            n_req = max(8, min(int(rate * span_s), 300))
            r = _run(em, standing, rate_hz=rate, n=n_req)
            assert r.serve.n_ok == r.n_arrivals   # open-loop drops nothing
            rows.append(_row("sweep", r, load_factor=f, rate_hz=rate,
                             capacity_hz=capacity))
        sweep = [r for r in rows if r["scenario"] == "sweep"]
        # shape asserts (noise-free): goodput cannot exceed offered, and
        # the overloaded tail is no better than the underloaded one
        assert all(r["goodput_hz"] <= r["offered_hz"] + 1e-9 for r in sweep)
        assert sweep[-1]["p99_ms"] >= sweep[0]["p99_ms"]
    finally:
        standing.close()
        _REGISTRY.pop(SCENARIO, None)
    assert not standing.active and standing.pending == 0  # clean shutdown
    emit("service", rows)


if __name__ == "__main__":
    main(fast="--fast" in __import__("sys").argv)
