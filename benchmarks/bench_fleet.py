"""Fleet executor benchmark: thread vs process vs remote, cold vs warm.

Replays the same mixed fleet several ways and reports where each
executor's costs live:

  * ``thread_wall_s``   — in-process thread fleet (the PR 1/2 baseline),
                          warm plan cache and segment programs;
  * ``process_cold_s``  — first ``ProcessFleet.run`` after spawn: each
                          worker traces its fused programs once (worker
                          spawn + jax import time is reported separately
                          as ``spawn_s``);
  * ``process_warm_s``  — the same pool again: pure replay + IPC, the
                          steady-state cost a long-lived fleet pays;
  * ``remote_warm_s``   — the same bundles through the full network
                          stack: loopback TCP to ``repro.fleet.agent``
                          subprocesses (one worker each), so
                          ``framing_overhead`` = remote_warm /
                          process_warm isolates what the length-prefixed
                          pickle framing + agent proxy hop add over a raw
                          ``Pipe`` (agent join/spawn cost is
                          ``remote_join_s``).

The regression guards are deliberately loose — this container's wall-clock
ratios swing ~2x run-to-run (see bench_dispatch) — and the remote scenario
has NO wall-clock gate at all: the hard assert is correctness, which is
noise-free — every process- and remote-fleet report must consume totals
bit-identical to the in-process replay.  The warm-pool guard catches the
failure mode that matters architecturally: workers re-tracing per bundle
instead of once per process would push warm replay toward cold time and
far past the bound.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

from benchmarks.common import emit
from repro.core import Emulator, PlanCache
from repro.fleet import (ProcessFleet, RemoteFleet, WorkerSpec,
                         bundle_profile)
from repro.scenarios import generate

WORKERS = 2


def _spawn_agents(port: int, n: int):
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    old = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = src + (os.pathsep + old if old else "")
    return [subprocess.Popen(
        [sys.executable, "-m", "repro.fleet.agent",
         "--connect", f"127.0.0.1:{port}", "--workers", "1"],
        env=env) for _ in range(n)]


def fleet_profiles(k: int):
    """A mixed fleet: scan steps + checkpoints, request traffic, stragglers."""
    kinds = [
        lambda i: generate("training_scan", n_steps=8, ckpt_every=4,
                           flops_per_step=4e7, hbm_per_step=3.4e7,
                           ckpt_bytes=1 << 20),
        lambda i: generate("serving_traffic", n_requests=6, n_params=2e6,
                           prefill_tokens=64, decode_tokens=8, seed=i),
        lambda i: generate("fanout_straggler", n_workers=4, work_flops=5e7,
                           work_hbm=4e7, jitter=0.0, seed=i),
    ]
    return [kinds[i % len(kinds)](i) for i in range(k)]


def main(fast: bool = False):
    k = 4 if fast else 8
    reps = 3
    profiles = fleet_profiles(k)
    em = Emulator(plan_cache=PlanCache())

    em.emulate_many(profiles, max_workers=WORKERS)          # warm in-process
    thread_fleet = None
    thread_s = float("inf")
    for _ in range(reps):
        f = em.emulate_many(profiles, max_workers=WORKERS)
        if f.wall_s < thread_s:
            thread_s, thread_fleet = f.wall_s, f

    bundles = [bundle_profile(em, p) for p in profiles]
    t0 = time.perf_counter()
    fleet = ProcessFleet(WORKERS, WorkerSpec(emulator=em.spec()))
    try:
        fleet.warmup()
        spawn_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        cold_reports = fleet.run(bundles)
        cold_s = time.perf_counter() - t0

        warm_s = float("inf")
        warm_reports = cold_reports
        for _ in range(reps):
            t0 = time.perf_counter()
            r = fleet.run(bundles)
            dt = time.perf_counter() - t0
            if dt < warm_s:
                warm_s, warm_reports = dt, r
    finally:
        fleet.close()

    # -- remote scenario: same bundles over loopback TCP agents ------------
    remote = RemoteFleet(WorkerSpec(emulator=em.spec()),
                         listen="127.0.0.1:0", agents=WORKERS)
    procs = _spawn_agents(remote.bound_addr[1], WORKERS)
    try:
        t0 = time.perf_counter()
        remote.warmup(timeout=300.0)
        remote_join_s = time.perf_counter() - t0

        remote.run(bundles)                    # agents trace once (cold)
        remote_warm_s = float("inf")
        remote_reports = None
        for _ in range(reps):
            t0 = time.perf_counter()
            r = remote.run(bundles)
            dt = time.perf_counter() - t0
            if dt < remote_warm_s:
                remote_warm_s, remote_reports = dt, r
    finally:
        remote.close()
        for p in procs:
            try:
                p.wait(timeout=30.0)
            except subprocess.TimeoutExpired:
                p.kill()
    em.storage.cleanup()

    identical = all(
        pr.consumed == tr.consumed and pr.n_samples == tr.n_samples
        for pr, tr in zip(warm_reports, thread_fleet.reports))
    remote_identical = all(
        rr.consumed == tr.consumed and rr.n_samples == tr.n_samples
        for rr, tr in zip(remote_reports, thread_fleet.reports))
    rows = [{
        "k_profiles": k,
        "workers": WORKERS,
        "thread_wall_s": thread_s,
        "spawn_s": spawn_s,
        "process_cold_s": cold_s,
        "process_warm_s": warm_s,
        "warm_vs_thread": warm_s / thread_s if thread_s else 0.0,
        "cold_vs_warm": cold_s / warm_s if warm_s else 0.0,
        "remote_agents": WORKERS,
        "remote_join_s": remote_join_s,
        "remote_warm_s": remote_warm_s,
        "framing_overhead": remote_warm_s / warm_s if warm_s else 0.0,
        "worker_deaths": fleet.worker_deaths,
        "agent_deaths": remote.worker_deaths,
        "consumed_identical": identical,
        "remote_consumed_identical": remote_identical,
    }]
    emit("fleet", rows)
    assert identical, \
        "process-fleet totals must be bit-identical to in-process replay"
    # correctness only for the network hop — framing_overhead is reported,
    # not gated (container wall-clock swings ~2x run-to-run)
    assert remote_identical, \
        "remote-fleet totals must be bit-identical to in-process replay"
    # Loose guards only (2x run-to-run noise): warm process replay must be
    # in the same decade as the thread fleet — re-tracing per bundle would
    # be orders of magnitude off — and an absolute floor keeps tiny fast
    # runs from tripping on IPC constants.
    bound = max(5.0 * thread_s, 2.0)
    assert warm_s <= bound, \
        f"warm process fleet {warm_s:.3f}s vs bound {bound:.3f}s " \
        f"(thread fleet {thread_s:.3f}s) — are workers re-tracing per bundle?"
    return rows


if __name__ == "__main__":
    main()
