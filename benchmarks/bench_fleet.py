"""Fleet executor benchmark: thread vs process vs remote, cold vs warm —
plus the streamed-production-day soak (``soak()``).

Replays the same mixed fleet several ways and reports where each
executor's costs live:

  * ``thread_wall_s``   — in-process thread fleet (the PR 1/2 baseline),
                          warm plan cache and segment programs;
  * ``process_cold_s``  — first ``ProcessFleet.run`` after spawn: each
                          worker traces its fused programs once (worker
                          spawn + jax import time is reported separately
                          as ``spawn_s``);
  * ``process_warm_s``  — the same pool again: pure replay + IPC, the
                          steady-state cost a long-lived fleet pays;
  * ``remote_warm_s``   — the same bundles through the full network
                          stack: loopback TCP to ``repro.fleet.agent``
                          subprocesses (one worker each), so
                          ``framing_overhead`` = remote_warm /
                          process_warm isolates what the length-prefixed
                          pickle framing + agent proxy hop add over a raw
                          ``Pipe`` (agent join/spawn cost is
                          ``remote_join_s``).

The regression guards are deliberately loose — this container's wall-clock
ratios swing ~2x run-to-run (see bench_dispatch) — and the remote scenario
has NO wall-clock gate at all: the hard assert is correctness, which is
noise-free — every process- and remote-fleet report must consume totals
bit-identical to the in-process replay.  The warm-pool guard catches the
failure mode that matters architecturally: workers re-tracing per bundle
instead of once per process would push warm replay toward cold time and
far past the bound.

``soak()`` is the ISSUE 6 acceptance scenario: a synthetic "production
day" of profiles streamed through an elastic process fleet at a bounded
compile-ahead window, never materialized.  Its hard asserts are exact
(profile amounts are powers of two, so every fold is integer-exact in
float64): streamed totals == materialized totals == the analytic
expectation, and coordinator peak-RSS growth is *independent of profile
count* — a 10x-smaller streamed run must show no less growth (within
slack) than the full one.  Both suites merge rows into
``experiments/results/fleet.json`` keyed on a ``scenario`` field.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from benchmarks.common import RESULT_DIR, emit
from repro.core import (Emulator, PlanCache, ResourceVector, Sample,
                        SynapseProfile)
from repro.fleet import (FleetConfig, ProcessFleet, RemoteFleet, WorkerSpec,
                         bundle_profile)
from repro.scenarios import generate

WORKERS = 2


def _emit_fleet(scenario: str, rows):
    """``emit`` overwrites ``fleet.json``; merge by scenario so the
    executors row and the soak row coexist in one results file."""
    path = os.path.join(RESULT_DIR, "fleet.json")
    merged = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                # rows written before scenario tagging are executors rows
                merged = [r for r in json.load(f)
                          if r.get("scenario", "executors") != scenario]
        except (ValueError, OSError):
            merged = []
    for r in rows:
        r.setdefault("scenario", scenario)
    emit("fleet", merged + rows)


def _spawn_agents(port: int, n: int):
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    old = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = src + (os.pathsep + old if old else "")
    return [subprocess.Popen(
        [sys.executable, "-m", "repro.fleet.agent",
         "--connect", f"127.0.0.1:{port}", "--workers", "1"],
        env=env) for _ in range(n)]


def fleet_profiles(k: int):
    """A mixed fleet: scan steps + checkpoints, request traffic, stragglers."""
    kinds = [
        lambda i: generate("training_scan", n_steps=8, ckpt_every=4,
                           flops_per_step=4e7, hbm_per_step=3.4e7,
                           ckpt_bytes=1 << 20),
        lambda i: generate("serving_traffic", n_requests=6, n_params=2e6,
                           prefill_tokens=64, decode_tokens=8, seed=i),
        lambda i: generate("fanout_straggler", n_workers=4, work_flops=5e7,
                           work_hbm=4e7, jitter=0.0, seed=i),
    ]
    return [kinds[i % len(kinds)](i) for i in range(k)]


def main(fast: bool = False):
    k = 4 if fast else 8
    reps = 3
    profiles = fleet_profiles(k)
    em = Emulator(plan_cache=PlanCache())

    cfg = FleetConfig.thread(max_workers=WORKERS)
    em.emulate_many(profiles, config=cfg)                   # warm in-process
    thread_fleet = None
    thread_s = float("inf")
    for _ in range(reps):
        f = em.emulate_many(profiles, config=cfg)
        if f.wall_s < thread_s:
            thread_s, thread_fleet = f.wall_s, f

    bundles = [bundle_profile(em, p) for p in profiles]
    t0 = time.perf_counter()
    fleet = ProcessFleet(WORKERS, WorkerSpec(emulator=em.spec()))
    try:
        fleet.warmup()
        spawn_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        cold_reports = fleet.run(bundles)
        cold_s = time.perf_counter() - t0

        warm_s = float("inf")
        warm_reports = cold_reports
        for _ in range(reps):
            t0 = time.perf_counter()
            r = fleet.run(bundles)
            dt = time.perf_counter() - t0
            if dt < warm_s:
                warm_s, warm_reports = dt, r
    finally:
        fleet.close()

    # -- remote scenario: same bundles over loopback TCP agents ------------
    remote = RemoteFleet(WorkerSpec(emulator=em.spec()),
                         listen="127.0.0.1:0", agents=WORKERS)
    procs = _spawn_agents(remote.bound_addr[1], WORKERS)
    try:
        t0 = time.perf_counter()
        remote.warmup(timeout=300.0)
        remote_join_s = time.perf_counter() - t0

        remote.run(bundles)                    # agents trace once (cold)
        remote_warm_s = float("inf")
        remote_reports = None
        for _ in range(reps):
            t0 = time.perf_counter()
            r = remote.run(bundles)
            dt = time.perf_counter() - t0
            if dt < remote_warm_s:
                remote_warm_s, remote_reports = dt, r
    finally:
        remote.close()
        for p in procs:
            try:
                p.wait(timeout=30.0)
            except subprocess.TimeoutExpired:
                p.kill()
    em.storage.cleanup()

    identical = all(
        pr.consumed == tr.consumed and pr.n_samples == tr.n_samples
        for pr, tr in zip(warm_reports, thread_fleet.reports))
    remote_identical = all(
        rr.consumed == tr.consumed and rr.n_samples == tr.n_samples
        for rr, tr in zip(remote_reports, thread_fleet.reports))
    rows = [{
        "k_profiles": k,
        "workers": WORKERS,
        "thread_wall_s": thread_s,
        "spawn_s": spawn_s,
        "process_cold_s": cold_s,
        "process_warm_s": warm_s,
        "warm_vs_thread": warm_s / thread_s if thread_s else 0.0,
        "cold_vs_warm": cold_s / warm_s if warm_s else 0.0,
        "remote_agents": WORKERS,
        "remote_join_s": remote_join_s,
        "remote_warm_s": remote_warm_s,
        "framing_overhead": remote_warm_s / warm_s if warm_s else 0.0,
        "worker_deaths": fleet.worker_deaths,
        "agent_deaths": remote.worker_deaths,
        "consumed_identical": identical,
        "remote_consumed_identical": remote_identical,
    }]
    _emit_fleet("executors", rows)
    assert identical, \
        "process-fleet totals must be bit-identical to in-process replay"
    # correctness only for the network hop — framing_overhead is reported,
    # not gated (container wall-clock swings ~2x run-to-run)
    assert remote_identical, \
        "remote-fleet totals must be bit-identical to in-process replay"
    # Loose guards only (2x run-to-run noise): warm process replay must be
    # in the same decade as the thread fleet — re-tracing per bundle would
    # be orders of magnitude off — and an absolute floor keeps tiny fast
    # runs from tripping on IPC constants.
    bound = max(5.0 * thread_s, 2.0)
    assert warm_s <= bound, \
        f"warm process fleet {warm_s:.3f}s vs bound {bound:.3f}s " \
        f"(thread fleet {thread_s:.3f}s) — are workers re-tracing per bundle?"
    return rows


# ---------------------------------------------------------------------------
# streamed production-day soak (ISSUE 6 acceptance)
# ---------------------------------------------------------------------------

# One soak sample = exactly one quantization iteration of each atom, so the
# emulated amounts are powers of two and every sum below stays integer-
# exact in float64 — the exactness the totals asserts lean on.
_SOAK_TILE = 64                  # 2 * 64^3  = 2^19 flops / iteration
_SOAK_BLOCK = 1 << 18            # 2 * 2^18  = 2^19 bytes / iteration
_SOAK_FPI = 2.0 * _SOAK_TILE ** 3
_SOAK_BPI = 2.0 * _SOAK_BLOCK


def _rss_kb() -> int:
    """Current resident set, not the ru_maxrss high-water mark — the soak
    needs growth *during* a run, and a monotone mark from warmup would
    mask it."""
    with open("/proc/self/statm") as f:
        pages = int(f.read().split()[1])
    return pages * (os.sysconf("SC_PAGE_SIZE") // 1024)


def _soak_profile(i: int, samples_per: int) -> SynapseProfile:
    # 7 distinct day shapes so the stream isn't one repeated profile;
    # amounts stay exact multiples of one iteration
    rv = ResourceVector(flops=_SOAK_FPI * (1 + i % 7), hbm_bytes=_SOAK_BPI)
    return SynapseProfile(
        command=f"soak:{i}",
        samples=[Sample(index=j, resources=rv) for j in range(samples_per)])


def _soak_source(n_profiles: int, samples_per: int, tracker=None):
    for i in range(n_profiles):
        if tracker is not None:
            tracker["peak"] = max(tracker["peak"], _rss_kb())
        yield _soak_profile(i, samples_per)


def _expected_totals(n_profiles: int, samples_per: int):
    flops = sum(samples_per * int(_SOAK_FPI) * (1 + i % 7)
                for i in range(n_profiles))
    return float(flops), float(n_profiles * samples_per * int(_SOAK_BPI))


def soak(fast: bool = False):
    """Replay a synthetic production day as a stream: profiles are pulled,
    compiled, and shipped at most ``window`` ahead of an elastic 1→3
    process fleet, with per-profile reports dropped after index-order
    folding (``collect="totals"``).  Asserts, exactly:

      * streamed totals == materialized fixed-fleet totals (bit-identical)
        == the analytic expectation — nothing lost or double-counted
        across backpressure, autoscaling, or completion reordering;
      * the fleet really scaled (≥1 scale-up, parked back at the floor);
      * coordinator peak-RSS growth is independent of profile count: the
        full run may not grow more than a 10x-smaller streamed run plus a
        fixed slack.
    """
    n_profiles = 2_000 if fast else 5_000
    samples_per = 50 if fast else 200    # 100k / 1M samples
    window = 8
    em = Emulator(compute_tile=_SOAK_TILE, mem_block=_SOAK_BLOCK)
    cfg = FleetConfig.process(max_workers=3, autoscale=True, min_workers=1,
                              window=window, timeout=3600.0)

    # -- calibration run at a tenth of the size: its RSS growth is the
    # "profile-count-independent" yardstick (and it warms jax/XLA, so the
    # big run's growth measures the pipeline, not first-touch allocations)
    small_n = max(n_profiles // 10, 50)
    base = _rss_kb()
    tracker = {"peak": base}
    em.emulate_many(_soak_source(small_n, samples_per, tracker),
                    config=cfg, collect="totals")
    small_growth = tracker["peak"] - base

    # -- the day itself, streamed ------------------------------------------
    base = _rss_kb()
    tracker = {"peak": base}
    t0 = time.perf_counter()
    streamed = em.emulate_many(
        _soak_source(n_profiles, samples_per, tracker),
        config=cfg, collect="totals")
    stream_wall = time.perf_counter() - t0
    big_growth = tracker["peak"] - base

    # -- the same profile set materialized on a fixed-size fleet -----------
    day = [_soak_profile(i, samples_per) for i in range(n_profiles)]
    t0 = time.perf_counter()
    fixed = em.emulate_many(day, config=FleetConfig.process(
        max_workers=3, window=window, timeout=3600.0), collect="totals")
    fixed_wall = time.perf_counter() - t0

    exp_flops, exp_hbm = _expected_totals(n_profiles, samples_per)
    rows = [{
        "n_profiles": n_profiles,
        "samples_per_profile": samples_per,
        "n_samples": streamed.n_samples,
        "window": window,
        "stream_wall_s": stream_wall,
        "samples_per_s": streamed.n_samples / stream_wall if stream_wall
        else 0.0,
        "materialized_wall_s": fixed_wall,
        "scale_ups": streamed.scaling.get("scale_ups", 0),
        "scale_downs": streamed.scaling.get("scale_downs", 0),
        "peak_workers": streamed.scaling.get("peak_workers", 0),
        "peak_window": streamed.scaling.get("peak_window", 0),
        "small_run_rss_growth_kb": small_growth,
        "rss_growth_kb": big_growth,
        "total_flops": streamed.totals.flops,
        "totals_bit_identical": streamed.totals == fixed.totals,
        "totals_exact": (streamed.totals.flops == exp_flops
                         and streamed.totals.hbm_bytes == exp_hbm),
    }]
    _emit_fleet("soak", rows)

    assert streamed.n_replayed == fixed.n_replayed == n_profiles
    assert streamed.n_samples == n_profiles * samples_per
    assert not streamed.reports, "collect='totals' must drop reports"
    assert streamed.totals == fixed.totals, \
        "streamed-vs-materialized totals must be bit-identical"
    assert streamed.totals.flops == exp_flops \
        and streamed.totals.hbm_bytes == exp_hbm, \
        f"soak totals drifted from the analytic expectation: " \
        f"{streamed.totals.flops} != {exp_flops}"
    assert streamed.scaling.get("scale_ups", 0) >= 1, \
        "the elastic fleet never scaled up under a backed-up queue"
    assert streamed.scaling.get("peak_window", 0) <= window
    # RSS independence: 10x the profiles may not cost more coordinator
    # memory than the small run did, beyond a fixed allocator-noise slack.
    slack_kb = 96 * 1024
    assert big_growth <= small_growth + slack_kb, \
        f"coordinator RSS grew with profile count: {big_growth}kB for " \
        f"{n_profiles} profiles vs {small_growth}kB for {small_n} " \
        f"(+{slack_kb}kB slack) — is the stream being materialized?"
    return rows


# ---------------------------------------------------------------------------
# chaos: recovery cost under a seeded fault schedule (ISSUE 7 acceptance)
# ---------------------------------------------------------------------------

def chaos(fast: bool = False):
    """Replay the same exact-amount profile set twice on a 2-worker process
    fleet — once clean, once under a seeded ``ChaosPolicy`` where every
    worker dies exactly once, on its 5th dispatch — and report what the
    faults cost: worker deaths, requeues, requeue latency, lost replay
    work, MTTR (death → replacement ready), heartbeat volume, and the
    wall-clock overhead of recovering.  The hard asserts are noise-free:
    fault-injected totals must be bit-identical to the clean run AND equal
    the analytic expectation, the scheduled deaths must actually happen,
    and every death must be measured (MTTR recorded, requeues counted).
    """
    from repro.fleet import ChaosPolicy

    n = 12 if fast else 24
    samples_per = 4
    em = Emulator(compute_tile=_SOAK_TILE, mem_block=_SOAK_BLOCK)
    profiles = [_soak_profile(i, samples_per) for i in range(n)]

    t0 = time.perf_counter()
    clean = em.emulate_many(
        profiles, config=FleetConfig.process(max_workers=WORKERS),
        collect="totals")
    clean_wall = time.perf_counter() - t0

    pol = ChaosPolicy(seed=7, kill_every=5, max_faults=1)
    # liveness 2s => 0.5s heartbeats: short enough that pings actually
    # flow within this run's few seconds, three orders of magnitude above
    # the ms-scale bundle replays so nothing is falsely reaped
    cfg = FleetConfig.process(max_workers=WORKERS, chaos=pol,
                              max_respawns=8, liveness_timeout=2.0)
    t0 = time.perf_counter()
    hurt = em.emulate_many(profiles, config=cfg, collect="totals")
    chaos_wall = time.perf_counter() - t0
    rec = hurt.recovery

    exp_flops, exp_hbm = _expected_totals(n, samples_per)
    rows = [{
        "n_profiles": n,
        "workers": WORKERS,
        "kill_every": 5,
        "clean_wall_s": clean_wall,
        "chaos_wall_s": chaos_wall,
        "recovery_overhead": chaos_wall / clean_wall if clean_wall else 0.0,
        "worker_deaths": rec.get("worker_deaths", 0),
        "requeued": rec.get("requeued", 0),
        "requeue_latency_s": rec.get("requeue_latency_s", 0.0),
        "lost_replay_s": rec.get("lost_replay_s", 0.0),
        "mttr_s": rec.get("mttr_s"),
        "heartbeats": rec.get("heartbeats", 0),
        "respawns": hurt.cache_stats.get("respawns", 0),
        "totals_bit_identical": hurt.totals == clean.totals,
        "totals_exact": (hurt.totals.flops == exp_flops
                         and hurt.totals.hbm_bytes == exp_hbm),
    }]
    _emit_fleet("chaos", rows)

    assert hurt.n_replayed == clean.n_replayed == n
    assert hurt.totals == clean.totals, \
        "fault-injected totals must be bit-identical to the clean run"
    assert hurt.totals.flops == exp_flops \
        and hurt.totals.hbm_bytes == exp_hbm, \
        "chaos totals drifted from the analytic expectation"
    assert rec.get("worker_deaths", 0) >= 1, \
        "the seeded kill schedule never fired — chaos is not reaching workers"
    assert rec.get("requeued", 0) >= rec["worker_deaths"] or \
        rec.get("requeued", 0) >= 1, \
        "deaths happened but their in-flight bundles were not requeued"
    assert rec.get("mttr_s") is not None and rec["mttr_s"] > 0.0, \
        "worker deaths were repaired but MTTR was not measured"
    assert rec.get("heartbeats", 0) >= 1, \
        "liveness_timeout was armed but no heartbeat ever arrived"
    assert rec.get("skipped") == [], "nothing should be skipped under raise"
    return rows


# ---------------------------------------------------------------------------
# dag: dependency-structured replay (ISSUE 10 acceptance scenario)
# ---------------------------------------------------------------------------

def dag(fast: bool = False):
    """Replay a fork-join diamond (``dag_diamond_workload``) on the process
    fleet's frontier scheduler and report what the structure costs and
    buys: per-run makespan vs serialized sum-of-work, the critical path
    and its parallelism ratio, and frontier bookkeeping volume (dep_wait/
    dep_release events).  Hard asserts are noise-free: the index-order
    fold must be bit-identical to the workload's analytic totals, and the
    diamond's makespan must beat the serialized sum by a real margin
    (the branches genuinely overlap — with ``fanout`` parallel branches
    and 2 workers, sum-of-work / makespan must clear 2x minus slack).
    """
    from repro.obs.recorder import Event
    from repro.scenarios.dag import dag_diamond_workload

    fanout = 4 if fast else 8
    samples_per = 2 if fast else 4
    # ~1000 compute iterations per sample: tens of ms of genuine replay
    # per branch, so scheduling/IPC overhead can't masquerade as the
    # branch window.  Straggler does 2x: visible on the critical path,
    # but not so dominant that the overlap ratio collapses toward 1.
    d = dag_diamond_workload(fanout=fanout, work_flops=1000 * _SOAK_FPI,
                             work_hbm=_SOAK_BPI, samples_per=samples_per,
                             straggler_index=0, straggler_factor=2.0)
    em = Emulator(compute_tile=_SOAK_TILE, mem_block=_SOAK_BLOCK)
    t0 = time.perf_counter()
    out = em.emulate_many(d, config=FleetConfig.process(max_workers=WORKERS))
    wall = time.perf_counter() - t0
    cp = out.dag
    events = [Event.from_dict(x) for x in out.obs["events"]]
    # branch-level overlap, from the merged timeline: the fork's whole
    # point is that branches 1..fanout replay concurrently.  (The cp
    # parallelism ratio is reported but not asserted on — the source
    # node is always the pool's first dispatch and its replay_s eats the
    # worker cold-start, which serializes the aggregate ratio toward 1.)
    disp, done = {}, {}
    for e in events:
        idx = e.get("idx")
        if e.kind == "dispatch" and idx is not None:
            disp.setdefault(idx, e.t)
        elif e.kind == "done" and idx is not None:
            done[idx] = e.t
    branch_ids = range(1, fanout + 1)
    branch_work = sum(done[i] - disp[i] for i in branch_ids)
    branch_span = max(done[i] for i in branch_ids) \
        - min(disp[i] for i in branch_ids)
    overlap = branch_work / branch_span if branch_span > 0 else 0.0
    rows = [{
        "fanout": fanout,
        "workers": WORKERS,
        "n_nodes": len(d),
        "n_edges": d.n_edges,
        "wall_s": wall,
        "makespan_s": cp.get("makespan_s", 0.0),
        "critical_path_s": cp.get("critical_path_s", 0.0),
        "sum_work_s": cp.get("sum_work_s", 0.0),
        "parallelism": cp.get("parallelism", 0.0),
        "critical_nodes": cp.get("critical_nodes", []),
        "branch_overlap": overlap,
        "dep_waits": sum(e.kind == "dep_wait" for e in events),
        "dep_releases": sum(e.kind == "dep_release" for e in events),
        "totals_exact": out.totals == d.totals,
    }]
    _emit_fleet("dag", rows)

    assert out.n_replayed == len(d)
    assert out.totals == d.totals, \
        "frontier-scheduled fold drifted from the workload's analytic totals"
    assert cp and cp["n_nodes"] == len(d) and cp["n_edges"] == d.n_edges
    assert rows[0]["dep_releases"] >= 1
    # the structural win: branch replay intervals overlap across the two
    # workers, so their summed work exceeds the window they span.  Ideal
    # is 2x with 2 workers; demand 1.3x to keep the guard loose against
    # container wall-clock swing while still catching a frontier that
    # accidentally serializes independent branches.
    assert overlap >= 1.3, \
        f"no overlap: {branch_work:.3f}s of branch work spanned " \
        f"{branch_span:.3f}s — the frontier is serializing the fork"
    return rows


if __name__ == "__main__":
    main()
    soak()
    chaos()
    dag()
