"""Paper Experiment 3 (Fig. 7) — emulation fidelity on the profiling host.

Profile the application (runtime watchers for TTC truth + static watcher for
resource amounts), emulate it with the atoms on the same host, compare TTC.
Also sweeps emulation granularity (paper Fig. 2 discussion): 1 sample vs
per-scan samples — finer sampling re-serializes concurrent consumption.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, tiny_train_workload
from repro.core import (Emulator, RuntimeProfiler, calibrate,
                        profile_compiled)
from repro.core.metrics import ResourceVector, Sample, SynapseProfile


def main(fast: bool = False):
    calib = calibrate()
    rows = []
    sizes = [4] if fast else [2, 4, 8, 16]
    for steps in sizes:
        run_fn, meta = tiny_train_workload(steps=steps)
        # --- application truth (median of 3)
        walls = []
        for _ in range(3):
            t0 = time.perf_counter()
            run_fn()
            walls.append(time.perf_counter() - t0)
        app_s = sorted(walls)[1]

        # --- static profile of one step, scaled by step count
        from benchmarks.bench_profiling_consistency import (_abstract_batch,
                                                            _abstract_state)
        compiled = meta["step"].lower(_abstract_state(meta["model"]),
                                      _abstract_batch(meta)).compile()
        for granularity in (["scan"] if fast else ["step", "scan"]):
            prof = profile_compiled(compiled, command="bench-lm",
                                    tags={"steps": str(steps)},
                                    granularity=granularity)
            samples = []
            for i in range(steps):
                for s in prof.samples:
                    samples.append(Sample(index=len(samples),
                                          resources=s.resources,
                                          label=s.label))
            full = SynapseProfile(command=prof.command, tags=prof.tags,
                                  samples=samples)
            total_flops = full.totals.flops
            # the paper's CPU-efficiency metric: achieved / atom peak
            eff = (total_flops / app_s) / calib.flops_per_s
            for mode, emu in (
                    ("default", Emulator(calib)),
                    ("eff_matched", Emulator(calib, efficiency=eff))):
                rep = emu.emulate(full)
                rows.append({
                    "app_steps": steps,
                    "granularity": granularity,
                    "mode": mode,
                    "n_samples": len(samples),
                    "app_s": app_s,
                    "emulated_s": rep.ttc_s,
                    "diff_pct": 100.0 * (rep.ttc_s - app_s) / app_s,
                    "app_efficiency": eff,
                })
    emit("emulation_same_host", rows)
    return rows


if __name__ == "__main__":
    main()
