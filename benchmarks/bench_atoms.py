"""Atom fidelity microbench: a planned resource amount is consumed at the
calibrated rate (the paper's premise that atoms emulate at known efficiency).
Also sweeps the memory atom's block size (paper §IV-E.3 block-size knob)."""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import ComputeAtom, MemoryAtom, StorageAtom, calibrate


def main(fast: bool = False):
    calib = calibrate()
    rows = []
    # compute atom: planned flops vs wall time * calibrated rate
    atom = ComputeAtom(calib, tile=256)
    for gflops in ([2.0] if fast else [1.0, 4.0, 16.0]):
        thunk = atom.plan(gflops * 1e9)
        thunk()                                      # warm
        t0 = time.perf_counter(); done = thunk(); dt = time.perf_counter() - t0
        rows.append({"atom": "compute", "planned_gflops": gflops,
                     "consumed_gflops": done / 1e9, "wall_s": dt,
                     "rate_gflops": done / dt / 1e9,
                     "calib_gflops": calib.flops_per_s / 1e9})
    # memory atom block-size sweep
    for block in ([1 << 22] if fast else [1 << 18, 1 << 22, 1 << 25]):
        matom = MemoryAtom(calib, block_bytes=block)
        thunk = matom.plan(512e6)
        thunk()
        t0 = time.perf_counter(); done = thunk(); dt = time.perf_counter() - t0
        rows.append({"atom": "memory", "block_bytes": block,
                     "consumed_mb": done / 1e6, "wall_s": dt,
                     "rate_gbps": done / dt / 1e9,
                     "calib_gbps": calib.stream_bytes_per_s / 1e9})
    # storage atom
    satom = StorageAtom(calib, block_bytes=1 << 20)
    thunk = satom.plan_write(32e6)
    t0 = time.perf_counter(); done = thunk(); dt = time.perf_counter() - t0
    rows.append({"atom": "storage_write", "consumed_mb": done / 1e6,
                 "wall_s": dt, "rate_mbps": done / dt / 1e6,
                 "calib_mbps": calib.storage_write_bps / 1e6})
    emit("atoms", rows)
    return rows


if __name__ == "__main__":
    main()
