"""Shared benchmark plumbing: tiny-workload builders + CSV emit helpers."""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List

import jax
import numpy as np

RESULT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "results")


def emit(name: str, rows: List[Dict], keys=None):
    """Print ``name,us_per_call,derived`` style CSV + persist JSON."""
    os.makedirs(RESULT_DIR, exist_ok=True)
    with open(os.path.join(RESULT_DIR, name + ".json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)
    if rows:
        if keys is None:
            keys = []
            for r in rows:
                for k in r:
                    if k not in keys:
                        keys.append(k)
        print(f"# {name}")
        print(",".join(keys))
        for r in rows:
            print(",".join(_fmt(r.get(k)) for k in keys))
    sys.stdout.flush()


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def tiny_train_workload(num_layers=3, d_model=128, vocab=256, seq=128,
                        batch=8, steps=1):
    """A small real LM train function: the 'application' Synapse profiles."""
    from repro.configs.base import ModelConfig
    from repro.configs.run import RunConfig
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models.model_zoo import build_model
    from repro.optim.adamw import OptConfig
    from repro.train.step import init_train_state, make_train_step

    cfg = ModelConfig(name=f"bench-lm-{num_layers}x{d_model}", family="dense",
                      num_layers=num_layers, d_model=d_model, num_heads=4,
                      num_kv_heads=2, head_dim=max(d_model // 4, 8),
                      d_ff=d_model * 2, vocab_size=vocab, tie_embeddings=True)
    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    remat="none", loss_chunk=0)
    model = build_model(cfg, run)
    data = SyntheticLM(DataConfig(vocab_size=vocab, seq_len=seq,
                                  global_batch=batch))
    step = jax.jit(make_train_step(model, OptConfig()), donate_argnums=0)
    state = init_train_state(model, jax.random.key(0))
    batches = [data.batch_at(i) for i in range(steps)]

    # warm up compile outside the profiled region (we profile steady state)
    state, _ = step(state, batches[0])
    jax.block_until_ready(state["params"])
    holder = {"state": state}

    def run_fn():
        s = holder["state"]
        for b in batches:
            s, _ = step(s, b)
        jax.block_until_ready(s["params"])
        holder["state"] = s

    meta = {"cfg": cfg, "model": model, "step": step, "steps": steps,
            "tokens_per_step": seq * batch}
    return run_fn, meta
