"""Paper Experiment 1 (Fig. 4) — profiling self-interference & overhead.

TTC of the application (a real LM train loop) alone vs under the Synapse
runtime watchers, across application sizes and sampling rates.  Requirement
P.1/P.2: overhead ~ 0 independent of size and rate.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, tiny_train_workload
from repro.core import RuntimeProfiler


def main(fast: bool = False):
    rows = []
    sizes = [1, 4] if fast else [1, 2, 4, 8]
    rates = [10] if fast else [2, 10, 50]
    for steps in sizes:
        run_fn, meta = tiny_train_workload(steps=steps)
        # plain run (median of 3)
        plain = []
        for _ in range(3):
            t0 = time.perf_counter()
            run_fn()
            plain.append(time.perf_counter() - t0)
        plain_s = sorted(plain)[1]
        for rate in rates:
            prof = RuntimeProfiler(sample_rate=rate).profile_callable(
                run_fn, command="bench-lm", tags={"steps": str(steps)})
            rows.append({
                "app_steps": steps,
                "sample_rate": rate,
                "plain_s": plain_s,
                "profiled_s": prof.meta["wall_s"],
                "overhead_pct": 100.0 * (prof.meta["wall_s"] - plain_s)
                / max(plain_s, 1e-9),
                "n_samples": len(prof.samples),
            })
    emit("profiling_overhead", rows)
    return rows


if __name__ == "__main__":
    main()
