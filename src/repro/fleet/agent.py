"""Fleet host agent: lend this machine's workers to a remote coordinator.

    python -m repro.fleet.agent --connect COORD_HOST:PORT --workers 4
    python -m repro.fleet.agent --listen 0.0.0.0:9000     --workers 4

The agent is the host-side half of ``repro.fleet.transport``: it opens
one framed TCP connection to a coordinator (dialing out with
``--connect``, or with ``--listen`` waiting for the coordinator to dial
in — print-and-flushes its bound address first, so launchers can scrape
the port when asked for ``:0``).  After the handshake it receives the
fleet's ``WorkerSpec``, spawns ``--workers`` local worker processes from
it (a plain ``ProcessFleet`` — same spawn path, same XLA device-count
environment dance, same per-worker mesh build), reports ready with its
slot count, and then proxies: coordinator bundles are dispatched to idle
local workers, worker reports stream back tagged with the coordinator's
dispatch epoch.

Local worker death is *not* hidden: the agent respawns within its budget
like any ``ProcessFleet``, but the orphaned bundle goes back to the
coordinator as a ``retry`` so the fleet-wide attempt/poison accounting
stays in one place.  If the agent runs out of live workers it returns
every queued bundle and exits; the coordinator reaps the closed
connection like a dead process worker.  The agent exits when the
coordinator says ``stop`` or its connection drops — it never outlives
the fleet it joined.

When the shipped ``WorkerSpec`` sets ``heartbeat_s``, the agent sends
``("ping",)`` frames from a daemon thread at that cadence — the
coordinator's liveness watermark.  (A *hung local worker* behind a live,
heartbeating agent is invisible to coordinator liveness; the agent's
ProcessFleet recovery is what covers that case.)  When the spec carries
a ``ChaosPolicy``, the agent derives the deterministic ``"agent"``-scope
actor and consults it per proxied result: it may mangle the Nth reply
frame (``corrupt_frame_nth`` — the coordinator reaps the corrupt stream)
or vanish instead of replying (``drop_agent_after``).  Its local workers
derive their own ``worker:<n>`` actors from the same policy, so a remote
fleet replays the same per-worker fault ordinals a process fleet would.
"""
from __future__ import annotations

import argparse
import os
import socket
import sys
import threading
import traceback
from collections import deque
from multiprocessing import connection as mp_conn
from typing import List, Optional

from repro.fleet.transport import framing
from repro.fleet.transport.remote import _IO_TIMEOUT, parse_addr
from repro.obs import clock as obs_clock
from repro.obs.recorder import FlightRecorder


def log(msg: str) -> None:
    print(f"[fleet-agent pid={os.getpid()}] {msg}", flush=True)


class _ChaosDrop(Exception):
    """Injected agent loss: close the coordinator connection abruptly."""


def serve(sock: socket.socket, n_workers: int) -> int:
    """Run the agent protocol on an established coordinator connection."""
    sock.settimeout(_IO_TIMEOUT)
    framing.handshake(sock)
    msg = framing.recv_frame(sock)
    if not (isinstance(msg, tuple) and msg and msg[0] == "spec"):
        raise framing.FramingError(
            f"expected a ('spec', WorkerSpec) frame first, got {msg!r}")
    spec = msg[1]
    from repro.fleet.executor import PeerGone, ProcessFleet

    chaos = getattr(spec, "chaos", None)
    actor = chaos.actor("agent") if chaos is not None else None
    send_lock = threading.Lock()   # heartbeat thread vs serve loop: one
    hb_stop = threading.Event()    # frame on the wire at a time
    # the agent's own flight recorder: local worker frames are absorbed
    # (rebased through the per-worker clock sync) and re-shipped to the
    # coordinator on each proxied result, so remote worker events reach
    # the merged timeline through two offset estimations, not one guess
    recorder = FlightRecorder("agent", capacity=2048)

    def absorb_local(peer, frame) -> None:
        if frame is None:
            return
        t_recv = obs_clock.now()
        if frame.echo_t is not None:
            peer.sync.observe(frame.echo_t, frame.sent_at, t_recv)
        recorder.absorb(frame,
                        peer.sync.to_local if peer.sync.synced else None)

    def send(msg, *, _mangle=None) -> None:
        with send_lock:
            framing.send_frame(sock, msg, _mangle=_mangle)

    def send_result(msg) -> None:
        """ok/err results pass through the chaos actor on their way out."""
        if actor is not None:
            act = actor.on_reply()
            if act == "drop":
                log(f"chaos: dropping connection instead of result "
                    f"#{actor.replies}")
                raise _ChaosDrop()
            if act == "corrupt":
                log(f"chaos: corrupting result frame #{actor.replies}")
                send(msg, _mangle=chaos.corrupt_bytes)
                return
        send(msg)

    log(f"spawning {n_workers} local worker(s)"
        + (f" with mesh {list(spec.mesh.shape)}" if spec.mesh else ""))
    try:
        fleet = ProcessFleet(n_workers, spec)
        infos = fleet.warmup()
    except BaseException:
        send(("err", None, None, traceback.format_exc()))
        raise
    send(("ready", {
        "workers": len(fleet.pids), "host": socket.gethostname(),
        "agent_pid": os.getpid(), "worker_infos": infos}))
    log(f"ready: {len(fleet.pids)} worker(s) warm, serving")
    heartbeat_s = getattr(spec, "heartbeat_s", 0.0) or 0.0
    if heartbeat_s > 0:
        def _beat():
            # first beat fires immediately (same contract as the process
            # worker's sender): even a short-lived agent registers a pulse
            while True:
                try:
                    send(("ping",))
                except (framing.TransportError, OSError):
                    return
                if hb_stop.wait(heartbeat_s):
                    return
        threading.Thread(target=_beat, daemon=True,
                         name="agent-heartbeat").start()

    pending = deque()          # (epoch, idx, bundle) awaiting a free worker
    stopping = False
    served = 0

    def reap_local(peer):
        """A local worker died: hand its orphaned bundles back (the
        coordinator owns the attempt budget, so a bundle that kills
        workers is *its* poison call, not something to retry here), reap
        and maybe respawn, and re-advertise the slot count — if the
        respawn budget is spent the pool shrank for good, and the
        coordinator must stop filling slots this host no longer has."""
        for e, idx in list(peer.tasks):
            recorder.record("requeue", idx=idx,
                            reason="agent-local worker died")
            send(("retry", e, idx, "agent-local worker died"))
        peer.tasks.clear()
        fleet._reap(peer, deque())
        if fleet._peers or fleet._pending_refill():
            send(("ready", {"workers": max(1, len(fleet._peers))}))

    try:
        while True:
            in_flight = any(p.tasks for p in fleet._peers)
            if stopping and not in_flight and not pending:
                break
            fleet._tick(deque())   # service due backoff respawns
            # -- collect: coordinator frames + local worker replies -------
            waitables = ([] if stopping else [sock]) + \
                [p.waitable for p in fleet._peers]
            for obj in mp_conn.wait(waitables, timeout=0.5):
                if obj is sock:
                    msg = framing.recv_frame(sock)
                    if msg[0] == "stop":
                        stopping = True
                    elif msg[0] == "run":
                        epoch, idx, bundle = msg[1], msg[2], msg[3]
                        if len(msg) > 4:     # coordinator clock echo
                            recorder.last_echo = msg[4]
                        pending.append((epoch, idx, bundle))
                    continue
                peer = next(p for p in fleet._peers if p.waitable is obj)
                try:
                    reply = peer.recv()
                except PeerGone:
                    reap_local(peer)
                    continue
                kind = reply[0]
                if kind == "ready":
                    peer.ready = True          # a respawned replacement
                elif kind == "obs":
                    absorb_local(peer, reply[1])
                elif kind == "ok":
                    e, idx, rep = reply[1], reply[2], reply[3]
                    absorb_local(peer,
                                 reply[4] if len(reply) > 4 else None)
                    peer.tasks.discard((e, idx))
                    served += 1
                    send_result(("ok", e, idx, rep, recorder.drain()))
                elif kind == "err":
                    e, idx, tb = reply[1], reply[2], reply[3]
                    if idx is None:            # replacement failed init
                        reap_local(peer)
                    else:
                        absorb_local(peer,
                                     reply[4] if len(reply) > 4 else None)
                        peer.tasks.discard((e, idx))
                        send_result(("err", e, idx, tb, recorder.drain()))
                # "ping" from a local worker: nothing to proxy — the
                # agent's own heartbeat is the coordinator-facing signal
            # -- dispatch queued bundles to free local slots --------------
            for peer in list(fleet._peers):
                while pending and peer.free_slots > 0:
                    if not peer.alive:
                        reap_local(peer)
                        break
                    epoch, idx, bundle = pending.popleft()
                    try:
                        peer.dispatch(epoch, idx, bundle)
                    except PeerGone:
                        pending.appendleft((epoch, idx, bundle))
                        reap_local(peer)
                        break
                    recorder.record("dispatch", idx=idx, peer=peer.scope)
            if not fleet._peers and not fleet._pending_refill():
                for epoch, idx, _ in pending:
                    send(("retry", epoch, idx,
                          "agent has no live workers"))
                pending.clear()
                log("no live workers left and respawn budget spent — "
                    "leaving the fleet")
                return 1
    except framing.TransportClosed:
        log("coordinator connection closed — shutting down")
    except _ChaosDrop:
        try:
            sock.close()
        except OSError:
            pass
        log("chaos: agent dropped out of the fleet")
        return 3
    finally:
        hb_stop.set()
        try:
            # ship whatever the recorder still holds (events since the
            # last proxied result) before leaving the fleet
            send(("obs", recorder.drain()))
        except Exception:  # noqa: BLE001 — exit path, connection may be gone
            pass
        fleet.close()
    log(f"served {served} bundle(s), exiting")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.fleet.agent",
        description="Join this machine's emulator workers to a remote "
                    "fleet coordinator (see repro.fleet.transport)")
    how = ap.add_mutually_exclusive_group(required=True)
    how.add_argument("--connect", metavar="HOST:PORT",
                     help="dial a coordinator listening at HOST:PORT")
    how.add_argument("--listen", metavar="HOST:PORT",
                     help="listen at HOST:PORT (port 0 for ephemeral; the "
                          "bound address is printed) and wait for one "
                          "coordinator to dial in")
    ap.add_argument("--workers", type=int, default=1, metavar="N",
                    help="local worker processes to offer (default 1)")
    ap.add_argument("--connect-timeout", type=float, default=30.0,
                    metavar="S", help="dial timeout (default 30s)")
    args = ap.parse_args(argv)
    if args.workers < 1:
        ap.error("--workers must be >= 1")

    if args.connect:
        addr = parse_addr(args.connect)
        log(f"connecting to coordinator {addr[0]}:{addr[1]}")
        sock = socket.create_connection(addr, timeout=args.connect_timeout)
    else:
        host, port = parse_addr(args.listen)
        srv = socket.create_server((host, port), backlog=1)
        bound = srv.getsockname()
        # scrapeable by launchers (and tests) that asked for port 0
        log(f"listening on {bound[0]}:{bound[1]}")
        sock, peer = srv.accept()
        srv.close()
        log(f"coordinator connected from {peer[0]}:{peer[1]}")
    try:
        return serve(sock, args.workers)
    except framing.TransportError as e:
        log(f"transport failed: {e}")
        return 1
    finally:
        try:
            sock.close()
        except OSError:
            pass


if __name__ == "__main__":
    sys.exit(main())
