"""Critical-path accounting for dependency-structured fleet runs.

A DAG run's product is its *tail*, not its totals: the makespan is gated
by the longest dependency chain of replay work, and aggregate metrics
hide exactly that (Cornebize & Legrand, arXiv 2102.07674).
``critical_path`` turns the ``BundleTiming`` stamps ``FleetBase.stream``
already records into the numbers that expose it:

* ``critical_path_s`` — the longest path of replay work through the DAG
  (the lower bound no amount of extra workers can beat);
* ``makespan_s`` — observed wall span, first enqueue to last done;
* ``sum_work_s`` — total replay work (the serial lower bound);
* ``parallelism`` — ``sum_work_s / makespan_s``, the achieved overlap;
* ``slack_s`` — per node: how much that node's replay could grow before
  it joins the critical path (0.0 for nodes already on it);
* ``critical_nodes`` — one longest path, root to leaf (ties broken
  toward the smallest index, so the result is deterministic).

All figures derive from ``BundleTiming.replay_s`` (dispatch → done of
the *last* attempt), so a chaos requeue charges queue time — never
replay time — and the critical path stays an honest work metric under
faults.  Skipped bundles carry zero replay work and simply pass their
parents' finish time through.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Sequence, Tuple


def validate_parents(idx: int, parents: Sequence[int],
                     command: str = "") -> Tuple[int, ...]:
    """The frontier scheduler's edge contract: parents must reference
    *earlier* stream indices.  Indices are assigned in arrival order, so
    a forward or self reference is the only way to express a cycle (or a
    parent that can never arrive) — both fail loudly here, up front,
    instead of deadlocking the stream."""
    parents = tuple(parents)
    bad = sorted({p for p in parents
                  if not isinstance(p, int) or isinstance(p, bool)
                  or p < 0 or p >= idx})
    if bad:
        what = f" ({command!r})" if command else ""
        raise ValueError(
            f"bundle {idx}{what} depends on {bad}: parents must reference "
            "earlier bundles in the stream (indices are assigned in "
            "arrival order, so forward or self references are "
            "unsatisfiable — a cycle or a parent that never arrives)")
    if len(set(parents)) != len(parents):
        raise ValueError(f"bundle {idx} repeats a parent: {parents}")
    return parents


def critical_path(parents: Mapping[int, Sequence[int]],
                  timings: Mapping[int, "BundleTiming"]) -> Dict:
    """Longest-path analysis of one DAG run from its per-bundle stamps.

    ``parents`` maps node index -> parent indices (topological by the
    stream contract: every parent index is smaller).  ``timings`` maps
    node index -> ``BundleTiming``.  Nodes present in ``parents`` but
    missing from ``timings`` (a raised run's unfinished tail) are
    ignored; edges into missing nodes are dropped.  Returns ``{}`` when
    there is nothing to account."""
    nodes = sorted(timings)
    if not nodes:
        return {}
    idxset = set(nodes)
    par = {i: tuple(p for p in parents.get(i, ()) if p in idxset)
           for i in nodes}
    kids: Dict[int, List[int]] = {i: [] for i in nodes}
    for i in nodes:
        for p in par[i]:
            kids[p].append(i)
    work = {i: max(0.0, float(timings[i].replay_s)) for i in nodes}
    # forward pass (ascending == topological): longest work path ENDING
    # at each node, inclusive
    finish: Dict[int, float] = {}
    for i in nodes:
        finish[i] = work[i] + max((finish[p] for p in par[i]), default=0.0)
    # backward pass: longest work path STARTING at each node, inclusive
    tail: Dict[int, float] = {}
    for i in reversed(nodes):
        tail[i] = work[i] + max((tail[c] for c in kids[i]), default=0.0)
    cp = max(finish.values())
    # slack: how far the longest path THROUGH this node sits under the
    # critical path (floored at 0 against float noise)
    slack = {i: max(0.0, cp - (finish[i] + tail[i] - work[i]))
             for i in nodes}
    # walk one critical path, root to leaf, smallest index on ties
    leaf = min(i for i in nodes if finish[i] == cp)
    path = [leaf]
    cur = leaf
    while par[cur]:
        best = max(finish[p] for p in par[cur])
        cur = min(p for p in par[cur] if finish[p] == best)
        path.append(cur)
    path.reverse()
    makespan = (max(t.done for t in timings.values())
                - min(t.enqueued for t in timings.values()))
    sum_work = sum(work.values())
    return {
        "critical_path_s": cp,
        "makespan_s": max(0.0, makespan),
        "sum_work_s": sum_work,
        "parallelism": (sum_work / makespan) if makespan > 0 else 0.0,
        "critical_nodes": path,
        "slack_s": slack,
        "n_nodes": len(nodes),
        "n_edges": sum(len(par[i]) for i in nodes),
    }
