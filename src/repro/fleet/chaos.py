"""Deterministic fault injection for fleet runs: the chaos engine.

Recovery machinery that is only ever exercised by ad-hoc SIGKILLs in
tests is anecdote, not property.  ``ChaosPolicy`` turns "recovery works"
into a *seeded, replayable* experiment: one picklable policy travels in
the ``WorkerSpec`` to every worker process and host agent, each actor
derives its own deterministic RNG stream from ``(seed, scope)``, and the
same policy + seed therefore reproduces the same fault sequence on the
thread, process, and remote paths — which is what lets tests and the
``bench_fleet.chaos`` benchmark assert exact totals and exact death
counts *under* injected faults.

Fault kinds (all opt-in, all schedulable):

  worker-side (``scope="worker:<spawn ordinal>"``, consulted once per
  dispatched bundle, ordinals are per-actor and 1-based):

    * ``kill_every`` / ``kill_prob`` — die (``os._exit``) *before*
      replying, so the coordinator requeues the in-flight bundle and the
      attempt/poison budget is exercised;
    * ``kill_on_init``   — die before building the emulator: the
      crash-loop breaker's test vector (a worker spec that can never
      come up);
    * ``hang_nth``       — go silent for ``hang_s``: stop heartbeating
      and stop serving, with the pipe still open.  This is the failure
      mode plain I/O-error liveness cannot see — only the heartbeat
      watermark reaps it;
    * ``fail_nth``       — reply ``("err", ...)``: a poison-ish bundle
      failure, the ``on_failure="skip"`` test vector;
    * ``delay_every`` / ``delay_s`` — straggler injection: sleep
      (jittered by the scoped RNG) before replying, the speculative
      re-dispatch test vector.

  agent-side (``scope="agent"``, consulted once per proxied reply):

    * ``drop_agent_after``   — close the coordinator connection instead
      of sending the Nth reply (abrupt agent loss mid-result);
    * ``corrupt_frame_nth``  — flip bytes in the Nth outbound frame
      payload (the ``framing`` corrupt-stream reap path, end to end).

``max_faults`` caps how many faults one actor fires across all kinds,
so a policy like ``kill_every=3, max_faults=1`` means "every worker
dies exactly once, at its third bundle" — deterministic death counts
with a bounded respawn bill.

Determinism contract: an actor's decision at ordinal ``n`` is a pure
function of ``(policy, scope, n)`` — the RNG is seeded from a stable
hash (not Python's salted ``hash``), and every probabilistic knob draws
exactly once per ordinal whether or not it fires, so enabling one fault
kind never shifts another kind's stream.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from random import Random
from typing import List, Optional, Tuple, Union

#: worker-side actions an actor may return from ``on_dispatch``
Action = Union[str, Tuple[str, float]]


def derive_seed(seed: int, scope: str) -> int:
    """Stable per-scope RNG seed: must agree across processes and runs
    (``hash()`` is salted per interpreter, so sha256 it is).  Public
    because it is the repo-wide seeding discipline — ``ChaosPolicy``
    scopes its fault streams with it and ``repro.service.arrivals``
    scopes its arrival streams with it, so a chaos-under-load run is
    reproducible end to end from two integers."""
    digest = hashlib.sha256(f"{seed}:{scope}".encode()).digest()
    return int.from_bytes(digest[:8], "big")


#: backwards-compatible alias (pre-service name)
_derive_seed = derive_seed


@dataclass(frozen=True)
class ChaosPolicy:
    """Picklable, seeded schedule of faults for one fleet run.

    Ship it in ``WorkerSpec.chaos`` (or ``FleetConfig.process(...,
    chaos=...)``) and every worker/agent spawned from that spec injects
    its scheduled faults; pass the same policy again and the same faults
    fire at the same per-actor ordinals.
    """

    seed: int = 0
    # -- worker-side schedules (per-dispatch ordinals, 1-based) -------------
    kill_every: Optional[int] = None     # die before replying to every Nth
    kill_prob: float = 0.0               # seeded per-dispatch death chance
    kill_on_init: bool = False           # die before the emulator builds
    hang_nth: Optional[int] = None       # go silent (no reply/heartbeat)...
    hang_s: float = 3600.0               # ...for this long, on the Nth
    fail_nth: Optional[int] = None       # reply ("err", ...) on the Nth
    delay_every: Optional[int] = None    # straggle on every Nth...
    delay_s: float = 0.0                 # ...by ~this (jittered 0.5x-1.5x)
    # -- agent-side schedules (per-reply ordinals, 1-based) -----------------
    drop_agent_after: Optional[int] = None   # vanish instead of Nth reply
    corrupt_frame_nth: Optional[int] = None  # mangle the Nth reply frame
    # -- budget --------------------------------------------------------------
    max_faults: Optional[int] = None     # per-actor cap across all kinds

    def __post_init__(self):
        for name in ("kill_every", "hang_nth", "fail_nth", "delay_every",
                     "drop_agent_after", "corrupt_frame_nth"):
            v = getattr(self, name)
            if v is not None and v < 1:
                raise ValueError(f"ChaosPolicy.{name} must be >= 1 (it is a "
                                 f"1-based ordinal/interval), got {v}")
        if not 0.0 <= self.kill_prob <= 1.0:
            raise ValueError(f"kill_prob must be in [0, 1], "
                             f"got {self.kill_prob}")
        if self.delay_s < 0 or self.hang_s < 0:
            raise ValueError("delay_s/hang_s must be >= 0")
        if self.max_faults is not None and self.max_faults < 0:
            raise ValueError("max_faults must be >= 0")

    @property
    def active(self) -> bool:
        """Does this policy schedule any fault at all?"""
        return any((self.kill_every, self.kill_prob, self.kill_on_init,
                    self.hang_nth, self.fail_nth, self.delay_every,
                    self.drop_agent_after, self.corrupt_frame_nth))

    def actor(self, scope: str) -> "ChaosActor":
        """One deterministic fault stream for one actor (worker/agent)."""
        return ChaosActor(self, scope)

    def rng(self, scope: str) -> Random:
        """A chaos-safe seeded RNG for non-actor consumers (e.g. the
        coordinator's respawn-backoff jitter) — same seed, same stream."""
        return Random(_derive_seed(self.seed, scope))

    def corrupt_bytes(self, payload: bytes) -> bytes:
        """Deterministically mangle a frame payload (XOR a byte run in
        the middle) — length is preserved so the corruption surfaces as
        an unpicklable frame, not a truncated one."""
        if not payload:
            return payload
        buf = bytearray(payload)
        start = len(buf) // 3
        for i in range(start, min(start + 16, len(buf))):
            buf[i] ^= 0xA5
        return bytes(buf)


class ChaosActor:
    """Per-actor fault stream: counts its own dispatch/reply ordinals and
    answers "what fault fires now?" deterministically.

    ``trace`` records ``(ordinal, action)`` for every fault fired — the
    reproducibility tests compare traces across identically-seeded
    actors.
    """

    def __init__(self, policy: ChaosPolicy, scope: str):
        self.policy = policy
        self.scope = scope
        self.rng = policy.rng(scope)
        self.dispatches = 0
        self.replies = 0
        self.faults = 0
        self.trace: List[Tuple[int, Action]] = []

    def _fire(self, ordinal: int, action: Action) -> Optional[Action]:
        if self.policy.max_faults is not None \
                and self.faults >= self.policy.max_faults:
            return None
        self.faults += 1
        self.trace.append((ordinal, action))
        return action

    def on_dispatch(self) -> Optional[Action]:
        """Consulted once per bundle a worker is asked to replay.

        Returns ``None`` (serve normally), ``"kill"``, ``"fail"``,
        ``("hang", seconds)``, or ``("delay", seconds)``.  Every
        probabilistic knob draws from the RNG on every call so the
        stream stays ordinal-aligned regardless of which faults fire.
        """
        p = self.policy
        self.dispatches += 1
        n = self.dispatches
        kill_draw = self.rng.random()            # always drawn: alignment
        delay_jitter = 0.5 + self.rng.random()   # always drawn: alignment
        if p.fail_nth is not None and n == p.fail_nth:
            return self._fire(n, "fail")
        if p.hang_nth is not None and n == p.hang_nth:
            return self._fire(n, ("hang", p.hang_s))
        if p.kill_every is not None and n % p.kill_every == 0:
            return self._fire(n, "kill")
        if p.kill_prob and kill_draw < p.kill_prob:
            return self._fire(n, "kill")
        if p.delay_every is not None and n % p.delay_every == 0:
            return self._fire(n, ("delay", p.delay_s * delay_jitter))
        return None

    def on_reply(self) -> Optional[str]:
        """Consulted once per reply an agent proxies back to the
        coordinator.  Returns ``None``, ``"corrupt"`` (mangle this
        frame), or ``"drop"`` (close the connection instead of sending).
        """
        p = self.policy
        self.replies += 1
        n = self.replies
        if p.drop_agent_after is not None and n > p.drop_agent_after:
            return self._fire(n, "drop")
        if p.corrupt_frame_nth is not None and n == p.corrupt_frame_nth:
            return self._fire(n, "corrupt")
        return None
