"""Serialization layer: detach compiled schedules into shippable bundles.

A ``ScheduleBundle`` is everything one fleet worker needs to replay one
profile, with every live object stripped out: the detached schedule payload
(plain ints/floats/dicts + one int32 table per segment, from
``CompiledSchedule.detach()``), the replay scales, and identification
metadata.  The emulator configuration travels separately — once per worker,
not once per bundle — as a ``WorkerSpec``: the parent's ``EmulatorSpec``
(calibration + atom configs) plus an optional ``MeshSpec`` describing the
device mesh each worker must build for itself.  Meshes hold live device
handles and jitted collectives, so they can never cross the process
boundary; their *specs* can, which is exactly what lets ``CollectiveAtom``
participate in process-fleet mode.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.emulator import Emulator, EmulatorSpec
from repro.core.metrics import ResourceVector, SynapseProfile
from repro.core.schedule import CompiledSchedule, rehydrate_schedule


@dataclass(frozen=True)
class MeshSpec:
    """Picklable description of the mesh a worker builds from its own
    devices (``jax.make_mesh``).  The parent sets
    ``--xla_force_host_platform_device_count=device_count`` in the spawned
    worker's environment so a CPU worker has enough devices to satisfy it.
    """
    shape: Tuple[int, ...] = (2,)
    axes: Tuple[str, ...] = ("model",)

    def __post_init__(self):
        if len(self.shape) != len(self.axes) or not self.shape:
            raise ValueError(f"mesh shape {self.shape} and axes {self.axes} "
                             "must be equal-length and non-empty")

    @property
    def device_count(self) -> int:
        return int(math.prod(self.shape))

    def build(self):
        """Construct the live mesh — call only inside the owning process."""
        from repro.launch.mesh import make_mesh
        return make_mesh(self.shape, self.axes)


@dataclass(frozen=True)
class WorkerSpec:
    """Per-worker configuration shipped once at spawn: how to build the
    worker's emulator (and mesh), and whether to pre-trace the common fused
    programs before accepting bundles."""
    emulator: EmulatorSpec
    mesh: Optional[MeshSpec] = None
    warmup: bool = True


@dataclass
class ScheduleBundle:
    """One profile's compiled schedule, detached for shipping.

    ``payload`` is the plain-data form from ``CompiledSchedule.detach()``;
    ``rehydrate()`` restores a ``CompiledSchedule`` whose tables and
    resource vectors are bit-identical to the originals, so a worker's
    ``Emulator.replay`` reports exactly the totals an in-process replay
    would.  The scales are baked in at bundle time because flop/byte
    amounts were already quantized into the tables with them applied —
    the barrier steps replayed per-sample on the worker need the same
    values.
    """
    command: str
    payload: Dict
    flops_scale: float = 1.0
    storage_scale: float = 1.0
    mem_scale: float = 1.0
    verify: bool = True
    n_profile_samples: int = 0
    planned: Optional[ResourceVector] = None
    tags: Dict[str, str] = field(default_factory=dict)

    def rehydrate(self) -> CompiledSchedule:
        return rehydrate_schedule(self.payload)


def bundle_profile(emulator: Emulator, profile: SynapseProfile, *,
                   keep_collectives: Optional[bool] = None,
                   flops_scale: float = 1.0, storage_scale: float = 1.0,
                   mem_scale: float = 1.0,
                   verify: bool = True) -> ScheduleBundle:
    """Compile one profile on ``emulator`` and detach it into a bundle.

    ``keep_collectives=True`` lowers wire-byte runs to executable barrier
    steps even though *this* process has no mesh — pass it when the bundle
    is headed for workers that do (i.e. the fleet has a ``MeshSpec``).
    """
    sched = emulator.compile(profile, flops_scale=flops_scale,
                             mem_scale=mem_scale,
                             keep_collectives=keep_collectives)
    return ScheduleBundle(command=profile.command, payload=sched.detach(),
                          flops_scale=flops_scale,
                          storage_scale=storage_scale, mem_scale=mem_scale,
                          verify=verify,
                          n_profile_samples=len(profile.samples),
                          planned=profile.totals, tags=dict(profile.tags))
