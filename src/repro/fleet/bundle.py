"""Serialization layer: detach compiled schedules into shippable bundles.

A ``ScheduleBundle`` is everything one fleet worker needs to replay one
profile, with every live object stripped out: the detached schedule payload
(plain ints/floats/dicts + one int32 table per segment, from
``CompiledSchedule.detach()``), the replay scales, and identification
metadata.  The emulator configuration travels separately — once per worker,
not once per bundle — as a ``WorkerSpec``: the parent's ``EmulatorSpec``
(calibration + atom configs) plus an optional ``MeshSpec`` describing the
device mesh each worker must build for itself.  Meshes hold live device
handles and jitted collectives, so they can never cross the process
boundary; their *specs* can, which is exactly what lets ``CollectiveAtom``
participate in process-fleet mode.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.emulator import Emulator, EmulatorSpec
from repro.core.metrics import ResourceVector, SynapseProfile
from repro.core.schedule import CompiledSchedule, rehydrate_schedule
from repro.fleet.chaos import ChaosPolicy


@dataclass(frozen=True)
class MeshSpec:
    """Picklable description of the mesh a worker builds from its own
    devices (``jax.make_mesh``).  The parent sets
    ``--xla_force_host_platform_device_count=device_count`` in the spawned
    worker's environment so a CPU worker has enough devices to satisfy it.
    """
    shape: Tuple[int, ...] = (2,)
    axes: Tuple[str, ...] = ("model",)

    def __post_init__(self):
        if len(self.shape) != len(self.axes) or not self.shape:
            raise ValueError(f"mesh shape {self.shape} and axes {self.axes} "
                             "must be equal-length and non-empty")

    @property
    def device_count(self) -> int:
        return int(math.prod(self.shape))

    def build(self):
        """Construct the live mesh — call only inside the owning process."""
        from repro.launch.mesh import make_mesh
        return make_mesh(self.shape, self.axes)


@dataclass(frozen=True)
class WorkerSpec:
    """Per-worker configuration shipped once at spawn: how to build the
    worker's emulator (and mesh), whether to pre-trace the common fused
    programs before accepting bundles, how often to heartbeat the
    coordinator (``heartbeat_s > 0`` starts a ``("ping",)`` sender thread
    in every worker and agent — the liveness watermark's signal), and an
    optional seeded ``ChaosPolicy`` whose faults every worker/agent
    spawned from this spec injects deterministically."""
    emulator: EmulatorSpec
    mesh: Optional[MeshSpec] = None
    warmup: bool = True
    heartbeat_s: float = 0.0
    chaos: Optional[ChaosPolicy] = None


@dataclass
class ScheduleBundle:
    """One profile's compiled schedule, detached for shipping.

    ``payload`` is the plain-data form from ``CompiledSchedule.detach()``;
    ``rehydrate()`` restores a ``CompiledSchedule`` whose tables and
    resource vectors are bit-identical to the originals, so a worker's
    ``Emulator.replay`` reports exactly the totals an in-process replay
    would.  The scales are baked in at bundle time because flop/byte
    amounts were already quantized into the tables with them applied —
    the barrier steps replayed per-sample on the worker need the same
    values.

    ``parents`` is the bundle's dependency edges: the stream indices of
    the bundles whose results must land before this one may dispatch
    (``FleetBase.stream``'s frontier scheduler enforces it).  The field
    is versioned the same way the v1/v2 detach payloads are: it defaults
    to ``()``, and bundles pickled before it existed deserialize without
    the attribute, so every consumer reads it through
    ``bundle_parents()`` — old bundles rehydrate *edge-free* and replay
    exactly as before.
    """
    command: str
    payload: Dict
    flops_scale: float = 1.0
    storage_scale: float = 1.0
    mem_scale: float = 1.0
    verify: bool = True
    n_profile_samples: int = 0
    planned: Optional[ResourceVector] = None
    tags: Dict[str, str] = field(default_factory=dict)
    parents: Tuple[int, ...] = ()

    def rehydrate(self) -> CompiledSchedule:
        return rehydrate_schedule(self.payload)


def bundle_parents(bundle) -> Tuple[int, ...]:
    """A bundle's dependency edges, tolerant of pre-``parents`` pickles
    (dataclass unpickling restores ``__dict__`` without calling
    ``__init__``, so old bundles simply lack the attribute): missing or
    empty means edge-free, exactly the pre-DAG behavior."""
    return tuple(getattr(bundle, "parents", ()) or ())


def bundle_profile(emulator: Emulator, profile: SynapseProfile, *,
                   keep_collectives: Optional[bool] = None,
                   mesh_spec: Optional[MeshSpec] = None,
                   flops_scale: float = 1.0, storage_scale: float = 1.0,
                   mem_scale: float = 1.0,
                   verify: bool = True,
                   parents: Tuple[int, ...] = ()) -> ScheduleBundle:
    """Compile one profile on ``emulator`` and detach it into a bundle.

    ``mesh_spec`` (the fleet's ``MeshSpec``) quantizes wire-byte runs into
    mesh-bound fused segments for the mesh each worker will build — this
    process needs no mesh, and the workers replay collectives inside their
    segment scans instead of per-sample barrier steps.
    ``keep_collectives=True`` is the barrier-step fallback for parents
    that know the workers own *a* mesh but not its shape.
    """
    if mesh_spec is None and keep_collectives is None \
            and emulator.collective is not None:
        # a mesh-owning parent compiling for workers of unknown mesh must
        # not bake ITS OWN mesh's quantization into the bundle — meshless
        # workers would refuse the mesh-bound segments.  Barrier steps are
        # the portable lowering (workers with a mesh execute them
        # per-sample, workers without one skip the wire and keep the
        # consumed accounting intact).
        keep_collectives = True
    sched = emulator.compile(profile, flops_scale=flops_scale,
                             mem_scale=mem_scale,
                             keep_collectives=keep_collectives,
                             mesh_spec=mesh_spec)
    return ScheduleBundle(command=profile.command, payload=sched.detach(),
                          flops_scale=flops_scale,
                          storage_scale=storage_scale, mem_scale=mem_scale,
                          verify=verify,
                          n_profile_samples=len(profile.samples),
                          planned=profile.totals, tags=dict(profile.tags),
                          parents=tuple(parents))
