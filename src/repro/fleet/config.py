"""One picklable knob surface for every fleet executor.

``FleetConfig`` collapses the executor sprawl that used to be duplicated
across ``Emulator.emulate_many``, ``repro.scenarios.run_fleet`` and the
``repro.scenarios`` CLI — ``executor=``, ``max_workers=``, ``mesh_spec=``,
``hosts=``, ``listen=``, ``agents=``, ``timeout=`` — plus the streaming
knobs those surfaces never had: a compile-ahead ``window`` (how many
bundles the coordinator may hold pulled-but-unfinished, the backpressure
bound on the iterator-of-bundles pipeline) and ``autoscale`` /
``min_workers`` (grow the pool on queue depth, park it back at the floor
when the stream drains).

Everything validates at *construction*: a mesh on the thread executor,
hosts without the remote executor, ``agents=`` without a listener — all
fail loudly before any profile is generated, compiled, or shipped.  The
``thread()`` / ``process()`` / ``remote()`` constructors only expose the
knobs their executor understands, so misuse is an argument error rather
than a runtime surprise.  Configs are frozen and picklable, so one object
can parameterize a CLI invocation, travel in a job description, or be
compared in tests.

Migration (every surface accepts ``config=``)::

    # before (still works, folds into a FleetConfig + DeprecationWarning)
    em.emulate_many(profiles, executor="process", max_workers=8,
                    mesh_spec=MeshSpec(shape=(2,)), timeout=120.0)

    # after
    cfg = FleetConfig.process(max_workers=8, mesh=MeshSpec(shape=(2,)),
                              timeout=120.0)
    em.emulate_many(profiles, config=cfg)
    run_fleet(jobs, profiles=store.stream(tags), config=cfg)
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, fields
from typing import Dict, Optional, Sequence, Tuple

from repro.core.emulator import UNSET, VALID_EXECUTORS, _Unset
from repro.fleet.bundle import MeshSpec
from repro.fleet.chaos import ChaosPolicy

#: legacy kwarg names the surfaces fold into a FleetConfig
LEGACY_FLEET_KWARGS = ("executor", "max_workers", "mesh_spec", "hosts",
                       "listen", "agents", "timeout")


@dataclass(frozen=True)
class FleetConfig:
    """Where and how a fleet replays: executor, pool shape, stream shape.

    ``window`` bounds the compile-ahead pipeline: the coordinator holds at
    most ``window`` bundles pulled from the profile source but not yet
    finished, blocking the source (and therefore compilation) when workers
    fall behind.  ``None`` picks ``2 × worker slots`` at run time, which
    keeps every slot fed while the queue-depth signal stays live.

    ``autoscale`` makes the pool elastic between ``min_workers`` (default
    1) and ``max_workers``: the scheduler spawns/invites capacity while
    queued bundles outnumber free slots and retires idle workers (or
    releases idle remote agents) once the stream drains.  Scale events and
    high-water marks surface in ``FleetReport.scaling``.

    The robustness knobs shape how the scheduler survives faults:
    ``max_attempts`` is the per-bundle dispatch budget before a bundle is
    declared poison; ``liveness_timeout`` arms heartbeat-based hung-peer
    detection (process/remote only — a peer silent that long is destroyed
    and its work requeued); ``speculate`` re-dispatches a straggling
    bundle once its age exceeds ``speculate × median`` completion time
    (first result wins); ``on_failure`` picks between failing the run on
    a poison bundle (``"raise"``) and completing degraded (``"skip"``,
    holes listed in ``FleetReport.recovery["skipped"]``); ``chaos``
    injects a seeded, reproducible fault schedule (process/remote only);
    ``max_respawns`` caps worker respawns (process only).  Fault accounting
    lands in ``FleetReport.recovery``.

    ``dag=True`` declares the run dependency-structured (bundles carry
    ``parents`` edges, or the profile source is a ``WorkloadDag``) and
    validates the combination up front: dependency edges need the
    frontier scheduler in ``FleetBase.stream``, so the thread executor
    is rejected at construction, and ``check_collect`` rejects
    ``collect="totals"`` — totals mode drops the per-node timing that
    critical-path accounting folds (and its index-order fold contract is
    what makes DAG totals bit-identical to the linear stream's).
    Passing a ``WorkloadDag`` to ``emulate_many`` applies the same
    checks even with ``dag=False`` — the flag exists so a config built
    far from the profile source still fails loudly at construction.
    """

    executor: str = "thread"
    max_workers: int = 4
    min_workers: Optional[int] = None        # autoscale floor (default 1)
    autoscale: bool = False
    window: Optional[int] = None             # compile-ahead bundles
    mesh_spec: Optional[MeshSpec] = None
    hosts: Optional[Tuple[str, ...]] = None
    listen: Optional[str] = None
    agents: Optional[int] = None
    timeout: float = 600.0
    max_attempts: int = 3                    # per-bundle dispatch budget
    liveness_timeout: Optional[float] = None  # hung-peer reap threshold
    on_failure: str = "raise"                # or "skip": complete degraded
    speculate: Optional[float] = None        # straggler re-dispatch factor
    chaos: Optional[ChaosPolicy] = None      # seeded fault injection
    max_respawns: Optional[int] = None       # process-pool respawn budget
    dag: bool = False                        # dependency-structured run

    def __post_init__(self):
        if self.executor not in VALID_EXECUTORS:
            raise ValueError(
                f"unknown executor {self.executor!r}; valid choices: "
                + ", ".join(repr(e) for e in VALID_EXECUTORS))
        if self.hosts is not None and not isinstance(self.hosts, tuple):
            object.__setattr__(self, "hosts", tuple(self.hosts))
        if self.max_workers < 1:
            raise ValueError("max_workers must be >= 1")
        if self.timeout < 0:
            raise ValueError("timeout must be >= 0")
        if self.window is not None and self.window < 1:
            raise ValueError("window must be >= 1 (it bounds compile-ahead "
                             "bundles in flight)")
        if self.executor != "remote" and (self.hosts is not None
                                          or self.listen is not None
                                          or self.agents is not None):
            raise ValueError("hosts/listen/agents configure "
                             "executor='remote' agents; they have no "
                             f"meaning for executor={self.executor!r}")
        if self.executor == "remote" and not self.hosts \
                and self.listen is None:
            raise ValueError("executor='remote' needs agents to schedule "
                             "on: pass hosts=[...] to dial listening agents "
                             "and/or listen='host:port' (+ agents=N) to "
                             "accept dial-in agents")
        if self.agents is not None and self.listen is None:
            raise ValueError("agents=N counts dial-in joins and needs "
                             "listen='host:port'")
        if self.mesh_spec is not None and self.executor == "thread":
            raise ValueError("mesh_spec requires executor='process' or "
                             "'remote': thread workers share one jax "
                             "client and cannot own per-worker meshes, so "
                             "the collective legs it asks for would be "
                             "silently dropped")
        if self.autoscale and self.executor == "thread":
            raise ValueError("autoscale requires executor='process' or "
                             "'remote': only those pools can spawn/retire "
                             "workers (threads are a fixed shared pool)")
        if self.min_workers is not None:
            if not self.autoscale:
                raise ValueError("min_workers is the autoscale floor; pass "
                                 "autoscale=True with it")
            if not 1 <= self.min_workers <= self.max_workers:
                raise ValueError(
                    f"min_workers={self.min_workers} must satisfy "
                    f"1 <= min_workers <= max_workers={self.max_workers}")
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1 (it is the "
                             "per-bundle dispatch budget)")
        if self.on_failure not in ("raise", "skip"):
            raise ValueError(f"on_failure must be 'raise' or 'skip', got "
                             f"{self.on_failure!r}")
        if self.liveness_timeout is not None and self.liveness_timeout <= 0:
            raise ValueError("liveness_timeout must be > 0 seconds")
        if self.speculate is not None and self.speculate < 1.0:
            raise ValueError("speculate must be >= 1.0 (it multiplies the "
                             "median bundle completion time)")
        if self.executor == "thread":
            for knob, val in (("liveness_timeout", self.liveness_timeout),
                              ("speculate", self.speculate),
                              ("chaos", self.chaos)):
                if val is not None:
                    raise ValueError(
                        f"{knob} requires executor='process' or 'remote': "
                        "thread workers share one process, so there is no "
                        "peer to heartbeat, kill, or re-dispatch against")
        if self.max_respawns is not None:
            if self.executor != "process":
                raise ValueError("max_respawns caps the local process "
                                 "pool's respawn budget; remote agents own "
                                 "their own (executor='process' only)")
            if self.max_respawns < 0:
                raise ValueError("max_respawns must be >= 0")
        if self.chaos is not None and not isinstance(self.chaos,
                                                     ChaosPolicy):
            raise TypeError(f"chaos must be a ChaosPolicy, got "
                            f"{type(self.chaos).__name__}")
        if self.dag and self.executor == "thread":
            raise ValueError(
                "dag=True requires executor='process' or 'remote': "
                "dependency edges are honored by the frontier scheduler "
                "in FleetBase.stream — the in-process thread pool has no "
                "dispatch gating, so edges would be silently ignored")

    def check_collect(self, collect: str, *, dag: Optional[bool] = None
                      ) -> None:
        """Validate a ``collect`` mode against this config (and, when the
        caller knows it, whether the profile source is actually a DAG).
        ``collect="totals"`` on a dependency-structured run is rejected:
        totals mode drops the per-node ``BundleTiming`` stamps that
        critical-path accounting needs."""
        effective = self.dag if dag is None else (dag or self.dag)
        if effective and collect == "totals":
            raise ValueError(
                "collect='totals' is incompatible with a "
                "dependency-structured run: totals mode drops the "
                "per-node BundleTiming stamps critical-path accounting "
                "needs — use collect='reports'")

    @property
    def scale_min(self) -> int:
        """Effective autoscale floor."""
        return self.min_workers if self.min_workers is not None else 1

    # -- fleet construction (the service's standing pool uses these) --------

    def worker_spec(self, emulator_spec) -> "WorkerSpec":
        """Build the ``WorkerSpec`` this config implies for one worker:
        the emulator's picklable recipe (``Emulator.spec()``), the
        per-worker mesh, the chaos policy, and a heartbeat cadence derived
        from ``liveness_timeout`` (4 beats per window, floored at 100ms)
        exactly like ``run_process_fleet`` does."""
        from repro.fleet.bundle import WorkerSpec
        heartbeat = 0.0
        if self.liveness_timeout is not None:
            heartbeat = max(0.1, self.liveness_timeout / 4.0)
        return WorkerSpec(emulator=emulator_spec, mesh=self.mesh_spec,
                          heartbeat_s=heartbeat, chaos=self.chaos)

    def build(self, spec: "WorkerSpec"):
        """Construct the live pool (``ProcessFleet`` / ``RemoteFleet``)
        this config describes.  Only those two executors *have* a standing
        pool to build — the thread path replays in-process and raises
        here.  The caller owns the returned fleet's lifecycle."""
        if self.executor == "process":
            from repro.fleet.executor import ProcessFleet
            return ProcessFleet(self.max_workers, spec,
                                autoscale=self.autoscale,
                                min_workers=self.min_workers,
                                max_respawns=self.max_respawns)
        if self.executor == "remote":
            from repro.fleet.transport.remote import RemoteFleet
            return RemoteFleet(spec, hosts=self.hosts, listen=self.listen,
                               agents=self.agents,
                               autoscale=self.autoscale,
                               min_workers=self.min_workers)
        raise ValueError(
            "only executor='process' or 'remote' can build a standing "
            f"worker pool; executor={self.executor!r} replays in-process")

    # -- constructors (each exposes only its executor's knobs) --------------

    @classmethod
    def thread(cls, max_workers: int = 4, *, window: Optional[int] = None,
               max_attempts: int = 3, on_failure: str = "raise",
               timeout: float = 600.0) -> "FleetConfig":
        """In-process thread pool: shared plan cache, no meshes, no
        elasticity — but the profile source is still pulled lazily with a
        ``window``-bounded submission queue."""
        return cls(executor="thread", max_workers=max_workers,
                   window=window, max_attempts=max_attempts,
                   on_failure=on_failure, timeout=timeout)

    @classmethod
    def process(cls, max_workers: int = 4, *,
                min_workers: Optional[int] = None, autoscale: bool = False,
                mesh: Optional[MeshSpec] = None,
                window: Optional[int] = None,
                max_attempts: int = 3,
                liveness_timeout: Optional[float] = None,
                on_failure: str = "raise",
                speculate: Optional[float] = None,
                chaos: Optional[ChaosPolicy] = None,
                max_respawns: Optional[int] = None,
                dag: bool = False,
                timeout: float = 600.0) -> "FleetConfig":
        """Spawn-based local worker pool (``repro.fleet.ProcessFleet``)."""
        return cls(executor="process", max_workers=max_workers,
                   min_workers=min_workers, autoscale=autoscale,
                   mesh_spec=mesh, window=window,
                   max_attempts=max_attempts,
                   liveness_timeout=liveness_timeout, on_failure=on_failure,
                   speculate=speculate, chaos=chaos,
                   max_respawns=max_respawns, dag=dag, timeout=timeout)

    @classmethod
    def remote(cls, hosts: Optional[Sequence[str]] = None, *,
               listen: Optional[str] = None, agents: Optional[int] = None,
               mesh: Optional[MeshSpec] = None, autoscale: bool = False,
               min_workers: Optional[int] = None,
               window: Optional[int] = None,
               max_attempts: int = 3,
               liveness_timeout: Optional[float] = None,
               on_failure: str = "raise",
               speculate: Optional[float] = None,
               chaos: Optional[ChaosPolicy] = None,
               dag: bool = False,
               timeout: float = 600.0) -> "FleetConfig":
        """TCP host agents (``repro.fleet.RemoteFleet``): dial ``hosts``
        and/or ``listen`` for dial-in agents.  With ``autoscale`` the open
        listener keeps inviting late joiners mid-run and idle agents are
        released once the stream drains (``min_workers`` agents are kept)."""
        return cls(executor="remote",
                   hosts=tuple(hosts) if hosts else None, listen=listen,
                   agents=agents, mesh_spec=mesh, autoscale=autoscale,
                   min_workers=min_workers, window=window,
                   max_attempts=max_attempts,
                   liveness_timeout=liveness_timeout, on_failure=on_failure,
                   speculate=speculate, chaos=chaos, dag=dag,
                   timeout=timeout)

    # -- legacy folding ------------------------------------------------------

    @classmethod
    def fold(cls, config: Optional["FleetConfig"], given: Dict,
             *, caller: str) -> "FleetConfig":
        """Resolve one call's fleet configuration.

        ``given`` holds only the legacy kwargs the caller explicitly
        passed.  ``config=`` and legacy kwargs are mutually exclusive;
        legacy kwargs keep working but fold into a ``FleetConfig`` under a
        ``DeprecationWarning``.  No config and no legacy kwargs means the
        defaults (thread pool of 4).
        """
        given = {k: v for k, v in given.items()
                 if v is not UNSET and not isinstance(v, _Unset)}
        unknown = set(given) - {f.name for f in fields(cls)}
        if unknown:
            raise TypeError(f"{caller}: unknown fleet kwarg(s) "
                            f"{sorted(unknown)}")
        if config is not None:
            if given:
                raise ValueError(
                    f"{caller} got both config= and legacy fleet kwarg(s) "
                    f"{sorted(given)}; pass one surface, not both")
            if not isinstance(config, cls):
                raise TypeError(f"{caller}: config must be a FleetConfig, "
                                f"got {type(config).__name__}")
            return config
        if not given:
            return cls()
        warnings.warn(
            f"{caller}: fleet kwarg(s) {sorted(given)} are deprecated; "
            "pass config=repro.fleet.FleetConfig(...) (or its .thread()/"
            ".process()/.remote() constructors) instead",
            DeprecationWarning, stacklevel=3)
        return cls(**given)
