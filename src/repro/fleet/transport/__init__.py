"""Socket transport for multi-host fleets.

``framing`` is the wire layer (magic/version handshake, length-prefixed
pickle frames, loud typed failures); ``remote`` is the coordinator side
(``RemoteFleet`` — the transport-agnostic fleet scheduler over framed TCP
peers); ``repro.fleet.agent`` is the host side (``python -m
repro.fleet.agent`` joins a machine's worker processes to a coordinator).
"""
from repro.fleet.transport.framing import (MAGIC, VERSION,  # noqa: F401
                                           FramingError, TransportClosed,
                                           TransportError, VersionMismatch)
from repro.fleet.transport.remote import (AgentPeer,  # noqa: F401
                                          RemoteFleet, parse_addr,
                                          run_remote_fleet)
