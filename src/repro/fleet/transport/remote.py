"""Multi-host fleet: schedule bundles over TCP to host agents.

``RemoteFleet`` is the network instantiation of the transport-agnostic
scheduler in ``repro.fleet.executor``: each peer is one framed TCP
connection (see ``framing``) to a host agent
(``python -m repro.fleet.agent``) that fronts N worker processes on its
machine.  The coordinator ships the ``WorkerSpec`` once per agent at
join time, then streams ``ScheduleBundle``s into the agent's free worker
slots and collects ``EmulationReport``s — the same attempt-budget,
poison-bundle, and worker-death semantics as ``ProcessFleet``, because
it *is* the same scheduler: a dead TCP peer is reaped like a dead
process, and its in-flight bundles requeue onto surviving agents.

Two join topologies, freely mixable:

  * **dial** — agents already listening (``agent --listen``), the
    coordinator connects out: ``RemoteFleet(spec, hosts=["h1:9000",
    "h2:9000"])``.
  * **accept** — the coordinator listens and agents dial in
    (``agent --connect host:port``): ``RemoteFleet(spec,
    listen="0.0.0.0:9000", agents=2)``.  The listener stays open during
    runs, so late agents join the pool mid-run — a reaped agent's work
    can drain onto a machine that wasn't there when the run started.

Wire messages (pickled frames; every run/reply carries the dispatch
epoch so a straggler reply from an aborted run can never be mistaken
for a live one):

  coordinator -> agent:  ("spec", WorkerSpec)
                         ("run", epoch, idx, ScheduleBundle[, t_sent])
                         ("stop",)
  agent -> coordinator:  ("ready", info)
                         ("ok", epoch, idx, EmulationReport[, ObsFrame])
                         ("retry", epoch, idx, reason)   requeue: an
                              agent-local worker died with this in flight
                         ("err", epoch, idx, traceback[, ObsFrame])
                              idx=None: the agent failed to initialize
                         ("obs", ObsFrame)  final buffer, shipped on stop

The optional trailing fields are the flight-recorder piggyback
(``repro.obs``): a dispatch carries the coordinator's monotonic stamp,
and results ship the agent's drained event buffer (its own events plus
its local workers', already rebased to the agent clock) with that stamp
echoed — the coordinator folds the echo into a per-agent clock-offset
estimate and merges the events onto the run timeline.  Both arities are
accepted on both ends.
"""
from __future__ import annotations

import socket
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.emulator import Emulator, FleetReport, ReportFold
from repro.fleet.bundle import WorkerSpec, bundle_profile
from repro.fleet.dag import critical_path
from repro.fleet.executor import FleetBase, Peer, PeerGone
from repro.fleet.transport import framing
from repro.obs import clock as obs_clock

_IO_TIMEOUT = 60.0         # per-chunk socket deadline: a wedged peer is
                           # a dead peer, not a hung coordinator
_HANDSHAKE_TIMEOUT = 10.0  # dial: we initiated, give the agent room
# Accepts happen inline in the scheduler loop, so a stray TCP client that
# connects and says nothing stalls dispatch for the whole handshake
# window — keep it short: a real agent writes its 8-byte hello
# immediately after connecting.
_ACCEPT_HANDSHAKE_TIMEOUT = 2.0


def parse_addr(text: str) -> Tuple[str, int]:
    """``"host:port"`` (or bare ``"port"``) -> (host, port)."""
    host, _, port = str(text).rpartition(":")
    try:
        return host or "127.0.0.1", int(port)
    except ValueError:
        raise ValueError(f"bad address {text!r}: expected HOST:PORT") from None


class AgentPeer(Peer):
    """One connected host agent; capacity = its advertised worker count."""

    def __init__(self, sock: socket.socket, addr: Tuple[str, int]):
        super().__init__()
        self.sock = sock
        self.addr = addr
        self.capacity = 1          # grows when the ready info arrives
        self.scope = f"agent:{addr[0]}:{addr[1]}"
        self._named = False        # upgraded to the hostname on ready

    @property
    def waitable(self):
        return self.sock

    def dispatch(self, epoch, idx, bundle):
        try:
            framing.send_frame(self.sock,
                               ("run", epoch, idx, bundle,
                                obs_clock.now()))
        except framing.TransportError as e:
            raise PeerGone(str(e)) from e
        self.tasks.add((epoch, idx))

    def recv(self):
        try:
            msg = framing.recv_frame(self.sock)
        except framing.TransportError as e:
            # a corrupt stream (FramingError) is as unusable as a closed
            # one — either way this peer is done
            raise PeerGone(str(e)) from e
        kind = msg[0]
        if kind == "ping":
            return ("ping",)
        if kind == "ready":
            info = msg[1]
            self.capacity = max(1, int(info.get("workers", 1)))
            if not self._named and isinstance(info, dict) \
                    and info.get("host"):
                self.scope = f"agent:{info['host']}"
                self._named = True
            return ("ready", info)
        if kind in ("ok", "retry", "err", "obs"):
            return msg
        return ("err", None, None, f"unknown agent message {kind!r}")

    def stop(self):
        try:
            framing.send_frame(self.sock, ("stop",))
        except framing.TransportError:
            pass

    def drain_obs(self, timeout: float = 0.5):
        """Best-effort read of the final ``("obs", frame)`` a stopped
        agent ships on its way out; returns the frame or None."""
        try:
            self.sock.settimeout(timeout)
            while True:
                msg = framing.recv_frame(self.sock)
                if msg and msg[0] == "obs":
                    return msg[1]
                if msg and msg[0] not in ("ping",):
                    return None     # a late result: too late to use
        except (framing.TransportError, OSError):
            return None
        finally:
            try:
                self.sock.settimeout(_IO_TIMEOUT)
            except OSError:
                pass

    def close(self):
        try:
            self.sock.close()
        except OSError:
            pass

    def describe(self) -> str:
        return f"agent {self.addr[0]}:{self.addr[1]}"


class RemoteFleet(FleetBase):
    """A fleet of host agents reachable over TCP.

    Warm state like ``ProcessFleet``: agents join once (spawning and
    warming their local workers), then many ``run()``/``stream()`` calls
    reuse their traced programs.  ``worker_deaths`` counts reaped
    *agents*; ``n_workers`` is the fleet-wide worker-slot total.

    With ``autoscale=True`` the pool is elastic: the open listener keeps
    *inviting* capacity mid-run (a late joiner admitted after initial
    assembly counts as a scale-up), and once a stream's source drains,
    idle agents beyond the ``min_workers`` floor are *released* — sent the
    polite ``stop`` frame, so their worker pools exit instead of idling on
    another machine.
    """

    def __init__(self, spec: WorkerSpec, *,
                 hosts: Optional[Sequence[str]] = None,
                 listen: Optional[str] = None,
                 agents: Optional[int] = None,
                 connect_timeout: float = 30.0,
                 autoscale: bool = False,
                 min_workers: Optional[int] = None):
        super().__init__()
        if not hosts and listen is None:
            raise ValueError("RemoteFleet needs agents to schedule on: pass "
                             "hosts=[...] to dial listening agents and/or "
                             "listen='host:port' (+ agents=N) to accept "
                             "dial-in agents")
        if agents is not None and listen is None:
            raise ValueError("agents=N counts dial-in joins and needs "
                             "listen='host:port'")
        if min_workers is not None and not autoscale:
            raise ValueError("min_workers is the autoscale floor; pass "
                             "autoscale=True with it")
        self.spec = spec
        self._autoscale = autoscale
        self._scale_min = max(1, min_workers or 1)
        self._listener: Optional[socket.socket] = None
        self._min_agents = len(hosts or ())
        for addr in hosts or ():
            self._dial(parse_addr(addr), connect_timeout)
        if listen is not None:
            host, port = parse_addr(listen)
            self._listener = socket.create_server((host, port), backlog=16)
            self._min_agents += 1 if agents is None else agents

    # -- joining ------------------------------------------------------------

    @property
    def bound_addr(self) -> Optional[Tuple[str, int]]:
        """The listener's actual (host, port) — for ``listen='host:0'``."""
        if self._listener is None:
            return None
        addr = self._listener.getsockname()
        return addr[0], addr[1]

    @property
    def n_workers(self) -> int:
        return sum(p.capacity for p in self._peers)

    @property
    def n_agents(self) -> int:
        return len(self._peers)

    def _dial(self, addr: Tuple[str, int], timeout: float) -> None:
        sock = socket.create_connection(addr, timeout=timeout)
        self._join(sock, addr, _HANDSHAKE_TIMEOUT)

    def _join(self, sock: socket.socket, addr: Tuple[str, int],
              handshake_timeout: float) -> None:
        """Handshake + ship the WorkerSpec; the ready comes back later
        through the normal scheduler loop."""
        sock.settimeout(handshake_timeout)
        try:
            framing.handshake(sock)
            framing.send_frame(sock, ("spec", self.spec))
        except framing.TransportError:
            sock.close()
            raise
        sock.settimeout(_IO_TIMEOUT)
        self._peers.append(AgentPeer(sock, addr))

    def _handle_extra(self, obj) -> None:
        if obj is not self._listener:
            return
        try:
            sock, addr = self._listener.accept()
        except OSError:
            return
        try:
            self._join(sock, addr, _ACCEPT_HANDSHAKE_TIMEOUT)
        except framing.TransportError:
            # not a fleet agent (port scanner, wrong version): drop it,
            # keep listening — never take the fleet down
            return
        if self._min_agents == 0:
            # past initial assembly: this join is elastic capacity the
            # listener invited mid-run, i.e. a scale-up
            self.scale_ups += 1

    def _extra_waitables(self) -> List:
        return [self._listener] if self._listener is not None else []

    def _close_extras(self) -> None:
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
            self._listener = None

    # -- lifecycle ----------------------------------------------------------

    def _warming(self) -> bool:
        return (sum(1 for p in self._peers if p.ready) < self._min_agents
                or super()._warming())

    def warmup(self, timeout: float = 120.0) -> List[Dict]:
        infos = super().warmup(timeout)
        # the join gate is for *initial* fleet assembly only — once met,
        # later agent deaths are handled by reap/requeue, not by blocking
        # the next run on a replacement that may never come
        self._min_agents = 0
        return infos

    def _assemble(self, timeout: float) -> None:
        if self._min_agents:
            # initial assembly only: agents may still be dialing in, so
            # don't declare an empty pool dead before the join gate was
            # ever met.  Once assembled (_min_agents == 0), a late joiner
            # that is connected but still warming must NOT re-gate the
            # run — dispatches to it buffer in the socket, and the warm
            # agents keep draining meanwhile.
            self.warmup(timeout=min(timeout, 120.0))


def run_remote_fleet(emulator: Emulator, profiles, *,
                     hosts: Optional[Sequence[str]] = None,
                     listen: Optional[str] = None,
                     agents: Optional[int] = None, mesh_spec=None,
                     flops_scale: float = 1.0, storage_scale: float = 1.0,
                     mem_scale: float = 1.0, verify: bool = True,
                     timeout: float = 600.0,
                     fleet: Optional[RemoteFleet] = None,
                     window: Optional[int] = None, autoscale: bool = False,
                     min_workers: Optional[int] = None,
                     collect: str = "reports",
                     max_attempts: Optional[int] = None,
                     liveness_timeout: Optional[float] = None,
                     speculate: Optional[float] = None,
                     on_failure: str = "raise",
                     chaos=None) -> FleetReport:
    """Compile → detach → ship over TCP, streamed: one-call remote replay.

    Backs ``Emulator.emulate_many(executor="remote")``.  ``profiles`` may
    be any iterable — a lazy source is compiled as the scheduler pulls, at
    most ``window`` bundles ahead of dispatch, so coordinator memory is
    bounded by the window however long the stream runs.  Pass ``fleet`` to
    reuse a warm ``RemoteFleet`` (the caller keeps ownership; the spec —
    chaos policy included — is then the caller's); otherwise one is
    assembled from ``hosts``/``listen``/``agents`` and torn down around
    this run — tearing down tells the agents to exit, so one-shot runs
    don't leave orphaned worker pools on other machines.  With
    ``mesh_spec`` set, every agent's workers build their own device mesh
    and collective legs execute on each host.  ``collect="totals"`` drops
    per-profile reports and returns index-order-folded aggregates only.

    Hardening: ``liveness_timeout`` arms hung-agent reaping (the shipped
    spec asks agents to heartbeat at a quarter of it), ``speculate``/
    ``max_attempts``/``on_failure`` pass through to ``stream``, and a
    seeded ``chaos`` policy travels in the spec so agents *and* their
    local workers inject the same deterministic fault schedule as a
    process fleet given the same policy.  Stats/scaling/recovery are
    snapshotted even when the stream raises — the partial ``FleetReport``
    rides on the exception as ``.fleet_report``.

    ``profiles`` may also be a ``WorkloadDag`` (anything with a
    ``parents_map``): node bundles ship their dependency edges, the
    scheduler's frontier gates dispatch on them across agents, and the
    report's ``dag`` dict carries critical-path accounting — same
    contract as ``run_process_fleet``, ``collect="totals"`` rejected.
    """
    is_dag = hasattr(profiles, "parents_map")
    if is_dag and collect == "totals":
        raise ValueError(
            "collect='totals' is incompatible with a WorkloadDag: totals "
            "mode drops the per-node BundleTiming stamps critical-path "
            "accounting needs — use collect='reports'")
    own = fleet is None
    if own:
        # assemble (and config-validate / dial) BEFORE compiling: a bad
        # hosts/listen config or unreachable agent should not cost a full
        # fleet's worth of trace/compile work first
        heartbeat_s = (max(0.1, liveness_timeout / 4.0)
                       if liveness_timeout else 0.0)
        fleet = RemoteFleet(WorkerSpec(emulator=emulator.spec(),
                                       mesh=mesh_spec,
                                       heartbeat_s=heartbeat_s,
                                       chaos=chaos),
                            hosts=hosts, listen=listen, agents=agents,
                            autoscale=autoscale, min_workers=min_workers)
    t0 = time.perf_counter()
    fold = ReportFold(keep_reports=collect != "totals")
    n_samples = {"n": 0}                 # true profile samples compiled

    timings: Dict[int, "BundleTiming"] = {}

    def _bundles():
        if is_dag:
            for node in profiles.nodes:
                b = bundle_profile(emulator, node.profile,
                                   mesh_spec=mesh_spec,
                                   flops_scale=flops_scale,
                                   storage_scale=storage_scale,
                                   mem_scale=mem_scale, verify=verify,
                                   parents=node.parents)
                n_samples["n"] += b.n_profile_samples
                yield b
            return
        for p in profiles:
            b = bundle_profile(emulator, p, mesh_spec=mesh_spec,
                               flops_scale=flops_scale,
                               storage_scale=storage_scale,
                               mem_scale=mem_scale, verify=verify)
            n_samples["n"] += b.n_profile_samples
            yield b

    def _snapshot():
        return ({"agents": fleet.n_agents, "workers": fleet.n_workers,
                 "worker_deaths": fleet.worker_deaths},
                dict(fleet.last_scaling), dict(fleet.last_recovery),
                fleet.n_workers)

    def _report(stats, scaling, recovery, workers, last_n=None):
        return FleetReport(
            reports=fold.reports, wall_s=time.perf_counter() - t0,
            serial_s=fold.serial_s, max_workers=workers, cache_stats=stats,
            totals=fold.totals, n_samples=n_samples["n"],
            n_replayed=fold.n_done, scaling=scaling, recovery=recovery,
            obs=fleet.obs_snapshot(last_n),
            dag=(critical_path(profiles.parents_map, timings)
                 if is_dag else {}))

    gen = fleet.stream(_bundles(), timeout=timeout, window=window,
                       max_attempts=max_attempts,
                       liveness_timeout=liveness_timeout,
                       speculate=speculate, on_failure=on_failure,
                       record_timing=(timings.__setitem__
                                      if is_dag else None))
    try:
        for idx, rep in gen:
            if rep is None:
                # degraded-mode hole: cascade holes classified apart
                fold.skip(idx,
                          ancestor=idx in fleet.last_ancestor_skips)
            else:
                fold.add(idx, rep)
        snap = _snapshot()
    except BaseException as e:
        # close the generator first so its finally published this run's
        # scaling/recovery records, then let the partial report ride out
        # on the exception
        gen.close()
        # postmortem: the merged timeline's tail rides out on the raise
        e.fleet_report = _report(*_snapshot(), last_n=256)
        raise
    finally:
        if own:
            fleet.close()
    return _report(*snap)
