"""Wire framing for the fleet transport: versioned, length-prefixed frames.

A fleet connection speaks two layers:

  1. **Handshake** — on connect, each side writes an 8-byte hello
     (``MAGIC`` + big-endian version + reserved) and reads the peer's.
     A peer that is not a Synapse fleet endpoint fails the magic check;
     a peer from an incompatible release fails the version check.  Both
     fail *before* any pickle payload is exchanged, so a stray client
     can never feed bytes into ``pickle.loads``.
  2. **Frames** — every message after the handshake is one frame: a
     4-byte big-endian length prefix followed by exactly that many bytes
     of pickled payload.  The length is checked against
     ``MAX_FRAME_BYTES`` before any allocation, so a corrupt or hostile
     header cannot ask the receiver to buffer gigabytes.

Failure modes are loud and typed: a clean close between frames raises
``TransportClosed`` (the peer is gone — reap it); a close *inside* a
frame, a bad magic, or an oversized header raises ``FramingError`` (the
stream is corrupt — the connection is unusable either way).  Nothing in
this module retries or blocks forever: reads run under the socket's
timeout, and a timeout surfaces as ``TransportClosed`` too.

The payload is pickle because both ends are this repo (the coordinator
ships ``WorkerSpec``/``ScheduleBundle``s, agents ship
``EmulationReport``s) — the handshake is what keeps pickle off the wire
for strangers.  Agents should still only connect to coordinators they
trust, exactly like any multiprocessing-over-network transport.
"""
from __future__ import annotations

import pickle
import socket
import struct

MAGIC = b"SYNF"
VERSION = 1
MAX_FRAME_BYTES = 1 << 28          # 256 MiB: far above any real bundle

_HELLO = struct.Struct(">4sHH")    # magic, version, reserved
_LEN = struct.Struct(">I")


class TransportError(RuntimeError):
    """Base class for everything this layer raises."""


class FramingError(TransportError):
    """The byte stream is corrupt: truncated frame, oversized length
    header, or a peer that isn't speaking this protocol at all."""


class VersionMismatch(FramingError):
    """The peer speaks this protocol, but a different version of it."""


class TransportClosed(TransportError):
    """The peer is gone: clean EOF between frames, reset, or a read/write
    that sat past the socket timeout."""


def send_hello(sock: socket.socket) -> None:
    try:
        sock.sendall(_HELLO.pack(MAGIC, VERSION, 0))
    except (BrokenPipeError, ConnectionResetError, OSError) as e:
        raise TransportClosed(f"peer closed during handshake: {e}") from e


def recv_hello(sock: socket.socket) -> None:
    raw = _recv_exact(sock, _HELLO.size, what="handshake hello")
    magic, version, _ = _HELLO.unpack(raw)
    if magic != MAGIC:
        raise FramingError(
            f"peer is not a Synapse fleet endpoint: expected magic "
            f"{MAGIC!r}, got {magic!r}")
    if version != VERSION:
        raise VersionMismatch(
            f"peer speaks fleet framing v{version}, this side v{VERSION}")


def handshake(sock: socket.socket) -> None:
    """Symmetric hello exchange — both ends call this right after
    connect/accept (8 bytes each way always fit in the socket buffers,
    so send-then-recv cannot deadlock)."""
    send_hello(sock)
    recv_hello(sock)


def send_frame(sock: socket.socket, obj, *, _mangle=None) -> None:
    """Pickle ``obj`` into one length-prefixed frame.

    ``_mangle`` is a fault-injection hook (``bytes -> bytes``, length
    preserved) used by the chaos engine and the framing tests to put a
    corrupt-but-well-framed payload on the wire; production callers
    leave it None.
    """
    payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    if len(payload) > MAX_FRAME_BYTES:
        raise FramingError(f"refusing to send a {len(payload)}-byte frame "
                           f"(cap {MAX_FRAME_BYTES})")
    if _mangle is not None:
        payload = _mangle(payload)
    try:
        sock.sendall(_LEN.pack(len(payload)) + payload)
    except (BrokenPipeError, ConnectionResetError, OSError) as e:
        raise TransportClosed(f"peer closed while sending: {e}") from e


def recv_frame(sock: socket.socket):
    head = _recv_exact(sock, _LEN.size, what="frame header", clean_eof=True)
    n = _LEN.unpack(head)[0]
    if n > MAX_FRAME_BYTES:
        raise FramingError(f"frame header announces {n} bytes "
                           f"(cap {MAX_FRAME_BYTES}) — corrupt stream")
    payload = _recv_exact(sock, n, what=f"{n}-byte frame payload")
    try:
        return pickle.loads(payload)
    except Exception as e:  # noqa: BLE001 — unpickling bad bytes can raise
        # almost anything (UnpicklingError, EOFError, AttributeError...);
        # a well-framed but undecodable payload is a corrupt stream, and
        # must surface as FramingError -> PeerGone, not leak raw pickle
        # internals into the scheduler
        raise FramingError(
            f"frame payload failed to unpickle ({type(e).__name__}: {e}) "
            "— corrupt stream") from e


def _recv_exact(sock: socket.socket, n: int, *, what: str,
                clean_eof: bool = False) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        try:
            chunk = sock.recv(n - len(buf))
        except (ConnectionResetError, socket.timeout, OSError) as e:
            raise TransportClosed(f"peer lost mid-{what}: {e}") from e
        if not chunk:
            if clean_eof and not buf:
                raise TransportClosed("peer closed the connection")
            raise FramingError(f"connection closed mid-{what}: got "
                               f"{len(buf)} of {n} bytes")
        buf += chunk
    return bytes(buf)
