"""Fleet executors: replay schedule bundles on pools of remote peers.

Two layers live here.  ``FleetBase`` is the transport-agnostic scheduler:
it owns the pending queue, the one-bundle-per-worker-slot dispatch loop,
the per-bundle attempt budget (a bundle that keeps killing workers is
declared poison instead of looping forever), the run deadline, and the
reap-requeue-refill dance when a peer dies.  It schedules ``Peer``
objects — anything with worker slots that can ``dispatch`` a bundle and
``recv`` a normalized reply — and never touches a pipe or a socket
itself.

``ProcessFleet`` is the local instantiation: each peer is one spawn-based
worker process (see ``repro.fleet.worker``) behind a multiprocessing
``Pipe``, with its own jax client, emulator, jitted programs, and — when
the ``WorkerSpec`` carries a ``MeshSpec`` — its own device mesh.
``repro.fleet.transport.remote.RemoteFleet`` is the network
instantiation: each peer is a TCP connection to a host agent that fronts
several such worker processes on another machine.  Both inherit the same
scheduling semantics, which is the point — a dead TCP peer is reaped
exactly like a dead process, and its in-flight bundles requeue onto the
survivors.

Scheduling is work-stealing-simple: one in-flight bundle per worker slot,
next bundle to the first slot that frees up, so a straggler profile never
blocks the rest of the fleet.  Only when no peer is left alive (and none
can be refilled) with work still pending does ``run`` raise.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import time
from collections import deque
from multiprocessing import connection as mp_conn
from typing import Deque, Dict, List, Optional, Sequence, Set, Tuple

from repro.core.emulator import EmulationReport, Emulator, FleetReport
from repro.fleet.bundle import ScheduleBundle, WorkerSpec, bundle_profile
from repro.fleet.worker import worker_loop

_MAX_ATTEMPTS = 3          # dispatches per bundle before declaring it poison


class PeerGone(Exception):
    """The peer (worker process or remote agent) is dead or unreachable:
    reap it, requeue its in-flight bundles, keep draining on survivors."""


class Peer:
    """One schedulable fleet endpoint with ``capacity`` worker slots.

    ``tasks`` is the in-flight set of ``(dispatch epoch, bundle index)``
    pairs — epoch-qualified so a new run re-dispatching an index can never
    collide with a stale entry for the same index.  Entries from a
    *raised* run (stale epoch) stay until their late results arrive: they
    keep the slot occupied — the worker really is still busy — and the
    scheduler recognizes them by epoch, drops their results, and only
    then reuses the slot.  Subclasses translate their wire format into the
    normalized message tuples the scheduler consumes:

      ("ready", info)                 peer finished initializing
      ("ok",    epoch, idx, report)   bundle replayed
      ("retry", epoch, idx, reason)   peer-side worker died; requeue the
                                      bundle (its dispatch attempt stays
                                      counted, so poison budgets hold)
      ("err",   epoch, idx, tb)       bundle failed (idx=None: init died)
    """

    capacity = 1

    def __init__(self):
        self.tasks: Set[Tuple[int, int]] = set()
        self.ready = False

    @property
    def free_slots(self) -> int:
        return self.capacity - len(self.tasks)

    def epoch_for(self, idx: int) -> Optional[int]:
        """The dispatch epoch of in-flight bundle ``idx`` — for adapters
        whose wire protocol doesn't echo epochs (capacity-1 pipes hold at
        most one entry, so the lookup is unambiguous there)."""
        return next((e for (e, i) in self.tasks if i == idx), None)

    @property
    def alive(self) -> bool:
        """Cheap local liveness; transports without one return True and
        let death surface as ``PeerGone`` on I/O."""
        return True

    @property
    def waitable(self):
        """Object for ``multiprocessing.connection.wait``."""
        raise NotImplementedError

    def dispatch(self, epoch: int, idx: int, bundle: ScheduleBundle) -> None:
        raise NotImplementedError

    def recv(self):
        raise NotImplementedError

    def stop(self) -> None:
        """Best-effort polite shutdown request; never raises."""

    def close(self) -> None:
        """Tear down the endpoint; never raises."""

    def describe(self) -> str:
        return "fleet peer"


class FleetBase:
    """Transport-agnostic bundle scheduler over a pool of ``Peer``s.

    Subclasses populate ``self._peers`` and may override ``_refill`` (to
    respawn replacements after a death), ``_extra_waitables`` /
    ``_handle_extra`` (to service non-peer readiness, e.g. accepting new
    agents mid-run), and ``_warming`` (to gate on a minimum pool size).
    ``worker_deaths`` counts reaped peers across the pool's lifetime.
    """

    def __init__(self):
        self._peers: List[Peer] = []
        self._closed = False
        self._epoch = 0
        self.worker_deaths = 0

    # -- pool plumbing ------------------------------------------------------

    def _reap(self, peer: Peer, pending: Deque[int],
              epoch: Optional[int] = None) -> None:
        """A peer died: requeue its in-flight bundles (only those belonging
        to the current run — stragglers from a raised run are dropped),
        then refill the pool."""
        self.worker_deaths += 1
        for e, idx in peer.tasks:
            if epoch is not None and e == epoch:
                pending.appendleft(idx)
        peer.tasks.clear()
        peer.close()
        self._peers.remove(peer)
        self._refill(pending)

    def _refill(self, pending: Deque[int]) -> None:
        """Hook: replace a reaped peer if the transport can."""

    def _extra_waitables(self) -> List:
        return []

    def _handle_extra(self, obj) -> None:
        raise NotImplementedError(f"unexpected waitable {obj!r}")

    def _close_extras(self) -> None:
        pass

    def _wait(self, timeout: float, *, ready_only: bool = False) -> List:
        conns = [p.waitable for p in self._peers
                 if not (ready_only and p.ready)]
        conns += self._extra_waitables()
        return mp_conn.wait(conns, timeout=timeout) if conns else []

    def _peer_for(self, obj) -> Optional[Peer]:
        return next((p for p in self._peers if p.waitable is obj), None)

    def _warming(self) -> bool:
        return any(p.alive and not p.ready for p in self._peers)

    # -- lifecycle ----------------------------------------------------------

    def warmup(self, timeout: float = 120.0) -> List[Dict]:
        """Block until every live peer reported ready (and any subclass
        minimum-pool condition holds); returns their ready infos.  Not
        required before ``run`` (dispatches queue in the transport), but
        useful to separate spawn/connect/trace cost from replay cost —
        ``benchmarks/bench_fleet.py`` does exactly that."""
        deadline = time.monotonic() + timeout
        infos: List[Dict] = []
        while self._warming():
            if time.monotonic() > deadline:
                raise TimeoutError("fleet workers did not become ready "
                                   f"within {timeout}s")
            for obj in self._wait(0.5, ready_only=True):
                peer = self._peer_for(obj)
                if peer is None:
                    self._handle_extra(obj)
                    continue
                try:
                    msg = peer.recv()
                except PeerGone:
                    self._reap(peer, deque())
                    continue
                if msg[0] == "ready":
                    peer.ready = True
                    infos.append(msg[1])
                elif msg[0] == "err":
                    raise RuntimeError(
                        f"fleet worker failed to initialize:\n{msg[-1]}")
        if not self._peers:
            raise RuntimeError("no fleet worker survived initialization")
        return infos

    # -- execution ----------------------------------------------------------

    def run(self, bundles: Sequence[ScheduleBundle], *,
            timeout: float = 600.0) -> List[EmulationReport]:
        """Replay every bundle; returns reports in bundle order.

        Raises RuntimeError on a peer-reported replay failure, on a
        poison bundle (one that outlived ``_MAX_ATTEMPTS`` dispatch
        attempts across dying workers), or when the whole pool is dead
        with work still pending; TimeoutError past the deadline.
        """
        if self._closed:
            raise RuntimeError(f"{type(self).__name__} is closed")
        # A raised run (worker error, poison bundle, timeout) leaves
        # stragglers replaying on live peers.  Each run gets a fresh
        # epoch: stragglers' late results are recognized by their stale
        # epoch, discarded, and merely free their slot — they are never
        # returned as this run's reports and never block dispatch forever.
        self._epoch += 1
        epoch = self._epoch
        pending: Deque[int] = deque(range(len(bundles)))
        attempts = [0] * len(bundles)
        results: Dict[int, EmulationReport] = {}
        deadline = time.monotonic() + timeout
        while len(results) < len(bundles):
            if time.monotonic() > deadline:
                raise TimeoutError(f"fleet run exceeded {timeout}s with "
                                   f"{len(bundles) - len(results)} bundle(s) "
                                   "unfinished")
            # dispatch to free slots (death noticed on send is handled
            # exactly like death noticed on receive)
            for peer in list(self._peers):
                while pending and peer.free_slots > 0:
                    if not peer.alive:
                        self._reap(peer, pending, epoch)
                        break
                    idx = pending.popleft()
                    if attempts[idx] >= _MAX_ATTEMPTS:
                        raise RuntimeError(
                            f"bundle {idx} ({bundles[idx].command!r}) failed "
                            f"{attempts[idx]} dispatch attempts — poison "
                            "bundle, aborting the fleet run")
                    attempts[idx] += 1
                    try:
                        peer.dispatch(epoch, idx, bundles[idx])
                    except PeerGone:
                        pending.appendleft(idx)
                        attempts[idx] -= 1
                        self._reap(peer, pending, epoch)
                        break
            if not self._peers:
                raise RuntimeError(
                    f"all fleet workers died ({self.worker_deaths} death(s)) "
                    f"with {len(bundles) - len(results)} bundle(s) pending")
            # collect
            for obj in self._wait(0.5):
                peer = self._peer_for(obj)
                if peer is None:
                    self._handle_extra(obj)
                    continue
                try:
                    msg = peer.recv()
                except PeerGone:
                    self._reap(peer, pending, epoch)
                    continue
                kind = msg[0]
                if kind == "ready":
                    peer.ready = True
                elif kind == "ok":
                    _, e, idx, rep = msg
                    peer.tasks.discard((e, idx))
                    if e == epoch:
                        results[idx] = rep
                elif kind == "retry":
                    _, e, idx, _reason = msg
                    peer.tasks.discard((e, idx))
                    if e == epoch:
                        pending.append(idx)
                elif kind == "err":
                    _, e, idx, tb = msg
                    if idx is None:
                        raise RuntimeError(
                            f"fleet worker failed on initialization:\n{tb}")
                    peer.tasks.discard((e, idx))  # terminal either way
                    if e == epoch:
                        raise RuntimeError(
                            f"fleet worker ({peer.describe()}) failed on "
                            f"bundle {idx} ({bundles[idx].command!r}):\n{tb}")
        return [results[i] for i in range(len(bundles))]

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for peer in self._peers:
            peer.stop()
        for peer in self._peers:
            peer.close()
        self._peers.clear()
        self._close_extras()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# local instantiation: worker processes behind multiprocessing Pipes
# ---------------------------------------------------------------------------

class _PipePeer(Peer):
    """One spawn-based worker process behind a ``Pipe``: capacity 1.

    The on-pipe worker protocol (``repro.fleet.worker``) predates epochs —
    a capacity-1 worker replays serially, so the epoch of any reply is
    simply the epoch its single in-flight task was dispatched under; this
    adapter re-attaches it.
    """

    __slots__ = ("proc", "conn", "tasks", "ready")

    def __init__(self, proc, conn):
        super().__init__()
        self.proc = proc
        self.conn = conn

    @property
    def alive(self) -> bool:
        return self.proc.is_alive()

    @property
    def waitable(self):
        return self.conn

    def dispatch(self, epoch, idx, bundle):
        try:
            self.conn.send(("run", idx, bundle))
        except (BrokenPipeError, OSError) as e:
            raise PeerGone(str(e)) from e
        self.tasks.add((epoch, idx))

    def recv(self):
        try:
            msg = self.conn.recv()
        except (EOFError, ConnectionResetError, OSError) as e:
            raise PeerGone(str(e)) from e
        kind = msg[0]
        if kind == "ready":
            return ("ready", msg[1])
        if kind == "ok":
            _, idx, rep = msg
            return ("ok", self.epoch_for(idx), idx, rep)
        if kind == "err":
            _, idx, tb = msg
            return ("err", self.epoch_for(idx), idx, tb)
        return ("err", None, None, f"unknown worker message {kind!r}")

    def stop(self):
        if self.alive:
            try:
                self.conn.send(("stop",))
            except (BrokenPipeError, OSError):
                pass

    def close(self):
        try:
            self.conn.close()
        except OSError:
            pass
        # instant for a reaped (dead) process; grace for a polite stop
        self.proc.join(timeout=5.0)

    def describe(self) -> str:
        return f"worker pid {self.proc.pid}"


class ProcessFleet(FleetBase):
    """A pool of emulator worker processes that replay ``ScheduleBundle``s.

    The pool is warm state: spawn it once, ``run()`` it many times (each
    run reuses the workers' traced programs and plan caches), ``close()``
    it when done — or use it as a context manager.  ``worker_deaths`` and
    ``respawns`` count recovery events across the pool's lifetime.
    """

    def __init__(self, n_workers: int, spec: WorkerSpec, *,
                 respawn: bool = True, max_respawns: Optional[int] = None):
        if n_workers < 1:
            raise ValueError("ProcessFleet needs n_workers >= 1")
        super().__init__()
        self.spec = spec
        self.n_workers = n_workers
        self.respawns = 0
        self._respawn = respawn
        self._respawns_left = (n_workers if max_respawns is None
                               else max_respawns)
        self._ctx = mp.get_context("spawn")
        for _ in range(n_workers):
            self._spawn()

    def _spawn(self) -> None:
        parent_conn, child_conn = self._ctx.Pipe()
        # The mesh's device count must reach the child's XLA before its
        # backend initializes; setting it in the *parent's* environment
        # around the spawn is the only ordering that beats every module the
        # child bootstrap may import.
        old_flags = os.environ.get("XLA_FLAGS")
        if self.spec.mesh is not None:
            # append AFTER any inherited flags: XLA takes the last
            # occurrence of a repeated flag, and this repo's own tooling
            # (dryrun, test_distributed) exports its own device-count flag
            os.environ["XLA_FLAGS"] = (
                (f"{old_flags} " if old_flags else "")
                + f"--xla_force_host_platform_device_count="
                  f"{self.spec.mesh.device_count}")
        try:
            proc = self._ctx.Process(target=worker_loop,
                                     args=(child_conn, self.spec),
                                     daemon=True)
            proc.start()
        finally:
            if self.spec.mesh is not None:
                if old_flags is None:
                    os.environ.pop("XLA_FLAGS", None)
                else:
                    os.environ["XLA_FLAGS"] = old_flags
        child_conn.close()
        self._peers.append(_PipePeer(proc, parent_conn))

    def _refill(self, pending: Deque[int]) -> None:
        if self._respawn and self._respawns_left > 0:
            self._respawns_left -= 1
            self.respawns += 1
            self._spawn()

    @property
    def pids(self) -> List[int]:
        return [p.proc.pid for p in self._peers if p.alive]

    def close(self) -> None:
        if self._closed:
            return
        peers = list(self._peers)
        super().close()                     # stop + close (join 5s each)
        for p in peers:                     # stragglers get the axe
            if p.proc.is_alive():
                p.proc.terminate()
                p.proc.join(timeout=2.0)


def run_process_fleet(emulator: Emulator, profiles, *, max_workers: int = 4,
                      mesh_spec=None, flops_scale: float = 1.0,
                      storage_scale: float = 1.0, mem_scale: float = 1.0,
                      verify: bool = True, timeout: float = 600.0,
                      fleet: Optional[ProcessFleet] = None) -> FleetReport:
    """Compile → detach → ship: one-call process-fleet replay.

    Backs ``Emulator.emulate_many(executor="process")``.  Pass ``fleet`` to
    reuse a warm ``ProcessFleet`` (the caller keeps ownership); otherwise a
    pool sized ``min(max_workers, len(profiles))`` is spawned and torn down
    around this one run.  With ``mesh_spec`` set, wire-byte runs compile to
    mesh-bound fused segments and every worker builds its own mesh —
    collective legs move bytes inside the workers' segment scans.
    """
    bundles = [bundle_profile(emulator, p, mesh_spec=mesh_spec,
                              flops_scale=flops_scale,
                              storage_scale=storage_scale,
                              mem_scale=mem_scale, verify=verify)
               for p in profiles]
    own = fleet is None
    if own:
        workers = max(1, min(max_workers, len(profiles)))
        fleet = ProcessFleet(workers, WorkerSpec(emulator=emulator.spec(),
                                                 mesh=mesh_spec))
    t0 = time.perf_counter()
    try:
        reports = fleet.run(bundles, timeout=timeout)
    finally:
        if own:
            fleet.close()
    wall = time.perf_counter() - t0
    return FleetReport(
        reports=reports, wall_s=wall,
        serial_s=sum(r.ttc_s for r in reports),
        max_workers=fleet.n_workers,
        cache_stats={"workers": fleet.n_workers,
                     "worker_deaths": fleet.worker_deaths,
                     "respawns": fleet.respawns})
