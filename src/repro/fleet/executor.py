"""Fleet executors: replay schedule bundles on pools of remote peers.

Two layers live here.  ``FleetBase`` is the transport-agnostic scheduler:
it owns the pending queue, the one-bundle-per-worker-slot dispatch loop,
the per-bundle attempt budget (a bundle that keeps killing workers is
declared poison instead of looping forever), the run deadline, and the
reap-requeue-refill dance when a peer dies.  It schedules ``Peer``
objects — anything with worker slots that can ``dispatch`` a bundle and
``recv`` a normalized reply — and never touches a pipe or a socket
itself.

The interchange is an *iterator of bundles*, not a list: ``stream()``
pulls from the source only while fewer than ``window`` bundles are
pulled-but-unfinished, so a lazy source (a generator compiling profiles
on the fly, ``ProfileStore.stream`` feeding ``bundle_profile``) is
backpressured by the workers — the coordinator never materializes more
than a window's worth of compiled schedules no matter how long the
stream is.  ``run()`` is the materializing wrapper (list in, ordered
list of reports out) kept for warm-pool callers and tests.

``FleetBase`` also owns admission control and fleet *elasticity*: with
autoscaling enabled, queued bundles outnumbering free slots grows the
pool one peer per scheduler pass (``_scale_up`` — ProcessFleet spawns a
worker, RemoteFleet's open listener admits late joiners), and once the
source is exhausted idle peers are retired back down to the floor
(``_retire``).  Scale events and high-water marks are recorded in
``last_scaling`` and surfaced through ``FleetReport.scaling``.

``ProcessFleet`` is the local instantiation: each peer is one spawn-based
worker process (see ``repro.fleet.worker``) behind a multiprocessing
``Pipe``, with its own jax client, emulator, jitted programs, and — when
the ``WorkerSpec`` carries a ``MeshSpec`` — its own device mesh.
``repro.fleet.transport.remote.RemoteFleet`` is the network
instantiation: each peer is a TCP connection to a host agent that fronts
several such worker processes on another machine.  Both inherit the same
scheduling semantics, which is the point — a dead TCP peer is reaped
exactly like a dead process, and its in-flight bundles requeue onto the
survivors.

Scheduling is work-stealing-simple: one in-flight bundle per worker slot,
next bundle to the first slot that frees up, so a straggler profile never
blocks the rest of the fleet.  Only when no peer is left alive (and none
can be refilled) with work still pending does a run raise.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import time
from collections import deque
from multiprocessing import connection as mp_conn
from typing import (Deque, Dict, Iterable, Iterator, List, Optional, Set,
                    Tuple)

from repro.core.emulator import (EmulationReport, Emulator, FleetReport,
                                 ReportFold)
from repro.fleet.bundle import ScheduleBundle, WorkerSpec, bundle_profile
from repro.fleet.worker import worker_loop

_MAX_ATTEMPTS = 3          # dispatches per bundle before declaring it poison


class PeerGone(Exception):
    """The peer (worker process or remote agent) is dead or unreachable:
    reap it, requeue its in-flight bundles, keep draining on survivors."""


class Peer:
    """One schedulable fleet endpoint with ``capacity`` worker slots.

    ``tasks`` is the in-flight set of ``(dispatch epoch, bundle index)``
    pairs — epoch-qualified so a new run re-dispatching an index can never
    collide with a stale entry for the same index.  Entries from a
    *raised* run (stale epoch) stay until their late results arrive: they
    keep the slot occupied — the worker really is still busy — and the
    scheduler recognizes them by epoch, drops their results, and only
    then reuses the slot.  Subclasses translate their wire format into the
    normalized message tuples the scheduler consumes:

      ("ready", info)                 peer finished initializing
      ("ok",    epoch, idx, report)   bundle replayed
      ("retry", epoch, idx, reason)   peer-side worker died; requeue the
                                      bundle (its dispatch attempt stays
                                      counted, so poison budgets hold)
      ("err",   epoch, idx, tb)       bundle failed (idx=None: init died)
    """

    capacity = 1

    def __init__(self):
        self.tasks: Set[Tuple[int, int]] = set()
        self.ready = False

    @property
    def free_slots(self) -> int:
        return self.capacity - len(self.tasks)

    def epoch_for(self, idx: int) -> Optional[int]:
        """The dispatch epoch of in-flight bundle ``idx`` — for adapters
        whose wire protocol doesn't echo epochs (capacity-1 pipes hold at
        most one entry, so the lookup is unambiguous there)."""
        return next((e for (e, i) in self.tasks if i == idx), None)

    @property
    def alive(self) -> bool:
        """Cheap local liveness; transports without one return True and
        let death surface as ``PeerGone`` on I/O."""
        return True

    @property
    def waitable(self):
        """Object for ``multiprocessing.connection.wait``."""
        raise NotImplementedError

    def dispatch(self, epoch: int, idx: int, bundle: ScheduleBundle) -> None:
        raise NotImplementedError

    def recv(self):
        raise NotImplementedError

    def stop(self) -> None:
        """Best-effort polite shutdown request; never raises."""

    def close(self) -> None:
        """Tear down the endpoint; never raises."""

    def describe(self) -> str:
        return "fleet peer"


class FleetBase:
    """Transport-agnostic bundle scheduler over a pool of ``Peer``s.

    Subclasses populate ``self._peers`` and may override ``_refill`` (to
    respawn replacements after a death), ``_scale_up`` (to grow the pool
    when autoscaling), ``_extra_waitables`` / ``_handle_extra`` (to
    service non-peer readiness, e.g. accepting new agents mid-run),
    ``_assemble`` (to gate a run on initial pool assembly), and
    ``_warming`` (to gate warmup on a minimum pool size).
    ``worker_deaths`` counts reaped peers across the pool's lifetime;
    ``scale_ups``/``scale_downs`` count elasticity events the same way,
    and ``last_scaling`` holds the most recent stream's high-water marks.
    """

    def __init__(self):
        self._peers: List[Peer] = []
        self._closed = False
        self._epoch = 0
        self.worker_deaths = 0
        self.scale_ups = 0
        self.scale_downs = 0
        #: elasticity policy; subclasses flip these (ProcessFleet ctor,
        #: RemoteFleet ctor) — base default is a fixed-size pool
        self._autoscale = False
        self._scale_min = 1
        #: high-water marks / event counts of the most recent stream
        self.last_scaling: Dict[str, int] = {}

    # -- pool plumbing ------------------------------------------------------

    def _reap(self, peer: Peer, pending: Deque[int],
              epoch: Optional[int] = None) -> None:
        """A peer died: requeue its in-flight bundles (only those belonging
        to the current run — stragglers from a raised run are dropped),
        then refill the pool."""
        self.worker_deaths += 1
        for e, idx in peer.tasks:
            if epoch is not None and e == epoch:
                pending.appendleft(idx)
        peer.tasks.clear()
        peer.close()
        self._peers.remove(peer)
        self._refill(pending)

    def _refill(self, pending: Deque[int]) -> None:
        """Hook: replace a reaped peer if the transport can."""

    def _scale_up(self) -> bool:
        """Hook: add one peer of capacity (autoscale).  Returns True if the
        pool grew.  The base pool cannot grow."""
        return False

    def _retire(self, peer: Peer) -> None:
        """Politely release an idle peer (autoscale down).  Not a death:
        no requeue, no refill, no ``worker_deaths``."""
        peer.stop()
        peer.close()
        self._peers.remove(peer)
        self.scale_downs += 1

    def _assemble(self, timeout: float) -> None:
        """Hook: block until the initial pool is usable (RemoteFleet gates
        the first stream on its join quorum here)."""

    def _extra_waitables(self) -> List:
        return []

    def _handle_extra(self, obj) -> None:
        raise NotImplementedError(f"unexpected waitable {obj!r}")

    def _close_extras(self) -> None:
        pass

    def _wait(self, timeout: float, *, ready_only: bool = False) -> List:
        conns = [p.waitable for p in self._peers
                 if not (ready_only and p.ready)]
        conns += self._extra_waitables()
        return mp_conn.wait(conns, timeout=timeout) if conns else []

    def _peer_for(self, obj) -> Optional[Peer]:
        return next((p for p in self._peers if p.waitable is obj), None)

    def _warming(self) -> bool:
        return any(p.alive and not p.ready for p in self._peers)

    # -- lifecycle ----------------------------------------------------------

    def warmup(self, timeout: float = 120.0) -> List[Dict]:
        """Block until every live peer reported ready (and any subclass
        minimum-pool condition holds); returns their ready infos.  Not
        required before ``run`` (dispatches queue in the transport), but
        useful to separate spawn/connect/trace cost from replay cost —
        ``benchmarks/bench_fleet.py`` does exactly that."""
        deadline = time.monotonic() + timeout
        infos: List[Dict] = []
        while self._warming():
            if time.monotonic() > deadline:
                raise TimeoutError("fleet workers did not become ready "
                                   f"within {timeout}s")
            for obj in self._wait(0.5, ready_only=True):
                peer = self._peer_for(obj)
                if peer is None:
                    self._handle_extra(obj)
                    continue
                try:
                    msg = peer.recv()
                except PeerGone:
                    self._reap(peer, deque())
                    continue
                if msg[0] == "ready":
                    peer.ready = True
                    infos.append(msg[1])
                elif msg[0] == "err":
                    raise RuntimeError(
                        f"fleet worker failed to initialize:\n{msg[-1]}")
        if not self._peers:
            raise RuntimeError("no fleet worker survived initialization")
        return infos

    # -- execution ----------------------------------------------------------

    def stream(self, bundles: Iterable[ScheduleBundle], *,
               timeout: float = 600.0, window: Optional[int] = None
               ) -> Iterator[Tuple[int, EmulationReport]]:
        """Replay a (possibly lazy) bundle source; yields ``(idx, report)``
        pairs in completion order.

        This is the iterator-of-bundles contract: the source is pulled
        only while fewer than ``window`` bundles are outstanding (pulled
        but unfinished), so a source that compiles on ``next()`` is
        backpressured by worker throughput and coordinator memory stays
        bounded by the window, not the stream length.  ``window=None``
        tracks the pool at ``2 × worker slots`` (recomputed as the pool
        scales), keeping every slot fed while leaving queue depth visible
        to the autoscaler.

        Raises RuntimeError on a peer-reported replay failure, on a
        poison bundle (one that outlived the per-bundle dispatch-attempt
        budget across dying workers), or when the whole pool is dead with
        work still pending; TimeoutError past the deadline.  Completed
        bundles are dropped as their reports are yielded — a raised
        stream's stragglers are recognized by their stale epoch in later
        runs, exactly like ``run``'s.
        """
        if self._closed:
            raise RuntimeError(f"{type(self).__name__} is closed")
        self._assemble(timeout)
        # A raised run (worker error, poison bundle, timeout) leaves
        # stragglers replaying on live peers.  Each run gets a fresh
        # epoch: stragglers' late results are recognized by their stale
        # epoch, discarded, and merely free their slot — they are never
        # yielded into this run and never block dispatch forever.
        self._epoch += 1
        epoch = self._epoch
        source = iter(bundles)
        exhausted = False
        next_idx = 0
        held: Dict[int, ScheduleBundle] = {}   # pulled, result not yielded
        pending: Deque[int] = deque()
        attempts: Dict[int, int] = {}
        deadline = time.monotonic() + timeout
        base_ups, base_downs = self.scale_ups, self.scale_downs
        peak_workers = peak_queue = peak_window = 0
        try:
            while True:
                # -- admission: compile-ahead at most `window` bundles ----
                cap = sum(p.capacity for p in self._peers) or 1
                win = window if window is not None else max(2 * cap, 2)
                while not exhausted and len(held) < win:
                    try:
                        b = next(source)
                    except StopIteration:
                        exhausted = True
                        break
                    held[next_idx] = b
                    pending.append(next_idx)
                    attempts[next_idx] = 0
                    next_idx += 1
                if exhausted and not held:
                    break
                peak_window = max(peak_window, len(held))
                peak_queue = max(peak_queue, len(pending))
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"fleet run exceeded {timeout}s with {len(held)} "
                        "bundle(s) unfinished")
                # -- dispatch to free slots (death noticed on send is
                # handled exactly like death noticed on receive)
                for peer in list(self._peers):
                    while pending and peer.free_slots > 0:
                        if not peer.alive:
                            self._reap(peer, pending, epoch)
                            break
                        idx = pending.popleft()
                        if attempts[idx] >= _MAX_ATTEMPTS:
                            raise RuntimeError(
                                f"bundle {idx} ({held[idx].command!r}) "
                                f"failed {attempts[idx]} dispatch attempts "
                                "— poison bundle, aborting the fleet run")
                        attempts[idx] += 1
                        try:
                            peer.dispatch(epoch, idx, held[idx])
                        except PeerGone:
                            pending.appendleft(idx)
                            attempts[idx] -= 1
                            self._reap(peer, pending, epoch)
                            break
                # -- elasticity: queue depth drives the pool size ---------
                if self._autoscale:
                    if pending and not any(p.alive and p.free_slots > 0
                                           for p in self._peers):
                        self._scale_up()
                    elif exhausted and not pending:
                        # long tail: peers that already drained go idle
                        # while stragglers finish — release them early
                        idle = [p for p in self._peers if not p.tasks]
                        for p in idle[:len(self._peers) - self._scale_min]:
                            self._retire(p)
                peak_workers = max(peak_workers,
                                   sum(p.capacity for p in self._peers))
                if not self._peers:
                    raise RuntimeError(
                        f"all fleet workers died ({self.worker_deaths} "
                        f"death(s)) with {len(held)} bundle(s) pending")
                # -- collect ----------------------------------------------
                for obj in self._wait(0.5):
                    peer = self._peer_for(obj)
                    if peer is None:
                        self._handle_extra(obj)
                        continue
                    try:
                        msg = peer.recv()
                    except PeerGone:
                        self._reap(peer, pending, epoch)
                        continue
                    kind = msg[0]
                    if kind == "ready":
                        peer.ready = True
                    elif kind == "ok":
                        _, e, idx, rep = msg
                        peer.tasks.discard((e, idx))
                        if e == epoch:
                            del held[idx]
                            attempts.pop(idx, None)
                            yield idx, rep
                    elif kind == "retry":
                        _, e, idx, _reason = msg
                        peer.tasks.discard((e, idx))
                        if e == epoch:
                            pending.append(idx)
                    elif kind == "err":
                        _, e, idx, tb = msg
                        if idx is None:
                            raise RuntimeError(
                                "fleet worker failed on initialization:"
                                f"\n{tb}")
                        peer.tasks.discard((e, idx))  # terminal either way
                        if e == epoch:
                            raise RuntimeError(
                                f"fleet worker ({peer.describe()}) failed "
                                f"on bundle {idx} ({held[idx].command!r}):"
                                f"\n{tb}")
            # -- natural drain: an elastic pool parks back at its floor ---
            if self._autoscale:
                idle = [p for p in self._peers if not p.tasks]
                for p in idle[:len(self._peers) - self._scale_min]:
                    self._retire(p)
        finally:
            self.last_scaling = {
                "scale_ups": self.scale_ups - base_ups,
                "scale_downs": self.scale_downs - base_downs,
                "peak_workers": peak_workers,
                "peak_queue_depth": peak_queue,
                "peak_window": peak_window,
            }

    def run(self, bundles: Iterable[ScheduleBundle], *,
            timeout: float = 600.0,
            window: Optional[int] = None) -> List[EmulationReport]:
        """Replay every bundle; returns reports in bundle order.

        The materializing wrapper over ``stream`` — same failure
        semantics, but all reports are held until the source is drained.
        Prefer consuming ``stream`` directly for unbounded sources.
        """
        results: Dict[int, EmulationReport] = {}
        for idx, rep in self.stream(bundles, timeout=timeout, window=window):
            results[idx] = rep
        return [results[i] for i in range(len(results))]

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for peer in self._peers:
            peer.stop()
        for peer in self._peers:
            peer.close()
        self._peers.clear()
        self._close_extras()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# local instantiation: worker processes behind multiprocessing Pipes
# ---------------------------------------------------------------------------

class _PipePeer(Peer):
    """One spawn-based worker process behind a ``Pipe``: capacity 1.

    The on-pipe worker protocol (``repro.fleet.worker``) predates epochs —
    a capacity-1 worker replays serially, so the epoch of any reply is
    simply the epoch its single in-flight task was dispatched under; this
    adapter re-attaches it.
    """

    __slots__ = ("proc", "conn", "tasks", "ready")

    def __init__(self, proc, conn):
        super().__init__()
        self.proc = proc
        self.conn = conn

    @property
    def alive(self) -> bool:
        return self.proc.is_alive()

    @property
    def waitable(self):
        return self.conn

    def dispatch(self, epoch, idx, bundle):
        try:
            self.conn.send(("run", idx, bundle))
        except (BrokenPipeError, OSError) as e:
            raise PeerGone(str(e)) from e
        self.tasks.add((epoch, idx))

    def recv(self):
        try:
            msg = self.conn.recv()
        except (EOFError, ConnectionResetError, OSError) as e:
            raise PeerGone(str(e)) from e
        kind = msg[0]
        if kind == "ready":
            return ("ready", msg[1])
        if kind == "ok":
            _, idx, rep = msg
            return ("ok", self.epoch_for(idx), idx, rep)
        if kind == "err":
            _, idx, tb = msg
            return ("err", self.epoch_for(idx), idx, tb)
        return ("err", None, None, f"unknown worker message {kind!r}")

    def stop(self):
        if self.alive:
            try:
                self.conn.send(("stop",))
            except (BrokenPipeError, OSError):
                pass

    def close(self):
        try:
            self.conn.close()
        except OSError:
            pass
        # instant for a reaped (dead) process; grace for a polite stop
        self.proc.join(timeout=5.0)

    def describe(self) -> str:
        return f"worker pid {self.proc.pid}"


class ProcessFleet(FleetBase):
    """A pool of emulator worker processes that replay ``ScheduleBundle``s.

    The pool is warm state: spawn it once, ``run()``/``stream()`` it many
    times (each run reuses the workers' traced programs and plan caches),
    ``close()`` it when done — or use it as a context manager.
    ``worker_deaths`` and ``respawns`` count recovery events across the
    pool's lifetime.

    With ``autoscale=True`` the pool is elastic: it starts at
    ``min_workers`` (default 1), the scheduler spawns up to ``n_workers``
    while queued bundles outnumber free slots, and idle workers are
    retired back to the floor when a stream drains — so a bursty profile
    source pays for exactly the workers its queue depth asked for.
    """

    def __init__(self, n_workers: int, spec: WorkerSpec, *,
                 respawn: bool = True, max_respawns: Optional[int] = None,
                 min_workers: Optional[int] = None, autoscale: bool = False):
        if n_workers < 1:
            raise ValueError("ProcessFleet needs n_workers >= 1")
        if min_workers is not None and not autoscale:
            raise ValueError("min_workers is the autoscale floor; pass "
                             "autoscale=True with it")
        super().__init__()
        self.spec = spec
        self.n_workers = n_workers
        self.respawns = 0
        self._respawn = respawn
        self._respawns_left = (n_workers if max_respawns is None
                               else max_respawns)
        self._ctx = mp.get_context("spawn")
        self._autoscale = autoscale
        self._scale_max = n_workers
        self._scale_min = max(1, min_workers or 1) if autoscale else n_workers
        if self._scale_min > n_workers:
            raise ValueError(f"min_workers={min_workers} exceeds "
                             f"n_workers={n_workers}")
        for _ in range(self._scale_min if autoscale else n_workers):
            self._spawn()

    def _spawn(self) -> None:
        parent_conn, child_conn = self._ctx.Pipe()
        # The mesh's device count must reach the child's XLA before its
        # backend initializes; setting it in the *parent's* environment
        # around the spawn is the only ordering that beats every module the
        # child bootstrap may import.
        old_flags = os.environ.get("XLA_FLAGS")
        if self.spec.mesh is not None:
            # append AFTER any inherited flags: XLA takes the last
            # occurrence of a repeated flag, and this repo's own tooling
            # (dryrun, test_distributed) exports its own device-count flag
            os.environ["XLA_FLAGS"] = (
                (f"{old_flags} " if old_flags else "")
                + f"--xla_force_host_platform_device_count="
                  f"{self.spec.mesh.device_count}")
        try:
            proc = self._ctx.Process(target=worker_loop,
                                     args=(child_conn, self.spec),
                                     daemon=True)
            proc.start()
        finally:
            if self.spec.mesh is not None:
                if old_flags is None:
                    os.environ.pop("XLA_FLAGS", None)
                else:
                    os.environ["XLA_FLAGS"] = old_flags
        child_conn.close()
        self._peers.append(_PipePeer(proc, parent_conn))

    def _refill(self, pending: Deque[int]) -> None:
        if self._respawn and self._respawns_left > 0:
            self._respawns_left -= 1
            self.respawns += 1
            self._spawn()

    def _scale_up(self) -> bool:
        if len(self._peers) >= self._scale_max:
            return False
        self._spawn()
        self.scale_ups += 1
        return True

    @property
    def pids(self) -> List[int]:
        return [p.proc.pid for p in self._peers if p.alive]

    def close(self) -> None:
        if self._closed:
            return
        peers = list(self._peers)
        super().close()                     # stop + close (join 5s each)
        for p in peers:                     # stragglers get the axe
            if p.proc.is_alive():
                p.proc.terminate()
                p.proc.join(timeout=2.0)


def run_process_fleet(emulator: Emulator, profiles, *, max_workers: int = 4,
                      mesh_spec=None, flops_scale: float = 1.0,
                      storage_scale: float = 1.0, mem_scale: float = 1.0,
                      verify: bool = True, timeout: float = 600.0,
                      fleet: Optional[ProcessFleet] = None,
                      window: Optional[int] = None, autoscale: bool = False,
                      min_workers: Optional[int] = None,
                      collect: str = "reports") -> FleetReport:
    """Compile → detach → ship, streamed: one-call process-fleet replay.

    Backs ``Emulator.emulate_many(executor="process")``.  ``profiles`` may
    be any iterable — a list or a lazy source like
    ``ProfileStore.stream(...)``: compilation happens as the scheduler
    pulls, at most ``window`` bundles ahead of dispatch, so coordinator
    memory is bounded by the window even for a production day's worth of
    profiles.  Pass ``fleet`` to reuse a warm ``ProcessFleet`` (the caller
    keeps ownership); otherwise a pool sized ``min(max_workers,
    len(profiles))`` (or starting at ``min_workers`` when ``autoscale``)
    is spawned and torn down around this one run.  With ``mesh_spec`` set,
    wire-byte runs compile to mesh-bound fused segments and every worker
    builds its own mesh — collective legs move bytes inside the workers'
    segment scans.  ``collect="totals"`` drops per-profile reports and
    returns aggregates only (the bounded-memory soak mode).
    """
    n_samples = {"n": 0}                 # true profile samples compiled

    def _bundles():
        for p in profiles:
            b = bundle_profile(emulator, p, mesh_spec=mesh_spec,
                               flops_scale=flops_scale,
                               storage_scale=storage_scale,
                               mem_scale=mem_scale, verify=verify)
            n_samples["n"] += b.n_profile_samples
            yield b

    own = fleet is None
    if own:
        n = len(profiles) if hasattr(profiles, "__len__") else None
        workers = max(1, min(max_workers, n)) if n is not None \
            else max(1, max_workers)
        fleet = ProcessFleet(workers, WorkerSpec(emulator=emulator.spec(),
                                                 mesh=mesh_spec),
                             autoscale=autoscale, min_workers=min_workers)
    t0 = time.perf_counter()
    fold = ReportFold(keep_reports=collect != "totals")
    try:
        for idx, rep in fleet.stream(_bundles(), timeout=timeout,
                                     window=window):
            fold.add(idx, rep)
        stats = {"workers": fleet.n_workers,
                 "worker_deaths": fleet.worker_deaths,
                 "respawns": fleet.respawns}
        scaling = dict(fleet.last_scaling)
        n_workers = fleet.n_workers
    finally:
        if own:
            fleet.close()
    wall = time.perf_counter() - t0
    return FleetReport(
        reports=fold.reports, wall_s=wall, serial_s=fold.serial_s,
        max_workers=n_workers, cache_stats=stats, totals=fold.totals,
        n_samples=n_samples["n"], n_replayed=fold.n_done, scaling=scaling)
