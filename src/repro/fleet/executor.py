"""Fleet executors: replay schedule bundles on pools of remote peers.

Two layers live here.  ``FleetBase`` is the transport-agnostic scheduler:
it owns the pending queue, the one-bundle-per-worker-slot dispatch loop,
the per-bundle attempt budget (a bundle that keeps killing workers is
declared poison instead of looping forever), the run deadline, and the
reap-requeue-refill dance when a peer dies.  It schedules ``Peer``
objects — anything with worker slots that can ``dispatch`` a bundle and
``recv`` a normalized reply — and never touches a pipe or a socket
itself.

The interchange is an *iterator of bundles*, not a list: ``stream()``
pulls from the source only while fewer than ``window`` bundles are
pulled-but-unfinished, so a lazy source (a generator compiling profiles
on the fly, ``ProfileStore.stream`` feeding ``bundle_profile``) is
backpressured by the workers — the coordinator never materializes more
than a window's worth of compiled schedules no matter how long the
stream is.  ``run()`` is the materializing wrapper (list in, ordered
list of reports out) kept for warm-pool callers and tests.

``FleetBase`` also owns admission control and fleet *elasticity*: with
autoscaling enabled, queued bundles outnumbering free slots grows the
pool one peer per scheduler pass (``_scale_up`` — ProcessFleet spawns a
worker, RemoteFleet's open listener admits late joiners), and once the
source is exhausted idle peers are retired back down to the floor
(``_retire``).  Scale events and high-water marks are recorded in
``last_scaling`` and surfaced through ``FleetReport.scaling``.

``ProcessFleet`` is the local instantiation: each peer is one spawn-based
worker process (see ``repro.fleet.worker``) behind a multiprocessing
``Pipe``, with its own jax client, emulator, jitted programs, and — when
the ``WorkerSpec`` carries a ``MeshSpec`` — its own device mesh.
``repro.fleet.transport.remote.RemoteFleet`` is the network
instantiation: each peer is a TCP connection to a host agent that fronts
several such worker processes on another machine.  Both inherit the same
scheduling semantics, which is the point — a dead TCP peer is reaped
exactly like a dead process, and its in-flight bundles requeue onto the
survivors.

Scheduling is work-stealing-simple: one in-flight bundle per worker slot,
next bundle to the first slot that frees up, so a straggler profile never
blocks the rest of the fleet.  Only when no peer is left alive (and none
can be refilled) with work still pending does a run raise.

Liveness is layered on top of I/O-error detection: workers and agents
whose spec sets ``heartbeat_s`` send periodic ``("ping",)`` frames, every
received message refreshes the peer's ``last_seen`` watermark, and a peer
that has in-flight work but has been silent past ``liveness_timeout`` is
reaped as *hung* — its bundles requeue exactly like a dead peer's,
instead of stalling the run to the global deadline.  ``speculate=p``
adds per-bundle soft timeouts: once the pending queue is empty, a bundle
in flight past ``p × median`` completion time is re-dispatched to a free
slot and the first result wins (the epoch/attempt machinery already
discards the loser).  Respawn after a death backs off exponentially
(jittered by a seeded, chaos-safe RNG) and a spec that keeps dying trips
``CrashLoopError`` instead of silently burning the respawn budget.
``on_failure="skip"`` turns worker-reported bundle failures and
exhausted attempt budgets into *skipped indices* rather than a raised
stream; either way ``last_recovery`` records what every fault cost
(requeue latency, lost replay work, MTTR, skips, speculation, heartbeat
volume) and surfaces as ``FleetReport.recovery``.

Bundles may carry dependency edges (``ScheduleBundle.parents``: stream
indices of earlier bundles).  ``stream`` then becomes a *frontier*
scheduler: an edged bundle is admitted into the window but enters the
pending queue only when every parent's result has landed, so a
fork-join sink can never race its branches no matter how many slots are
free.  Edges compose with the whole hardening stack — a killed parent
requeues and its children simply stay blocked until the retry lands,
and under ``on_failure="skip"`` a skipped parent *cascades*: every
transitively-blocked descendant is skipped too (reason ``"ancestor"``,
tallied separately in ``last_recovery["skipped_ancestor"]``) instead of
deadlocking the stream.  Edge-free bundles take the exact pre-DAG code
path, so linear streams replay bit-identically.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import statistics
import time
from collections import deque
from multiprocessing import connection as mp_conn
from random import Random
from dataclasses import dataclass
from typing import (Callable, Deque, Dict, Iterable, Iterator, List,
                    Optional, Set, Tuple)

from repro.core.emulator import (EmulationReport, Emulator, FleetReport,
                                 ReportFold)
from repro.fleet.bundle import (ScheduleBundle, WorkerSpec, bundle_parents,
                                bundle_profile)
from repro.fleet.chaos import ChaosPolicy
from repro.fleet.dag import critical_path, validate_parents
from repro.fleet.worker import worker_loop
from repro.obs import clock as obs_clock
from repro.obs.clock import ClockSync
from repro.obs.metrics import MetricsRegistry
from repro.obs.recorder import FlightRecorder, ObsFrame

_MAX_ATTEMPTS = 3          # dispatches per bundle before declaring it poison


class PeerGone(Exception):
    """The peer (worker process or remote agent) is dead or unreachable:
    reap it, requeue its in-flight bundles, keep draining on survivors."""


class CrashLoopError(RuntimeError):
    """A peer spec is dying repeatedly within the crash-loop window: the
    spec (not the luck) is the problem — stop respawning and say so
    loudly instead of exhausting ``max_respawns`` in silence."""


@dataclass(frozen=True)
class BundleTiming:
    """Per-bundle lifecycle stamps from one ``stream`` (``time.monotonic``
    clock).  ``queue_s`` is the *total* time the bundle sat in the pending
    queue — its initial wait plus every post-fault requeue wait — while
    ``replay_s`` is measured from the *last* dispatch only, so a chaos
    requeue never inflates the replay figure (the queueing-delay metric a
    serving layer builds on this stays honest under faults).  A skipped
    bundle reports ``ok=False`` with ``replay_s=0.0``; ``dispatched`` is
    ``None`` when the bundle never reached a worker."""

    enqueued: float             # admitted into the pending queue
    dispatched: Optional[float]  # last handed to a worker (None: never)
    done: float                 # result yielded (or bundle skipped)
    queue_s: float              # total pending-queue residency
    replay_s: float             # done - last dispatch (0.0 if skipped)
    attempts: int               # dispatch attempts consumed
    ok: bool                    # False: skipped under on_failure="skip"


class Peer:
    """One schedulable fleet endpoint with ``capacity`` worker slots.

    ``tasks`` is the in-flight set of ``(dispatch epoch, bundle index)``
    pairs — epoch-qualified so a new run re-dispatching an index can never
    collide with a stale entry for the same index.  Entries from a
    *raised* run (stale epoch) stay until their late results arrive: they
    keep the slot occupied — the worker really is still busy — and the
    scheduler recognizes them by epoch, drops their results, and only
    then reuses the slot.  Subclasses translate their wire format into the
    normalized message tuples the scheduler consumes:

      ("ready", info)                 peer finished initializing
      ("ok",    epoch, idx, report)   bundle replayed
      ("retry", epoch, idx, reason)   peer-side worker died; requeue the
                                      bundle (its dispatch attempt stays
                                      counted, so poison budgets hold)
      ("err",   epoch, idx, tb)       bundle failed (idx=None: init died)
      ("ping",)                       heartbeat: refreshes ``last_seen``

    ``last_seen`` is the liveness watermark: the scheduler stamps it on
    every received message (heartbeats included) and on every dispatch
    (handing a peer work restarts its window), and a busy-but-silent
    peer past ``liveness_timeout`` is reaped as hung.
    """

    capacity = 1

    def __init__(self):
        self.tasks: Set[Tuple[int, int]] = set()
        self.ready = False
        self.last_seen = time.monotonic()
        #: flight-recorder track name; transports set the real one
        #: (ProcessFleet: the spawn scope "worker:<n>")
        self.scope = "peer"
        #: per-peer clock-offset estimator, refined by the echo carried
        #: on every ObsFrame this peer ships home
        self.sync = ClockSync()

    @property
    def free_slots(self) -> int:
        return self.capacity - len(self.tasks)

    def epoch_for(self, idx: int) -> Optional[int]:
        """The dispatch epoch of in-flight bundle ``idx`` — for adapters
        whose wire protocol doesn't echo epochs (capacity-1 pipes hold at
        most one entry, so the lookup is unambiguous there)."""
        return next((e for (e, i) in self.tasks if i == idx), None)

    @property
    def alive(self) -> bool:
        """Cheap local liveness; transports without one return True and
        let death surface as ``PeerGone`` on I/O."""
        return True

    @property
    def waitable(self):
        """Object for ``multiprocessing.connection.wait``."""
        raise NotImplementedError

    def dispatch(self, epoch: int, idx: int, bundle: ScheduleBundle) -> None:
        raise NotImplementedError

    def recv(self):
        raise NotImplementedError

    def stop(self) -> None:
        """Best-effort polite shutdown request; never raises."""

    def close(self) -> None:
        """Tear down the endpoint; never raises."""

    def destroy(self) -> None:
        """Tear down a peer known to be *hung*: no grace a wedged
        endpoint will never honor.  Default: same as ``close``."""
        self.close()

    def describe(self) -> str:
        return "fleet peer"


class FleetBase:
    """Transport-agnostic bundle scheduler over a pool of ``Peer``s.

    Subclasses populate ``self._peers`` and may override ``_refill`` (to
    respawn replacements after a death), ``_scale_up`` (to grow the pool
    when autoscaling), ``_extra_waitables`` / ``_handle_extra`` (to
    service non-peer readiness, e.g. accepting new agents mid-run),
    ``_assemble`` (to gate a run on initial pool assembly), and
    ``_warming`` (to gate warmup on a minimum pool size).
    ``worker_deaths`` counts reaped peers across the pool's lifetime;
    ``scale_ups``/``scale_downs`` count elasticity events the same way,
    and ``last_scaling`` holds the most recent stream's high-water marks.
    """

    def __init__(self):
        self._peers: List[Peer] = []
        self._closed = False
        self._epoch = 0
        self.worker_deaths = 0
        self.hung_reaped = 0
        self.scale_ups = 0
        self.scale_downs = 0
        #: elasticity policy; subclasses flip these (ProcessFleet ctor,
        #: RemoteFleet ctor) — base default is a fixed-size pool
        self._autoscale = False
        self._scale_min = 1
        #: high-water marks / event counts of the most recent stream
        self.last_scaling: Dict[str, int] = {}
        #: fault-recovery accounting of the most recent stream
        self.last_recovery: Dict = {}
        #: indices skipped because an *ancestor* was skipped (cascade
        #: holes, not direct poison) — updated live during the stream so
        #: a consumer folding ``(idx, None)`` announcements can classify
        #: each hole the moment it is yielded
        self.last_ancestor_skips: Set[int] = set()
        #: MTTR bookkeeping: death times of faults a refill will repair,
        #: popped when the replacement reports ready (approximate when a
        #: scale-up races an outstanding respawn, exact otherwise)
        self._fault_opened: Deque[float] = deque()
        self._mttr_samples: List[float] = []
        #: closed fault windows as ``(opened, repaired)`` monotonic stamps
        #: — the joinable form of ``_mttr_samples`` (the SLO engine lines
        #: these up against the latency timeline for chaos attribution)
        self.fault_events: List[Tuple[float, float]] = []
        #: coordinator flight recorder: the merge target for every
        #: worker/agent frame that ships home (``repro.obs``)
        self.recorder = FlightRecorder("coordinator")
        #: Prometheus-style registry; scraped by ``repro.service`` and
        #: snapshotted into ``FleetReport.obs``
        self.metrics = MetricsRegistry()
        self._m_dispatch = self.metrics.counter(
            "repro_fleet_dispatch_total", "bundle dispatches")
        self._m_requeue = self.metrics.counter(
            "repro_fleet_requeue_total", "bundles returned for retry")
        self._m_deaths = self.metrics.counter(
            "repro_fleet_worker_deaths_total", "reaped peers")
        self._m_heartbeats = self.metrics.counter(
            "repro_fleet_heartbeats_total", "liveness pings observed")
        self._m_done = self.metrics.counter(
            "repro_fleet_done_total", "bundles completed")
        self._m_skip = self.metrics.counter(
            "repro_fleet_skip_total", "bundles skipped (degraded mode)")
        self._m_scale = self.metrics.counter(
            "repro_fleet_scale_events_total", "elasticity events")
        self._m_workers = self.metrics.gauge(
            "repro_fleet_workers", "current worker slots")
        self._m_replay = self.metrics.histogram(
            "repro_fleet_replay_seconds", "dispatch-to-result latency")
        self._m_queue = self.metrics.histogram(
            "repro_fleet_queue_seconds", "pending-queue residency")

    def _absorb_frame(self, peer: Peer, frame: Optional[ObsFrame]) -> None:
        """Merge a piggybacked worker/agent buffer onto the coordinator
        timeline: fold the frame's clock echo into the peer's offset
        estimate, then rebase every event through it."""
        if frame is None:
            return
        t_recv = obs_clock.now()
        if frame.echo_t is not None:
            peer.sync.observe(frame.echo_t, frame.sent_at, t_recv)
        self.recorder.absorb(
            frame, peer.sync.to_local if peer.sync.synced else None)

    def obs_snapshot(self, last_n: Optional[int] = None) -> Dict:
        """The ``FleetReport.obs`` payload: merged timeline (bounded),
        drop accounting, metrics snapshot."""
        snap = self.recorder.snapshot(last_n)
        snap["metrics"] = self.metrics.snapshot()
        return snap

    # -- pool plumbing ------------------------------------------------------

    def _reap(self, peer: Peer, pending: Deque[int],
              epoch: Optional[int] = None, *, hung: bool = False) -> None:
        """A peer died: requeue its in-flight bundles (only those belonging
        to the current run — stragglers from a raised run are dropped),
        then refill the pool.  ``hung`` peers get no teardown grace."""
        self.worker_deaths += 1
        self._m_deaths.inc()
        self.recorder.record("fault_opened", peer=peer.scope,
                             hung=hung,
                             in_flight=sorted(i for _, i in peer.tasks))
        for e, idx in peer.tasks:
            if epoch is not None and e == epoch:
                pending.appendleft(idx)
        peer.tasks.clear()
        if hung:
            peer.destroy()
        else:
            peer.close()
        self._peers.remove(peer)
        self._refill(pending)

    def _refill(self, pending: Deque[int]) -> None:
        """Hook: replace a reaped peer if the transport can."""

    def _tick(self, pending: Deque[int]) -> None:
        """Hook: service deferred pool work each scheduler pass (the
        backoff respawn queue, for transports that have one)."""

    def _pending_refill(self) -> bool:
        """Hook: is a deferred replacement (backoff respawn) still due?
        While True, an empty pool is *recovering*, not dead."""
        return False

    def _note_ready(self) -> None:
        """A peer reported ready: close the oldest open fault's MTTR
        window, if a refill was outstanding."""
        if self._fault_opened:
            opened = self._fault_opened.popleft()
            now = obs_clock.now()
            self._mttr_samples.append(now - opened)
            self.fault_events.append((opened, now))
            self.recorder.record("fault_repaired", mttr_s=now - opened)

    def _scale_up(self) -> bool:
        """Hook: add one peer of capacity (autoscale).  Returns True if the
        pool grew.  The base pool cannot grow."""
        return False

    def _retire(self, peer: Peer) -> None:
        """Politely release an idle peer (autoscale down).  Not a death:
        no requeue, no refill, no ``worker_deaths``."""
        peer.stop()
        if hasattr(peer, "drain_obs"):
            self._absorb_frame(peer, peer.drain_obs(0.2))
        peer.close()
        self._peers.remove(peer)
        self.scale_downs += 1
        self._m_scale.inc(direction="down")
        self.recorder.record("scale_down", peer=peer.scope)

    def _assemble(self, timeout: float) -> None:
        """Hook: block until the initial pool is usable (RemoteFleet gates
        the first stream on its join quorum here)."""

    def _extra_waitables(self) -> List:
        return []

    def _handle_extra(self, obj) -> None:
        raise NotImplementedError(f"unexpected waitable {obj!r}")

    def _close_extras(self) -> None:
        pass

    def _wait(self, timeout: float, *, ready_only: bool = False) -> List:
        conns = [p.waitable for p in self._peers
                 if not (ready_only and p.ready)]
        conns += self._extra_waitables()
        return mp_conn.wait(conns, timeout=timeout) if conns else []

    def _peer_for(self, obj) -> Optional[Peer]:
        return next((p for p in self._peers if p.waitable is obj), None)

    def _warming(self) -> bool:
        return any(p.alive and not p.ready for p in self._peers)

    # -- lifecycle ----------------------------------------------------------

    def warmup(self, timeout: float = 120.0) -> List[Dict]:
        """Block until every live peer reported ready (and any subclass
        minimum-pool condition holds); returns their ready infos.  Not
        required before ``run`` (dispatches queue in the transport), but
        useful to separate spawn/connect/trace cost from replay cost —
        ``benchmarks/bench_fleet.py`` does exactly that."""
        deadline = time.monotonic() + timeout
        infos: List[Dict] = []
        while self._warming() or (not self._peers and self._pending_refill()):
            if time.monotonic() > deadline:
                raise TimeoutError("fleet workers did not become ready "
                                   f"within {timeout}s")
            self._tick(deque())
            evs = self._wait(0.5, ready_only=True)
            if not evs and not self._peers:
                time.sleep(0.05)      # backoff respawn still pending
            for obj in evs:
                peer = self._peer_for(obj)
                if peer is None:
                    self._handle_extra(obj)
                    continue
                try:
                    msg = peer.recv()
                except PeerGone:
                    self._reap(peer, deque())
                    continue
                peer.last_seen = time.monotonic()
                if msg[0] == "ready":
                    peer.ready = True
                    self._note_ready()
                    infos.append(msg[1])
                elif msg[0] == "err":
                    raise RuntimeError(
                        f"fleet worker failed to initialize:\n{msg[-1]}")
                # "ping": watermark refreshed above, nothing else to do
        if not self._peers:
            raise RuntimeError("no fleet worker survived initialization")
        return infos

    # -- execution ----------------------------------------------------------

    def stream(self, bundles: Iterable[ScheduleBundle], *,
               timeout: float = 600.0, window: Optional[int] = None,
               max_attempts: Optional[int] = None,
               liveness_timeout: Optional[float] = None,
               speculate: Optional[float] = None,
               on_failure: str = "raise",
               record_timing: Optional[
                   Callable[[int, BundleTiming], None]] = None,
               idle_retire_s: Optional[float] = None
               ) -> Iterator[Tuple[int, EmulationReport]]:
        """Replay a (possibly lazy) bundle source; yields ``(idx, report)``
        pairs in completion order.

        This is the iterator-of-bundles contract: the source is pulled
        only while fewer than ``window`` bundles are outstanding (pulled
        but unfinished), so a source that compiles on ``next()`` is
        backpressured by worker throughput and coordinator memory stays
        bounded by the window, not the stream length.  ``window=None``
        tracks the pool at ``2 × worker slots`` (recomputed as the pool
        scales), keeping every slot fed while leaving queue depth visible
        to the autoscaler.

        *Arrival-time admission*: the source may yield ``None`` to say
        "nothing available right now" — the scheduler stops admitting for
        this pass but keeps dispatching/collecting, and asks again on the
        next pass.  That turns a pre-built iterator contract into an
        open-loop one: a standing serve loop backed by a live queue
        (``repro.service.standing``) yields ``None`` while the queue is
        empty and raises ``StopIteration`` only on drain/close.

        *Dependency edges*: a bundle whose ``parents`` tuple is
        non-empty is admitted (it occupies a window slot) but joins the
        pending queue only once every parent's result has been yielded —
        the dispatchable *frontier*.  Parents must reference earlier
        stream indices; forward/self references (the only way to express
        a cycle, since indices are assigned in arrival order) raise
        ``ValueError`` at admission instead of deadlocking.  Queue time
        starts at *release*, not admission, so ``BundleTiming.queue_s``
        never charges a child for its parents' replay.  A requeued
        (killed/hung) parent keeps its children blocked until the retry
        lands; a *skipped* parent (``on_failure="skip"``) cascades — all
        transitively-blocked descendants are skipped as ``(idx, None)``
        with reason ``"ancestor"`` and counted in
        ``last_recovery["skipped_ancestor"]`` (and live in
        ``last_ancestor_skips``), distinct from direct poison.
        Edge-free bundles take the identical pre-DAG path bit for bit.

        Hardening knobs:

        * ``max_attempts`` — per-bundle dispatch budget before the bundle
          is declared poison (default ``_MAX_ATTEMPTS`` = 3).
        * ``liveness_timeout`` — a *ready* peer holding in-flight work
          that has been silent this long is reaped as hung (requeue, no
          teardown grace).  Pair with a heartbeating spec: without
          heartbeats a worker legitimately busy on a long bundle is
          indistinguishable from a wedged one.
        * ``speculate=p`` — once the pending queue is empty, a bundle in
          flight past ``p ×`` the median completion time (of the last 64
          completions, needs ≥ 3 samples) is re-dispatched to a free
          slot; first result wins, the loser's late reply is discarded by
          the epoch/held machinery.  Costs one attempt from the budget.
        * ``on_failure="skip"`` — a worker-reported bundle failure or an
          exhausted attempt budget *skips* that bundle instead of
          raising, and the stream keeps draining.  A skipped bundle is
          announced as ``(idx, None)`` so a consumer folding in index
          order can advance past the hole promptly (and is recorded in
          ``last_recovery["skipped"]``).
        * ``record_timing`` — callback invoked once per bundle (just
          before its result is yielded, or when it is skipped) with
          ``(idx, BundleTiming)``: separate enqueue/dispatch/done stamps
          plus honest queue-vs-replay split (a post-fault requeue charges
          queue time, never replay time).
        * ``idle_retire_s`` — autoscale only: when the pending queue
          stays below the pool floor (``min_workers``) for this long
          mid-stream, one idle worker is retired per elapsed window (the
          pool never drops below the floor).  Defaults to
          ``liveness_timeout`` when armed, so "a full liveness window of
          low queue depth" is the retire signal; with neither set,
          mid-stream scale-down is off and only the drain-time retire
          runs.  Retires are counted in ``last_scaling`` under both
          ``scale_downs`` and ``midstream_downs``.

        Raises RuntimeError on a peer-reported replay failure or poison
        bundle (under ``on_failure="raise"``), ``CrashLoopError`` when
        the transport's breaker trips, RuntimeError when the whole pool
        is dead (with no respawn due) and work is still pending;
        TimeoutError past the deadline.  Completed bundles are dropped as
        their reports are yielded — a raised stream's stragglers are
        recognized by their stale epoch in later runs, exactly like
        ``run``'s.
        """
        if self._closed:
            raise RuntimeError(f"{type(self).__name__} is closed")
        if on_failure not in ("raise", "skip"):
            raise ValueError(f"on_failure must be 'raise' or 'skip', "
                             f"got {on_failure!r}")
        max_att = _MAX_ATTEMPTS if max_attempts is None else int(max_attempts)
        if max_att < 1:
            raise ValueError(f"max_attempts must be >= 1, got {max_attempts}")
        if speculate is not None and speculate < 1.0:
            raise ValueError("speculate is a multiple of the median "
                             f"completion time and must be >= 1.0, "
                             f"got {speculate}")
        self._assemble(timeout)
        # A raised run (worker error, poison bundle, timeout) leaves
        # stragglers replaying on live peers.  Each run gets a fresh
        # epoch: stragglers' late results are recognized by their stale
        # epoch, discarded, and merely free their slot — they are never
        # yielded into this run and never block dispatch forever.
        self._epoch += 1
        epoch = self._epoch
        source = iter(bundles)
        exhausted = False
        next_idx = 0
        held: Dict[int, ScheduleBundle] = {}   # pulled, result not yielded
        pending: Deque[int] = deque()
        attempts: Dict[int, int] = {}
        deadline = time.monotonic() + timeout
        base_ups, base_downs = self.scale_ups, self.scale_downs
        base_deaths, base_hung = self.worker_deaths, self.hung_reaped
        base_mttr = len(self._mttr_samples)
        base_fev = len(self.fault_events)
        peak_workers = peak_queue = peak_window = 0
        midstream_downs = 0
        low_q_since: Optional[float] = None  # dwell timer for idle retire
        retire_s = idle_retire_s if idle_retire_s is not None \
            else liveness_timeout
        # -- recovery accounting (this stream only) --------------------------
        disp_at: Dict[int, float] = {}       # idx -> latest dispatch time
        requeue_ts: Dict[int, float] = {}    # idx -> when it re-entered pending
        # -- per-bundle lifecycle stamps (BundleTiming) ----------------------
        enq_at: Dict[int, float] = {}        # idx -> admission time
        q_since: Dict[int, float] = {}       # idx -> entered pending (latest)
        q_wait: Dict[int, float] = {}        # idx -> accumulated queue time
        done_times: List[float] = []         # dispatch->ok latencies
        skipped: List[int] = []
        # -- dependency frontier (bundles with parents edges) ----------------
        blocked: Dict[int, Set[int]] = {}    # idx -> unmet parent idxs
        dependants: Dict[int, List[int]] = {}  # parent -> blocked children
        completed: Set[int] = set()          # idxs whose result was yielded
        skipped_set: Set[int] = set()        # fast ancestor-doom lookup
        anc_skipped: List[int] = []          # cascade holes, not poison
        self.last_ancestor_skips = set()
        requeued = 0
        requeue_wait = 0.0
        requeue_waits = 0
        lost_replay = 0.0
        spec_extra: Set[int] = set()         # idxs with a live second copy
        spec_peer: Dict[int, Peer] = {}      # idx -> its speculative peer
        spec_dispatches = spec_wins = 0
        pings = 0

        def account_requeue(peer: Peer, now: float) -> None:
            """Charge a dying/hung peer's current-epoch work before _reap
            requeues it: count the requeue and the replay time lost."""
            nonlocal requeued, lost_replay
            for e, i in peer.tasks:
                if e == epoch and i in held:
                    requeued += 1
                    self._m_requeue.inc()
                    self.recorder.record("requeue", idx=i,
                                         reason="peer-died",
                                         peer=peer.scope)
                    t = disp_at.pop(i, None)
                    if t is not None:
                        lost_replay += now - t
                    requeue_ts[i] = now
                    q_since[i] = now        # back in the queue: the clock
                    # charges queue time again, never replay time

        def skip(idx: int, ancestor: Optional[int] = None) -> None:
            now = obs_clock.now()
            skipped.append(idx)
            skipped_set.add(idx)
            self._m_skip.inc()
            if ancestor is None:
                self.recorder.record("skip", idx=idx)
            else:
                # a cascade hole: this bundle never failed — a bundle it
                # (transitively) depends on did
                anc_skipped.append(idx)
                self.last_ancestor_skips.add(idx)
                self.recorder.record("skip", idx=idx, reason="ancestor",
                                     parent=ancestor)
            blocked.pop(idx, None)
            held.pop(idx, None)
            att = attempts.pop(idx, None)
            t = disp_at.pop(idx, None)
            spec_extra.discard(idx)
            spec_peer.pop(idx, None)
            requeue_ts.pop(idx, None)
            qw = q_wait.pop(idx, 0.0)
            qs = q_since.pop(idx, None)
            if qs is not None:              # skipped while still queued
                qw += now - qs
            enq = enq_at.pop(idx, now)
            if record_timing is not None:
                record_timing(idx, BundleTiming(
                    enqueued=enq, dispatched=t, done=now, queue_s=qw,
                    replay_s=0.0, attempts=att or 0, ok=False))

        def doomed(idx: int) -> List[int]:
            """Descendants transitively blocked on a just-skipped ``idx``
            — they can never dispatch, so the caller skips them too.  A
            multi-parent child reached through a second doomed parent is
            guarded by the ``blocked`` membership test (it was already
            unblocked-by-doom the first time)."""
            out: List[int] = []
            frontier = [idx]
            while frontier:
                p = frontier.pop(0)
                for c in sorted(dependants.pop(p, ())):
                    if c in blocked:
                        del blocked[c]
                        out.append(c)
                        frontier.append(c)
            return sorted(out)

        try:
            while True:
                # -- admission: compile-ahead at most `window` bundles ----
                cap = sum(p.capacity for p in self._peers) or 1
                win = window if window is not None else max(2 * cap, 2)
                saw_none = False
                while not exhausted and len(held) < win:
                    try:
                        b = next(source)
                    except StopIteration:
                        exhausted = True
                        break
                    if b is None:
                        # open-loop source: nothing has arrived yet — stop
                        # admitting this pass, keep the scheduler turning
                        saw_none = True
                        break
                    idx = next_idx
                    next_idx += 1
                    parents = bundle_parents(b)
                    if parents:
                        parents = validate_parents(
                            idx, parents, getattr(b, "command", ""))
                    now = obs_clock.now()
                    if any(p in skipped_set for p in parents):
                        # doomed on arrival: an ancestor is already a
                        # hole — announce this one immediately
                        anc = next(p for p in sorted(parents)
                                   if p in skipped_set)
                        enq_at[idx] = now
                        self.recorder.record("enqueue", idx=idx,
                                             parents=list(parents))
                        skip(idx, ancestor=anc)
                        yield idx, None
                        continue
                    held[idx] = b
                    attempts[idx] = 0
                    enq_at[idx] = now
                    unmet = {p for p in parents if p not in completed}
                    if unmet:
                        # admitted but not dispatchable: enters pending
                        # only when the last parent's result lands —
                        # q_since stamps at *release*, so queue_s never
                        # charges a child for its parents' replay
                        blocked[idx] = unmet
                        for p in unmet:
                            dependants.setdefault(p, []).append(idx)
                        self.recorder.record("enqueue", idx=idx,
                                             parents=list(parents))
                        self.recorder.record("dep_wait", idx=idx,
                                             unmet=sorted(unmet))
                        continue
                    pending.append(idx)
                    q_since[idx] = now
                    if parents:
                        self.recorder.record("enqueue", idx=idx,
                                             parents=list(parents))
                    else:
                        self.recorder.record("enqueue", idx=idx)
                if exhausted and not held:
                    break
                peak_window = max(peak_window, len(held))
                peak_queue = max(peak_queue, len(pending))
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"fleet run exceeded {timeout}s with {len(held)} "
                        "bundle(s) unfinished")
                self._tick(pending)    # service due backoff respawns
                # -- dispatch to free slots (death noticed on send is
                # handled exactly like death noticed on receive)
                for peer in list(self._peers):
                    while pending and peer.free_slots > 0:
                        if not peer.alive:
                            account_requeue(peer, time.monotonic())
                            self._reap(peer, pending, epoch)
                            break
                        idx = pending.popleft()
                        if idx not in held:
                            # completed by a speculative twin or skipped
                            # while it waited in the queue — nothing to do
                            continue
                        if attempts[idx] >= max_att:
                            if on_failure == "skip":
                                skip(idx)
                                yield idx, None
                                for c in doomed(idx):
                                    skip(c, ancestor=idx)
                                    yield c, None
                                continue
                            raise RuntimeError(
                                f"bundle {idx} ({held[idx].command!r}) "
                                f"failed {attempts[idx]} dispatch attempts "
                                "— poison bundle, aborting the fleet run")
                        attempts[idx] += 1
                        try:
                            peer.dispatch(epoch, idx, held[idx])
                        except PeerGone:
                            pending.appendleft(idx)
                            attempts[idx] -= 1
                            account_requeue(peer, time.monotonic())
                            self._reap(peer, pending, epoch)
                            break
                        now = obs_clock.now()
                        disp_at[idx] = now
                        self._m_dispatch.inc()
                        self.recorder.record("dispatch", idx=idx,
                                             peer=peer.scope,
                                             attempt=attempts[idx])
                        # a dispatch is an interaction: restart the liveness
                        # window, or a peer idle longer than the timeout
                        # would be reaped the moment it got new work
                        peer.last_seen = now
                        t = requeue_ts.pop(idx, None)
                        if t is not None:
                            requeue_wait += now - t
                            requeue_waits += 1
                        qs = q_since.pop(idx, None)
                        if qs is not None:
                            q_wait[idx] = q_wait.get(idx, 0.0) + (now - qs)
                # -- elasticity: queue depth drives the pool size ---------
                if self._autoscale:
                    if pending and not any(p.alive and p.free_slots > 0
                                           for p in self._peers):
                        if self._scale_up():
                            self._m_scale.inc(direction="up")
                            self.recorder.record(
                                "scale_up", workers=len(self._peers))
                        low_q_since = None
                    elif exhausted and not pending:
                        # long tail: peers that already drained go idle
                        # while stragglers finish — release them early
                        idle = [p for p in self._peers if not p.tasks]
                        for p in idle[:len(self._peers) - self._scale_min]:
                            self._retire(p)
                    elif retire_s is not None \
                            and len(pending) < self._scale_min \
                            and len(self._peers) > self._scale_min:
                        # mid-stream scale-down: queue depth has stayed
                        # below the pool floor for a full window — a
                        # standing fleet between load peaks sheds one idle
                        # worker per elapsed window instead of holding its
                        # storm-sized pool until drain
                        now_e = time.monotonic()
                        if low_q_since is None:
                            low_q_since = now_e
                        elif now_e - low_q_since >= retire_s:
                            victim = next(
                                (p for p in self._peers
                                 if p.ready and not p.tasks), None)
                            if victim is not None:
                                self._retire(victim)
                                midstream_downs += 1
                            low_q_since = now_e
                    else:
                        low_q_since = None
                cap_now = sum(p.capacity for p in self._peers)
                peak_workers = max(peak_workers, cap_now)
                self._m_workers.set(cap_now)
                # -- liveness: reap hung-but-connected peers --------------
                if liveness_timeout is not None:
                    now = time.monotonic()
                    for peer in list(self._peers):
                        # only *ready* peers: a still-warming worker is
                        # paying its jax-import bill, not hanging
                        if peer.ready and peer.tasks \
                                and now - peer.last_seen > liveness_timeout:
                            self.hung_reaped += 1
                            account_requeue(peer, now)
                            self._reap(peer, pending, epoch, hung=True)
                # -- speculation: soft per-bundle timeout -----------------
                if speculate is not None and not pending \
                        and len(done_times) >= 3:
                    median = statistics.median(done_times[-64:])
                    threshold = speculate * median
                    now = time.monotonic()
                    for peer in list(self._peers):
                        for e, idx in list(peer.tasks):
                            if (e != epoch or idx not in held
                                    or idx in spec_extra
                                    or attempts[idx] >= max_att
                                    or now - disp_at.get(idx, now)
                                    <= threshold):
                                continue
                            twin = next(
                                (p for p in self._peers
                                 if p is not peer and p.alive and p.ready
                                 and p.free_slots > 0), None)
                            if twin is None:
                                continue
                            attempts[idx] += 1
                            try:
                                twin.dispatch(epoch, idx, held[idx])
                            except PeerGone:
                                attempts[idx] -= 1
                                account_requeue(twin, time.monotonic())
                                self._reap(twin, pending, epoch)
                                continue
                            spec_extra.add(idx)
                            spec_peer[idx] = twin
                            spec_dispatches += 1
                            disp_at[idx] = obs_clock.now()
                            twin.last_seen = disp_at[idx]
                            self._m_dispatch.inc()
                            self.recorder.record(
                                "dispatch", idx=idx, peer=twin.scope,
                                attempt=attempts[idx], speculative=True)
                if not self._peers and not self._pending_refill():
                    raise RuntimeError(
                        f"all fleet workers died ({self.worker_deaths} "
                        f"death(s)) with {len(held)} bundle(s) pending")
                # -- collect ----------------------------------------------
                # an open-loop pass (source had nothing *yet*) polls fast:
                # the next arrival should not sit in its feed queue for a
                # full peer-wait interval before admission
                evs = self._wait(0.02 if saw_none else 0.5)
                if not evs and not self._peers:
                    time.sleep(0.05)   # backoff respawn still pending
                for obj in evs:
                    peer = self._peer_for(obj)
                    if peer is None:
                        self._handle_extra(obj)
                        continue
                    try:
                        msg = peer.recv()
                    except PeerGone:
                        account_requeue(peer, time.monotonic())
                        self._reap(peer, pending, epoch)
                        continue
                    now = obs_clock.now()
                    peer.last_seen = now
                    kind = msg[0]
                    if kind == "ping":
                        pings += 1
                        self._m_heartbeats.inc()
                        self.recorder.record("heartbeat", peer=peer.scope)
                    elif kind == "ready":
                        peer.ready = True
                        self._note_ready()
                    elif kind == "obs":
                        # a final buffer shipped on stop/drain
                        self._absorb_frame(peer, msg[1])
                    elif kind == "ok":
                        e, idx, rep = msg[1], msg[2], msg[3]
                        self._absorb_frame(peer,
                                           msg[4] if len(msg) > 4 else None)
                        peer.tasks.discard((e, idx))
                        if e == epoch and idx in held:
                            t = disp_at.pop(idx, None)
                            if t is not None:
                                done_times.append(max(0.0, now - t))
                                self._m_replay.observe(max(0.0, now - t))
                            twin = spec_peer.pop(idx, None)
                            if twin is not None and twin is peer:
                                spec_wins += 1
                            spec_extra.discard(idx)
                            del held[idx]
                            att = attempts.pop(idx, None)
                            q_since.pop(idx, None)
                            qw = q_wait.pop(idx, 0.0)
                            self._m_queue.observe(qw)
                            self._m_done.inc()
                            self.recorder.record("done", idx=idx,
                                                 peer=peer.scope)
                            # frontier release: children whose last
                            # unmet parent this was become dispatchable
                            completed.add(idx)
                            for c in sorted(dependants.pop(idx, ())):
                                un = blocked.get(c)
                                if un is None:
                                    continue
                                un.discard(idx)
                                if not un:
                                    del blocked[c]
                                    q_since[c] = now
                                    pending.append(c)
                                    self.recorder.record("dep_release",
                                                         idx=c, parent=idx)
                            enq = enq_at.pop(idx, now)
                            if record_timing is not None:
                                record_timing(idx, BundleTiming(
                                    enqueued=enq, dispatched=t, done=now,
                                    queue_s=qw,
                                    replay_s=(max(0.0, now - t)
                                              if t is not None else 0.0),
                                    attempts=att or 1, ok=True))
                            yield idx, rep
                    elif kind == "retry":
                        _, e, idx, _reason = msg
                        peer.tasks.discard((e, idx))
                        if e == epoch and idx in held \
                                and idx not in pending:
                            requeued += 1
                            self._m_requeue.inc()
                            self.recorder.record("requeue", idx=idx,
                                                 reason=str(_reason),
                                                 peer=peer.scope)
                            t = disp_at.pop(idx, None)
                            if t is not None:
                                lost_replay += now - t
                            requeue_ts[idx] = now
                            q_since[idx] = now
                            pending.append(idx)
                    elif kind == "err":
                        e, idx, tb = msg[1], msg[2], msg[3]
                        self._absorb_frame(peer,
                                           msg[4] if len(msg) > 4 else None)
                        if idx is None:
                            raise RuntimeError(
                                "fleet worker failed on initialization:"
                                f"\n{tb}")
                        peer.tasks.discard((e, idx))  # terminal either way
                        if e == epoch and idx in held:
                            if on_failure == "skip":
                                skip(idx)
                                yield idx, None
                                for c in doomed(idx):
                                    skip(c, ancestor=idx)
                                    yield c, None
                                continue
                            raise RuntimeError(
                                f"fleet worker ({peer.describe()}) failed "
                                f"on bundle {idx} ({held[idx].command!r}):"
                                f"\n{tb}")
            # -- natural drain: an elastic pool parks back at its floor ---
            if self._autoscale:
                idle = [p for p in self._peers if not p.tasks]
                for p in idle[:len(self._peers) - self._scale_min]:
                    self._retire(p)
        finally:
            self.last_scaling = {
                "scale_ups": self.scale_ups - base_ups,
                "scale_downs": self.scale_downs - base_downs,
                "peak_workers": peak_workers,
                "peak_queue_depth": peak_queue,
                "peak_window": peak_window,
                "midstream_downs": midstream_downs,
            }
            mttr = self._mttr_samples[base_mttr:]
            self.last_recovery = {
                "worker_deaths": self.worker_deaths - base_deaths,
                "hung_reaped": self.hung_reaped - base_hung,
                "requeued": requeued,
                "requeue_latency_s": (requeue_wait / requeue_waits
                                      if requeue_waits else 0.0),
                "lost_replay_s": lost_replay,
                "mttr_s": (sum(mttr) / len(mttr)) if mttr else None,
                "skipped": sorted(skipped),
                "skipped_ancestor": sorted(anc_skipped),
                "speculative_dispatches": spec_dispatches,
                "speculative_wins": spec_wins,
                "heartbeats": pings,
                # (opened, repaired) monotonic stamps of every fault whose
                # MTTR window closed during this stream — joinable against
                # a latency timeline (repro.service.slo does exactly that)
                "fault_events": [
                    (o, r) for o, r in self.fault_events[base_fev:]],
            }

    def run(self, bundles: Iterable[ScheduleBundle], *,
            timeout: float = 600.0, window: Optional[int] = None,
            max_attempts: Optional[int] = None,
            liveness_timeout: Optional[float] = None,
            speculate: Optional[float] = None,
            on_failure: str = "raise") -> List[EmulationReport]:
        """Replay every bundle; returns reports in bundle order.

        The materializing wrapper over ``stream`` — same failure
        semantics, but all reports are held until the source is drained.
        Prefer consuming ``stream`` directly for unbounded sources.
        Under ``on_failure="skip"`` skipped bundles leave no entry, so
        the list may be shorter than the source (``last_recovery`` has
        the skipped indices).
        """
        results: Dict[int, EmulationReport] = {}
        for idx, rep in self.stream(bundles, timeout=timeout, window=window,
                                    max_attempts=max_attempts,
                                    liveness_timeout=liveness_timeout,
                                    speculate=speculate,
                                    on_failure=on_failure):
            if rep is not None:
                results[idx] = rep
        return [results[i] for i in sorted(results)]

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for peer in self._peers:
            peer.stop()
        for peer in self._peers:
            # collect the final buffer a stopping peer ships (events
            # since its last result — the stop-frame piggyback)
            if hasattr(peer, "drain_obs"):
                self._absorb_frame(peer, peer.drain_obs(0.2))
        for peer in self._peers:
            peer.close()
        self._peers.clear()
        self._close_extras()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# local instantiation: worker processes behind multiprocessing Pipes
# ---------------------------------------------------------------------------

class _PipePeer(Peer):
    """One spawn-based worker process behind a ``Pipe``: capacity 1.

    The on-pipe worker protocol (``repro.fleet.worker``) predates epochs —
    a capacity-1 worker replays serially, so the epoch of any reply is
    simply the epoch its single in-flight task was dispatched under; this
    adapter re-attaches it.
    """

    __slots__ = ("proc", "conn", "tasks", "ready")

    def __init__(self, proc, conn):
        super().__init__()
        self.proc = proc
        self.conn = conn

    @property
    def alive(self) -> bool:
        return self.proc.is_alive()

    @property
    def waitable(self):
        return self.conn

    def dispatch(self, epoch, idx, bundle):
        try:
            # the trailing stamp is the clock echo: the worker copies it
            # into the ObsFrame it ships home, closing the offset loop
            self.conn.send(("run", idx, bundle, obs_clock.now()))
        except (BrokenPipeError, OSError) as e:
            raise PeerGone(str(e)) from e
        self.tasks.add((epoch, idx))

    def recv(self):
        try:
            msg = self.conn.recv()
        except (EOFError, ConnectionResetError, OSError) as e:
            raise PeerGone(str(e)) from e
        kind = msg[0]
        if kind == "ping":
            return ("ping",)
        if kind == "ready":
            return ("ready", msg[1])
        if kind == "obs":
            return ("obs", msg[1])
        if kind == "ok":
            idx, rep = msg[1], msg[2]
            frame = msg[3] if len(msg) > 3 else None
            return ("ok", self.epoch_for(idx), idx, rep, frame)
        if kind == "err":
            idx, tb = msg[1], msg[2]
            frame = msg[3] if len(msg) > 3 else None
            return ("err", self.epoch_for(idx), idx, tb, frame)
        return ("err", None, None, f"unknown worker message {kind!r}")

    def stop(self):
        if self.alive:
            try:
                self.conn.send(("stop",))
            except (BrokenPipeError, OSError):
                pass

    def drain_obs(self, timeout: float = 0.5):
        """Best-effort read of the final ``("obs", frame)`` a stopped
        worker ships on its way out; returns the frame or None."""
        deadline = time.monotonic() + timeout
        try:
            while time.monotonic() < deadline:
                if not self.conn.poll(max(0.0, deadline - time.monotonic())):
                    return None
                msg = self.conn.recv()
                if msg and msg[0] == "obs":
                    return msg[1]
        except (EOFError, ConnectionResetError, OSError):
            return None
        return None

    def close(self):
        try:
            self.conn.close()
        except OSError:
            pass
        # instant for a reaped (dead) process; bounded grace for a polite
        # stop — a worker that outlives it is wedged and gets the axe
        self.proc.join(timeout=2.0)
        if self.proc.is_alive():
            self.proc.terminate()
            self.proc.join(timeout=2.0)

    def destroy(self):
        # hung worker: no grace it will never honor — terminate first
        try:
            self.conn.close()
        except OSError:
            pass
        if self.proc.is_alive():
            self.proc.terminate()
        self.proc.join(timeout=2.0)

    def describe(self) -> str:
        return f"worker pid {self.proc.pid}"


class ProcessFleet(FleetBase):
    """A pool of emulator worker processes that replay ``ScheduleBundle``s.

    The pool is warm state: spawn it once, ``run()``/``stream()`` it many
    times (each run reuses the workers' traced programs and plan caches),
    ``close()`` it when done — or use it as a context manager.
    ``worker_deaths`` and ``respawns`` count recovery events across the
    pool's lifetime.

    With ``autoscale=True`` the pool is elastic: it starts at
    ``min_workers`` (default 1), the scheduler spawns up to ``n_workers``
    while queued bundles outnumber free slots, and idle workers are
    retired back to the floor when a stream drains — so a bursty profile
    source pays for exactly the workers its queue depth asked for.
    """

    def __init__(self, n_workers: int, spec: WorkerSpec, *,
                 respawn: bool = True, max_respawns: Optional[int] = None,
                 min_workers: Optional[int] = None, autoscale: bool = False,
                 respawn_backoff: Tuple[float, float] = (0.1, 5.0),
                 crash_loop: Tuple[int, float] = (5, 10.0)):
        if n_workers < 1:
            raise ValueError("ProcessFleet needs n_workers >= 1")
        if min_workers is not None and not autoscale:
            raise ValueError("min_workers is the autoscale floor; pass "
                             "autoscale=True with it")
        super().__init__()
        self.spec = spec
        self.n_workers = n_workers
        self.respawns = 0
        self._respawn = respawn
        self._respawns_left = (n_workers if max_respawns is None
                               else max_respawns)
        self._ctx = mp.get_context("spawn")
        self._autoscale = autoscale
        self._scale_max = n_workers
        self._scale_min = max(1, min_workers or 1) if autoscale else n_workers
        if self._scale_min > n_workers:
            raise ValueError(f"min_workers={min_workers} exceeds "
                             f"n_workers={n_workers}")
        # -- respawn pacing: exponential backoff + crash-loop breaker -------
        self._backoff_base, self._backoff_cap = respawn_backoff
        self._crash_limit, self._crash_window = crash_loop
        self._death_log: Deque[float] = deque()   # deaths inside the window
        self._respawn_due: List[float] = []       # deferred spawn deadlines
        self._death_streak = 0
        self._last_death = float("-inf")
        # jitter comes from the chaos-safe seeded RNG so backoff delays —
        # and therefore fault *timings* — replay identically given the
        # same policy seed
        chaos = getattr(spec, "chaos", None)
        self._backoff_rng = (chaos.rng("coordinator")
                             if chaos is not None else Random(0))
        self._spawned = 0                         # spawn-ordinal -> scope
        for _ in range(self._scale_min if autoscale else n_workers):
            self._spawn()

    def _spawn(self) -> None:
        # the spawn ordinal names the worker's deterministic chaos scope:
        # the k-th worker this pool ever starts is "worker:k", on every
        # run with the same policy
        scope = f"worker:{self._spawned}"
        self._spawned += 1
        parent_conn, child_conn = self._ctx.Pipe()
        # The mesh's device count must reach the child's XLA before its
        # backend initializes; setting it in the *parent's* environment
        # around the spawn is the only ordering that beats every module the
        # child bootstrap may import.
        old_flags = os.environ.get("XLA_FLAGS")
        if self.spec.mesh is not None:
            # append AFTER any inherited flags: XLA takes the last
            # occurrence of a repeated flag, and this repo's own tooling
            # (dryrun, test_distributed) exports its own device-count flag
            os.environ["XLA_FLAGS"] = (
                (f"{old_flags} " if old_flags else "")
                + f"--xla_force_host_platform_device_count="
                  f"{self.spec.mesh.device_count}")
        try:
            proc = self._ctx.Process(target=worker_loop,
                                     args=(child_conn, self.spec, scope),
                                     daemon=True)
            proc.start()
        finally:
            if self.spec.mesh is not None:
                if old_flags is None:
                    os.environ.pop("XLA_FLAGS", None)
                else:
                    os.environ["XLA_FLAGS"] = old_flags
        child_conn.close()
        peer = _PipePeer(proc, parent_conn)
        peer.scope = scope          # flight-recorder track == chaos scope
        self._peers.append(peer)

    def _refill(self, pending: Deque[int]) -> None:
        """A worker died: schedule a replacement with exponential backoff
        (a respawn is *deferred*, serviced by ``_tick`` on scheduler
        passes) and trip the crash-loop breaker if this spec keeps dying.
        """
        if not self._respawn or self._respawns_left <= 0:
            return
        now = time.monotonic()
        self._death_log.append(now)
        while self._death_log and now - self._death_log[0] > \
                self._crash_window:
            self._death_log.popleft()
        if self._crash_limit and len(self._death_log) >= self._crash_limit:
            self.recorder.record("crash_loop",
                                 deaths=len(self._death_log),
                                 window_s=self._crash_window)
            raise CrashLoopError(
                f"fleet worker spec is crash-looping: "
                f"{len(self._death_log)} death(s) within "
                f"{self._crash_window:.1f}s (breaker limit "
                f"{self._crash_limit}) — refusing to burn the remaining "
                f"respawn budget ({self._respawns_left})")
        if now - self._last_death <= self._crash_window:
            self._death_streak += 1
        else:
            self._death_streak = 1
        self._last_death = now
        delay = min(self._backoff_cap,
                    self._backoff_base * (2 ** (self._death_streak - 1)))
        delay *= 0.5 + self._backoff_rng.random()     # jitter: 0.5x-1.5x
        self._respawns_left -= 1
        self._fault_opened.append(now)                # MTTR window opens
        self._respawn_due.append(now + delay)

    def _tick(self, pending: Deque[int]) -> None:
        now = time.monotonic()
        due = [t for t in self._respawn_due if t <= now]
        if due:
            self._respawn_due = [t for t in self._respawn_due if t > now]
            for _ in due:
                self.respawns += 1
                self._spawn()

    def _pending_refill(self) -> bool:
        return bool(self._respawn_due)

    def _scale_up(self) -> bool:
        if len(self._peers) >= self._scale_max:
            return False
        self._spawn()
        self.scale_ups += 1
        return True

    @property
    def pids(self) -> List[int]:
        return [p.proc.pid for p in self._peers if p.alive]

    def close(self) -> None:
        """Tear the pool down in parallel: issue every stop first, then
        join all workers against *one* shared grace deadline — closing a
        large (or dead) pool costs one grace period, not one per worker.
        """
        if self._closed:
            return
        self._closed = True
        self._respawn_due.clear()           # no respawns into a closed pool
        peers = list(self._peers)
        self._peers.clear()
        for p in peers:
            p.stop()                        # all stops in flight first
        for p in peers:
            # final flight-recorder buffers ride the stop frame home
            self._absorb_frame(p, p.drain_obs(0.2))
        for p in peers:
            try:
                p.conn.close()
            except OSError:
                pass
        deadline = time.monotonic() + 5.0   # one shared grace for the pool
        for p in peers:
            p.proc.join(timeout=max(0.0, deadline - time.monotonic()))
        for p in peers:                     # stragglers get the axe...
            if p.proc.is_alive():
                p.proc.terminate()
        deadline = time.monotonic() + 2.0   # ...against one shared deadline
        for p in peers:
            if p.proc.is_alive():
                p.proc.join(timeout=max(0.0, deadline - time.monotonic()))
        self._close_extras()


def run_process_fleet(emulator: Emulator, profiles, *, max_workers: int = 4,
                      mesh_spec=None, flops_scale: float = 1.0,
                      storage_scale: float = 1.0, mem_scale: float = 1.0,
                      verify: bool = True, timeout: float = 600.0,
                      fleet: Optional[ProcessFleet] = None,
                      window: Optional[int] = None, autoscale: bool = False,
                      min_workers: Optional[int] = None,
                      collect: str = "reports",
                      max_attempts: Optional[int] = None,
                      liveness_timeout: Optional[float] = None,
                      speculate: Optional[float] = None,
                      on_failure: str = "raise",
                      chaos: Optional[ChaosPolicy] = None,
                      max_respawns: Optional[int] = None) -> FleetReport:
    """Compile → detach → ship, streamed: one-call process-fleet replay.

    Backs ``Emulator.emulate_many(executor="process")``.  ``profiles`` may
    be any iterable — a list or a lazy source like
    ``ProfileStore.stream(...)``: compilation happens as the scheduler
    pulls, at most ``window`` bundles ahead of dispatch, so coordinator
    memory is bounded by the window even for a production day's worth of
    profiles.  Pass ``fleet`` to reuse a warm ``ProcessFleet`` (the caller
    keeps ownership; ``chaos``/``max_respawns`` are then the caller's
    business, baked into the warm pool's spec); otherwise a pool sized
    ``min(max_workers, len(profiles))`` (or starting at ``min_workers``
    when ``autoscale``) is spawned and torn down around this one run.
    With ``mesh_spec`` set, wire-byte runs compile to mesh-bound fused
    segments and every worker builds its own mesh — collective legs move
    bytes inside the workers' segment scans.  ``collect="totals"`` drops
    per-profile reports and returns aggregates only (the bounded-memory
    soak mode).

    Hardening: ``liveness_timeout`` arms hung-peer reaping (workers are
    spawned heartbeating at a quarter of it), ``speculate``/
    ``max_attempts``/``on_failure`` pass through to ``stream``, and a
    seeded ``chaos`` policy makes every spawned worker inject its
    scheduled faults.  Stats/scaling/recovery are snapshotted even when
    the stream raises — the partial ``FleetReport`` rides on the raised
    exception as ``.fleet_report`` so failure paths keep their recovery
    accounting.

    ``profiles`` may also be a ``WorkloadDag`` (anything with a
    ``parents_map``): each node compiles into a bundle carrying its
    dependency edges, ``stream``'s frontier gates dispatch on them, the
    fold distinguishes cascade holes from direct poison, and the
    returned report's ``dag`` dict carries critical-path accounting
    (``critical_path_s``, ``makespan_s``, per-node ``slack_s``) built
    from the per-bundle timing stamps.  ``collect="totals"`` is rejected
    for dags — it drops exactly the per-node timing the critical path
    needs.
    """
    is_dag = hasattr(profiles, "parents_map")
    if is_dag and collect == "totals":
        raise ValueError(
            "collect='totals' is incompatible with a WorkloadDag: totals "
            "mode drops the per-node BundleTiming stamps critical-path "
            "accounting needs — use collect='reports'")
    n_samples = {"n": 0}                 # true profile samples compiled

    def _bundles():
        if is_dag:
            for node in profiles.nodes:
                b = bundle_profile(emulator, node.profile,
                                   mesh_spec=mesh_spec,
                                   flops_scale=flops_scale,
                                   storage_scale=storage_scale,
                                   mem_scale=mem_scale, verify=verify,
                                   parents=node.parents)
                n_samples["n"] += b.n_profile_samples
                yield b
            return
        for p in profiles:
            b = bundle_profile(emulator, p, mesh_spec=mesh_spec,
                               flops_scale=flops_scale,
                               storage_scale=storage_scale,
                               mem_scale=mem_scale, verify=verify)
            n_samples["n"] += b.n_profile_samples
            yield b

    own = fleet is None
    if own:
        n = len(profiles) if hasattr(profiles, "__len__") else None
        workers = max(1, min(max_workers, n)) if n is not None \
            else max(1, max_workers)
        heartbeat_s = (max(0.1, liveness_timeout / 4.0)
                       if liveness_timeout else 0.0)
        fleet = ProcessFleet(workers,
                             WorkerSpec(emulator=emulator.spec(),
                                        mesh=mesh_spec,
                                        heartbeat_s=heartbeat_s,
                                        chaos=chaos),
                             autoscale=autoscale, min_workers=min_workers,
                             max_respawns=max_respawns)
    t0 = time.perf_counter()
    fold = ReportFold(keep_reports=collect != "totals")
    timings: Dict[int, BundleTiming] = {}

    def _snapshot():
        return ({"workers": fleet.n_workers,
                 "worker_deaths": fleet.worker_deaths,
                 "respawns": fleet.respawns},
                dict(fleet.last_scaling), dict(fleet.last_recovery),
                fleet.n_workers)

    def _report(stats, scaling, recovery, n_workers, last_n=None):
        return FleetReport(
            reports=fold.reports, wall_s=time.perf_counter() - t0,
            serial_s=fold.serial_s, max_workers=n_workers,
            cache_stats=stats, totals=fold.totals,
            n_samples=n_samples["n"], n_replayed=fold.n_done,
            scaling=scaling, recovery=recovery,
            obs=fleet.obs_snapshot(last_n),
            dag=(critical_path(profiles.parents_map, timings)
                 if is_dag else {}))

    gen = fleet.stream(_bundles(), timeout=timeout, window=window,
                       max_attempts=max_attempts,
                       liveness_timeout=liveness_timeout,
                       speculate=speculate, on_failure=on_failure,
                       record_timing=(timings.__setitem__
                                      if is_dag else None))
    try:
        for idx, rep in gen:
            if rep is None:
                # degraded-mode hole: fold past it, classifying cascade
                # holes (ancestor skipped) apart from direct poison
                fold.skip(idx,
                          ancestor=idx in fleet.last_ancestor_skips)
            else:
                fold.add(idx, rep)
        snap = _snapshot()
    except BaseException as e:
        # the stream raised: close the generator so its finally has
        # published this run's scaling/recovery, then snapshot — the
        # partially-folded totals and fault accounting ride out on the
        # exception instead of being lost
        gen.close()
        # postmortem: the last events of the merged timeline ride out on
        # the exception (CrashLoopError, poison, timeout) so failure
        # analysis sees the sequence, not just totals
        e.fleet_report = _report(*_snapshot(), last_n=256)
        raise
    finally:
        if own:
            fleet.close()
    return _report(*snap)
