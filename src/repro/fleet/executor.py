"""Process-level fleet executor: replay schedule bundles on worker processes.

``ProcessFleet`` owns a pool of spawn-based worker processes (see
``repro.fleet.worker``), each with its own jax client, emulator, jitted
programs, and — when the ``WorkerSpec`` carries a ``MeshSpec`` — its own
device mesh.  The parent compiles profiles once, detaches them into
``ScheduleBundle``s, and streams them to whichever worker is idle; workers
stream back ``EmulationReport``s.  Scheduling is work-stealing-simple:
one in-flight bundle per worker, next bundle to the first worker that
frees up, so a straggler profile never blocks the rest of the fleet.

Worker death is handled gracefully: a died worker's in-flight bundle is
re-queued (with a bounded attempt count, so a bundle that *kills* workers
poisons the run instead of looping forever), a replacement worker is
spawned while the respawn budget lasts, and the fleet keeps draining on the
survivors.  Only when no worker is left alive and none can be respawned
does ``run`` raise.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import time
from collections import deque
from multiprocessing import connection as mp_conn
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.emulator import EmulationReport, Emulator, FleetReport
from repro.fleet.bundle import ScheduleBundle, WorkerSpec, bundle_profile
from repro.fleet.worker import worker_loop

_MAX_ATTEMPTS = 3          # dispatches per bundle before declaring it poison


class _Worker:
    __slots__ = ("proc", "conn", "task", "ready")

    def __init__(self, proc, conn):
        self.proc = proc
        self.conn = conn
        # in-flight work as (run epoch, bundle index): a run() that raises
        # leaves stragglers replaying, and the next run() must neither
        # mistake their late results for its own nor dispatch over them
        self.task: Optional[Tuple[int, int]] = None
        self.ready = False

    @property
    def alive(self) -> bool:
        return self.proc.is_alive()


class ProcessFleet:
    """A pool of emulator worker processes that replay ``ScheduleBundle``s.

    The pool is warm state: spawn it once, ``run()`` it many times (each
    run reuses the workers' traced programs and plan caches), ``close()``
    it when done — or use it as a context manager.  ``worker_deaths`` and
    ``respawns`` count recovery events across the pool's lifetime.
    """

    def __init__(self, n_workers: int, spec: WorkerSpec, *,
                 respawn: bool = True, max_respawns: Optional[int] = None):
        if n_workers < 1:
            raise ValueError("ProcessFleet needs n_workers >= 1")
        self.spec = spec
        self.n_workers = n_workers
        self.worker_deaths = 0
        self.respawns = 0
        self._respawn = respawn
        self._respawns_left = (n_workers if max_respawns is None
                               else max_respawns)
        self._ctx = mp.get_context("spawn")
        self._workers: List[_Worker] = []
        self._closed = False
        self._epoch = 0
        for _ in range(n_workers):
            self._spawn()

    # -- pool plumbing ------------------------------------------------------

    def _spawn(self) -> None:
        parent_conn, child_conn = self._ctx.Pipe()
        # The mesh's device count must reach the child's XLA before its
        # backend initializes; setting it in the *parent's* environment
        # around the spawn is the only ordering that beats every module the
        # child bootstrap may import.
        old_flags = os.environ.get("XLA_FLAGS")
        if self.spec.mesh is not None:
            # append AFTER any inherited flags: XLA takes the last
            # occurrence of a repeated flag, and this repo's own tooling
            # (dryrun, test_distributed) exports its own device-count flag
            os.environ["XLA_FLAGS"] = (
                (f"{old_flags} " if old_flags else "")
                + f"--xla_force_host_platform_device_count="
                  f"{self.spec.mesh.device_count}")
        try:
            proc = self._ctx.Process(target=worker_loop,
                                     args=(child_conn, self.spec),
                                     daemon=True)
            proc.start()
        finally:
            if self.spec.mesh is not None:
                if old_flags is None:
                    os.environ.pop("XLA_FLAGS", None)
                else:
                    os.environ["XLA_FLAGS"] = old_flags
        child_conn.close()
        self._workers.append(_Worker(proc, parent_conn))

    @property
    def pids(self) -> List[int]:
        return [w.proc.pid for w in self._workers if w.alive]

    def _reap(self, w: _Worker, pending: deque,
              epoch: Optional[int] = None) -> None:
        """A worker died: requeue its in-flight bundle (only if it belongs
        to the current run — a straggler from a raised run is dropped),
        refill the pool."""
        self.worker_deaths += 1
        if w.task is not None and epoch is not None and w.task[0] == epoch:
            pending.appendleft(w.task[1])
        w.task = None
        try:
            w.conn.close()
        except OSError:
            pass
        self._workers.remove(w)
        w.proc.join(timeout=1.0)
        if self._respawn and self._respawns_left > 0:
            self._respawns_left -= 1
            self.respawns += 1
            self._spawn()

    def warmup(self, timeout: float = 120.0) -> List[Dict]:
        """Block until every live worker reported ready; returns their
        ready infos.  Not required before ``run`` (dispatches queue in the
        pipe), but useful to separate spawn/trace cost from replay cost —
        ``benchmarks/bench_fleet.py`` does exactly that."""
        deadline = time.monotonic() + timeout
        infos = []
        while any(w.alive and not w.ready for w in self._workers):
            if time.monotonic() > deadline:
                raise TimeoutError("fleet workers did not become ready "
                                   f"within {timeout}s")
            conns = [w.conn for w in self._workers
                     if w.alive and not w.ready]
            for conn in mp_conn.wait(conns, timeout=0.5):
                w = next(x for x in self._workers if x.conn is conn)
                try:
                    msg = conn.recv()
                except (EOFError, ConnectionResetError, OSError):
                    self._reap(w, deque())
                    continue
                if msg[0] == "ready":
                    w.ready = True
                    infos.append(msg[1])
                elif msg[0] == "err":
                    raise RuntimeError(
                        f"fleet worker failed to initialize:\n{msg[2]}")
        if not self._workers:
            raise RuntimeError("no fleet worker survived initialization")
        return infos

    # -- execution ----------------------------------------------------------

    def run(self, bundles: Sequence[ScheduleBundle], *,
            timeout: float = 600.0) -> List[EmulationReport]:
        """Replay every bundle; returns reports in bundle order.

        Raises RuntimeError on a worker-reported replay failure, on a
        poison bundle (one that outlived ``_MAX_ATTEMPTS`` dispatch
        attempts across dying workers), or when the whole pool is dead
        with work still pending.
        """
        if self._closed:
            raise RuntimeError("ProcessFleet is closed")
        # A raised run (worker error, poison bundle, timeout) leaves
        # stragglers replaying on live workers.  Each run gets a fresh
        # epoch: stragglers' late results are recognized by their stale
        # epoch, discarded, and merely free their worker — they are never
        # returned as this run's reports and never block dispatch forever.
        self._epoch += 1
        epoch = self._epoch
        pending = deque(range(len(bundles)))
        attempts = [0] * len(bundles)
        results: Dict[int, EmulationReport] = {}
        deadline = time.monotonic() + timeout
        while len(results) < len(bundles):
            if time.monotonic() > deadline:
                raise TimeoutError(f"fleet run exceeded {timeout}s with "
                                   f"{len(bundles) - len(results)} bundle(s) "
                                   "unfinished")
            # dispatch to idle workers (death noticed on send is handled
            # exactly like death noticed on receive)
            for w in list(self._workers):
                if w.task is None and pending:
                    if not w.alive:
                        self._reap(w, pending, epoch)
                        continue
                    idx = pending.popleft()
                    if attempts[idx] >= _MAX_ATTEMPTS:
                        raise RuntimeError(
                            f"bundle {idx} ({bundles[idx].command!r}) failed "
                            f"{attempts[idx]} dispatch attempts — poison "
                            "bundle, aborting the fleet run")
                    attempts[idx] += 1
                    try:
                        w.conn.send(("run", idx, bundles[idx]))
                        w.task = (epoch, idx)
                    except (BrokenPipeError, OSError):
                        pending.appendleft(idx)
                        attempts[idx] -= 1
                        self._reap(w, pending, epoch)
            if not self._workers:
                raise RuntimeError(
                    f"all fleet workers died ({self.worker_deaths} death(s)) "
                    f"with {len(bundles) - len(results)} bundle(s) pending")
            # collect
            conns = [w.conn for w in self._workers]
            for conn in mp_conn.wait(conns, timeout=0.5):
                w = next((x for x in self._workers if x.conn is conn), None)
                if w is None:
                    continue
                try:
                    msg = conn.recv()
                except (EOFError, ConnectionResetError, OSError):
                    self._reap(w, pending, epoch)
                    continue
                if msg[0] == "ready":
                    w.ready = True
                elif msg[0] == "ok":
                    _, idx, rep = msg
                    current = w.task is not None and w.task[0] == epoch
                    w.task = None
                    if current:
                        results[idx] = rep
                elif msg[0] == "err":
                    _, idx, tb = msg
                    if idx is None:
                        raise RuntimeError(
                            f"fleet worker failed on initialization:\n{tb}")
                    current = w.task is not None and w.task[0] == epoch
                    w.task = None          # terminal either way
                    if current:
                        raise RuntimeError(
                            f"fleet worker failed on bundle {idx} "
                            f"({bundles[idx].command!r}):\n{tb}")
        return [results[i] for i in range(len(bundles))]

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for w in self._workers:
            if w.alive:
                try:
                    w.conn.send(("stop",))
                except (BrokenPipeError, OSError):
                    pass
        for w in self._workers:
            w.proc.join(timeout=5.0)
            if w.proc.is_alive():
                w.proc.terminate()
                w.proc.join(timeout=2.0)
            try:
                w.conn.close()
            except OSError:
                pass
        self._workers.clear()

    def __enter__(self) -> "ProcessFleet":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def run_process_fleet(emulator: Emulator, profiles, *, max_workers: int = 4,
                      mesh_spec=None, flops_scale: float = 1.0,
                      storage_scale: float = 1.0, mem_scale: float = 1.0,
                      verify: bool = True,
                      fleet: Optional[ProcessFleet] = None) -> FleetReport:
    """Compile → detach → ship: one-call process-fleet replay.

    Backs ``Emulator.emulate_many(executor="process")``.  Pass ``fleet`` to
    reuse a warm ``ProcessFleet`` (the caller keeps ownership); otherwise a
    pool sized ``min(max_workers, len(profiles))`` is spawned and torn down
    around this one run.  With ``mesh_spec`` set, wire-byte runs compile to
    executable barrier steps and every worker builds its own mesh — the
    first fleet mode in which collective legs actually move bytes.
    """
    keep = True if mesh_spec is not None else None
    bundles = [bundle_profile(emulator, p, keep_collectives=keep,
                              flops_scale=flops_scale,
                              storage_scale=storage_scale,
                              mem_scale=mem_scale, verify=verify)
               for p in profiles]
    own = fleet is None
    if own:
        workers = max(1, min(max_workers, len(profiles)))
        fleet = ProcessFleet(workers, WorkerSpec(emulator=emulator.spec(),
                                                 mesh=mesh_spec))
    t0 = time.perf_counter()
    try:
        reports = fleet.run(bundles)
    finally:
        if own:
            fleet.close()
    wall = time.perf_counter() - t0
    return FleetReport(
        reports=reports, wall_s=wall,
        serial_s=sum(r.ttc_s for r in reports),
        max_workers=fleet.n_workers,
        cache_stats={"workers": fleet.n_workers,
                     "worker_deaths": fleet.worker_deaths,
                     "respawns": fleet.respawns})
