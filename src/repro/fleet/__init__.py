"""Fleet execution past the thread/GIL ceiling — and past the host.

``Emulator.emulate_many`` replays a fleet of profiles concurrently; this
package supplies its ``executor="process"`` and ``executor="remote"``
backends.  The schedule compiler made the split cheap: a
``CompiledSchedule`` is plain numpy iteration tables + resource vectors,
so the parent compiles once, detaches each schedule into a picklable
``ScheduleBundle``, and ships it — over a ``Pipe`` to a pool of
spawn-based worker processes (``ProcessFleet``), or over framed TCP to
host agents on other machines (``RemoteFleet`` +
``python -m repro.fleet.agent``).  Each worker builds its own
``Emulator`` + ``SegmentRunner`` exactly once — its own jax client, its
own jitted programs, its own plan cache, and (given a ``MeshSpec``) its
own device mesh — then replays bundles fused and streams back
``EmulationReport``s whose consumed totals are bit-identical to an
in-process replay of the same profile.  Both executors share one
transport-agnostic scheduler (``executor.FleetBase``): the same attempt
budget, poison-bundle cap, and reap-requeue-refill recovery whether the
dead peer was a process or a TCP connection.

Thread vs process vs remote executor — decision matrix:

  ==================  ====================  ====================  =====================
  dimension           executor="thread"     executor="process"    executor="remote"
  ==================  ====================  ====================  =====================
  parallelism         one GIL + one jax     one jax client *per   one jax client per
  ceiling             client; scales until  worker*; scales       worker per *host*;
                      dispatch serializes   with cores            scales with machines
  per-fleet           ~zero (shared pool)   worker spawn + jax    agent join + spawn/
  overhead                                  import + trace, ONCE  trace per host, ONCE;
                                            per worker (keep      then framed-TCP
                                            the pool warm)        pickle per bundle
  plan/program        fleet-wide PlanCache  per-worker cache;     per-worker cache on
  sharing             + shared              programs traced once  each host
                      SegmentRunner         per worker
  collectives         dropped (no           EXECUTE: each worker  EXECUTE: per-worker
                      per-thread mesh is    owns a mesh built     meshes on every host
                      possible)             from MeshSpec         (per-agent MeshSpec)
  collectives fused?  n/a without a mesh    YES: with mesh_spec   YES: the same mesh-
                      (a mesh-owning        the parent quantizes  bound bundles over
                      parent fuses its      wire runs into mesh-  TCP; agents' workers
                      own in-process        bound segment rows    run wire rows inside
                      replays)              (CollectiveQuant);    their segment scans
                                            workers replay a
                                            wire-heavy profile
                                            as ONE scan dispatch
  failure             a crash takes the     worker death reaped,  agent death reaped the
  isolation           whole fleet down      bundle re-queued,     same way; bundles
                                            pool refilled         requeue onto surviving
                                                                  hosts, late agents can
                                                                  join mid-run
  streaming source?   YES: profiles pulled  YES: compile→bundle   YES: same windowed
                      (and generated) at    happens as the        bundle stream over
                      most ``window``       scheduler pulls, at   TCP; coordinator
                      ahead of replay       most ``window``       memory bounded by the
                                            bundles ahead of      window, not the
                                            dispatch              stream length
  autoscales?         no (fixed shared      YES: spawns workers   YES: open listener
                      thread pool)          up to max_workers on  invites late joiners
                                            queue depth, retires  mid-run (scale-up);
                                            idle ones to the      idle agents released
                                            min_workers floor     down to the floor
                                            when the stream       when the stream
                                            drains                drains
  hung-peer           no (a wedged thread   YES: liveness_        YES: agents heartbeat
  detection?          holds its bundle      timeout arms worker   over TCP; a silent
                      until the run         heartbeats; a silent  agent is destroyed
                      timeout)              worker is destroyed   and its bundles
                                            and its bundles       requeue onto live
                                            requeued              hosts
  fault injection?    no (nothing to kill   YES: a seeded         YES: the same policy
                      without taking the    ChaosPolicy kills/    plus agent-side drop/
                      fleet down)           hangs/delays workers  corrupt-frame faults;
                                            deterministically,    same seed, same fault
                                            replayable run to     schedule across
                                            run                   transports
  degraded            YES: on_failure=      YES: poison bundles   YES: same scheduler,
  completion?         "skip" drops a        skipped, holes +      same skip accounting
                      raising profile,      per-fault recovery    over TCP
                      keeps the rest        cost in FleetReport
                                            .recovery
  dependency          no (edges would be    YES: bundles carry    YES: the same frontier
  edges?              silently ignored —    ``parents``; the      across agents — a
                      FleetConfig(dag=      stream's frontier     sink's parents may
                      True, executor=       dispatches a bundle   have replayed on
                      "thread") is          only after every      three different
                      rejected loudly)      parent's result       hosts; skipped-
                                            lands; a skipped      ancestor cascade
                                            parent cascades       identical
                                            (skipped_ancestor),
                                            a killed one just
                                            delays its children
  critical path?      no (no per-node       YES: FleetReport.dag  YES: same accounting
                      dispatch gating, so   carries critical_     (BundleTiming stamps
                      there is no DAG run   path_s / makespan_s   are coordinator-
                      to account)           / parallelism / per-  clock, transport-
                                            node slack_s from     agnostic)
                                            BundleTiming stamps;
                                            Perfetto export draws
                                            flow arrows along the
                                            edges
  open-loop           no (batch replay      YES: StandingFleet    YES: the same serve
  arrivals?           only: dispatch is     (repro.service)       loop over a warm
                      driven by the         holds the pool warm   agent pool; arrivals
                      source iterator,      and admits bundles    admit at arrival
                      not a clock)          at arrival time —     time across TCP
                                            seeded Poisson/
                                            diurnal/trace load
                                            independent of
                                            drain rate
  SLO accounting?     no (FleetReport       YES: repro.service    YES: same engine —
                      totals only)          .slo streams p50/     latency timeline and
                                            p99/p999 through a    fault windows are
                                            bounded sketch,       transport-agnostic
                                            counts per-window     monotonic stamps
                                            violations, joins
                                            chaos MTTR windows
                                            into the latency
                                            timeline
  traced?             partial: coordinator  YES: every party      YES: same recorder on
                      flight recorder       (coordinator, each    each agent; frames
                      only (no worker-      worker) runs a        hop agent->coordinator
                      side recorder to      FlightRecorder;       with the same clock
                      ship home)            worker frames ship    echo, so remote spans
                                            home piggybacked on   rebase through per-
                                            results, rebased      peer offset estimates;
                                            via per-peer          export with
                                            ClockSync onto one    repro.obs.trace or
                                            timeline              ``repro.scenarios
                                            (FleetReport.obs,     trace``
                                            Perfetto-exportable)
  metrics endpoint?   no (in-process        YES: fleet-level      YES: the same registry;
                      registry snapshot     MetricsRegistry       plus the service
                      only)                 snapshot in           /metrics scrape when
                                            FleetReport.obs;      driven through
                                            live Prometheus       repro.service
                                            scrape at /metrics
                                            when driven through
                                            repro.service
  best for            small fleets, tiny    large fleets,         fleets bigger than one
                      profiles, tests       collective legs,      machine; real TPU
                                            saturating a host     hosts joining later
  ==================  ====================  ====================  =====================

Rule of thumb: threads while the fleet is small enough that one process's
dispatch throughput isn't the bottleneck; processes when it is, when the
profiles carry collective legs, or when worker isolation matters; remote
when one machine isn't enough (or the workers must be *other* machines —
the paper's heterogeneous-resource pitch).  The remaining hop is real
``jax.distributed`` TPU workers: an agent whose WorkerSpec carries a
multi-host mesh instead of a forced-host-device one.

All of those knobs live on one picklable ``FleetConfig`` — the legacy
``executor=``/``max_workers=``/``mesh_spec=``/``hosts=``/``listen=``/
``agents=``/``timeout=`` kwarg sprawl on ``emulate_many``/``run_fleet``/
the CLI still works, but folds into a FleetConfig under a
DeprecationWarning.  Migrating is mechanical::

    # before
    em.emulate_many(profiles, executor="process", max_workers=8,
                    mesh_spec=MeshSpec(shape=(2,), axes=("model",)))

    # after — validated at construction, reusable across surfaces
    cfg = FleetConfig.process(max_workers=8, autoscale=True, min_workers=2,
                              mesh=MeshSpec(shape=(2,), axes=("model",)),
                              window=16)
    em.emulate_many(store.stream(tags), config=cfg, collect="totals")
    run_fleet(jobs, profiles=store.stream(tags), config=cfg)
"""
from repro.fleet.bundle import (MeshSpec, ScheduleBundle,  # noqa: F401
                                WorkerSpec, bundle_parents, bundle_profile)
from repro.fleet.chaos import ChaosPolicy, derive_seed  # noqa: F401
from repro.fleet.config import (UNSET, FleetConfig)  # noqa: F401
from repro.fleet.dag import critical_path, validate_parents  # noqa: F401
from repro.fleet.executor import (BundleTiming,  # noqa: F401
                                  CrashLoopError,
                                  FleetBase, Peer, PeerGone,
                                  ProcessFleet, run_process_fleet)
from repro.fleet.transport.remote import (RemoteFleet,  # noqa: F401
                                          run_remote_fleet)
