"""Process-level fleet execution: past the thread/GIL ceiling.

``Emulator.emulate_many`` replays a fleet of profiles concurrently; this
package supplies its ``executor="process"`` backend.  The schedule compiler
made the split cheap: a ``CompiledSchedule`` is plain numpy iteration
tables + resource vectors, so the parent compiles once, detaches each
schedule into a picklable ``ScheduleBundle``, and ships it to a pool of
spawn-based worker processes (``ProcessFleet``).  Each worker builds its
own ``Emulator`` + ``SegmentRunner`` exactly once — its own jax client,
its own jitted programs, its own plan cache, and (given a ``MeshSpec``)
its own device mesh — then replays bundles fused and streams back
``EmulationReport``s whose consumed totals are bit-identical to an
in-process replay of the same profile.

Thread vs process executor — decision matrix:

  =====================  =======================  =========================
  dimension              executor="thread"        executor="process"
  =====================  =======================  =========================
  parallelism ceiling    one GIL + one jax        one jax client *per
                         client; scales until     worker*; scales with
                         dispatch serializes      cores/hosts
  per-fleet overhead     ~zero (shared pool)      worker spawn + jax import
                                                  + trace, ONCE per worker
                                                  (keep the pool warm)
  plan/program sharing   fleet-wide PlanCache     per-worker cache; programs
                         + shared SegmentRunner   traced once per worker
  collectives            dropped (no per-thread   EXECUTE: each worker owns
                         mesh is possible)        a mesh built from MeshSpec
  failure isolation      a crash takes the        worker death is reaped,
                         whole fleet down         bundle re-queued, pool
                                                  refilled
  best for               small fleets, tiny       large fleets, collective
                         profiles, tests          legs, saturating a host
  =====================  =======================  =========================

Rule of thumb: threads while the fleet is small enough that one process's
dispatch throughput isn't the bottleneck; processes when it is, when the
profiles carry collective legs, or when worker isolation matters.  This is
also the stepping stone to multi-host scale-out — a ``ScheduleBundle`` that
crosses a process boundary crosses a network boundary just as easily.
"""
from repro.fleet.bundle import (MeshSpec, ScheduleBundle,  # noqa: F401
                                WorkerSpec, bundle_profile)
from repro.fleet.executor import (ProcessFleet,  # noqa: F401
                                  run_process_fleet)
