"""Fleet worker process: build an emulator once, replay bundles forever.

Spawned (never forked — a forked child would inherit the parent's
initialized XLA backend and its single-device view) by
``repro.fleet.executor.ProcessFleet`` with one end of a pipe and a
``WorkerSpec``.  Module-level imports stay light so worker start-up cost is
dominated by exactly one thing: the child's own jax import + program
tracing, which happens once per *worker*, not once per bundle — the whole
point of shipping detached schedules.

Protocol (pickled tuples over the pipe):

  parent -> worker:  ("run", idx, ScheduleBundle[, t_sent]) | ("stop",)
  worker -> parent:  ("ready", info_dict)
                     ("ok", idx, EmulationReport[, ObsFrame])
                     ("err", idx | None, traceback_str[, ObsFrame])
                     ("ping",)   heartbeat, sent every ``heartbeat_s``
                                 from a daemon thread when the spec asks
                     ("obs", ObsFrame)   final buffer, shipped on stop

The optional trailing fields are the flight-recorder piggyback
(``repro.obs``): dispatches carry the coordinator's clock stamp, and
every result ships the worker's drained event buffer home with that
stamp echoed, so the coordinator can estimate this worker's clock
offset and merge its events onto one timeline.  Both arities are
accepted on both ends — test fakes and older tooling speak the bare
tuples unchanged.

A bundle that fails to replay sends ``err`` and the worker keeps serving
(the parent decides whether to abort); a failure during initialization
sends ``err`` with ``idx=None`` and exits.

When the spec carries a ``ChaosPolicy``, the worker derives a
deterministic fault actor from its spawn ``scope`` (``"worker:<n>"``)
and consults it before replaying each bundle: it may die without
replying (``kill``), go silent with the pipe open and heartbeats paused
(``hang`` — the failure only heartbeat liveness can see), reply an
injected ``err`` (``fail``), or straggle (``delay``) before serving
normally.  All sends go through one lock so the heartbeat thread and
the serve loop never interleave a pickle mid-frame.
"""
from __future__ import annotations

import os
import threading
import time
import traceback


def _init(spec):
    """Build this worker's emulator (and mesh) from its spec; returns
    (emulator, info dict for the ready message)."""
    import jax

    from repro.core.atoms import PlanCache
    from repro.core.schedule import FusedSegment

    mesh = None
    if spec.mesh is not None:
        if jax.device_count() < spec.mesh.device_count:
            raise RuntimeError(
                f"worker has {jax.device_count()} device(s) but the mesh "
                f"spec needs {spec.mesh.device_count}; the parent must set "
                "--xla_force_host_platform_device_count before spawn")
        mesh = spec.mesh.build()
    em = spec.emulator.build(mesh=mesh)
    # one plan cache per worker process: barrier-step plans (storage,
    # collectives, odd-sized legs) dedup across every bundle this worker
    # will ever replay
    em.set_plan_cache(PlanCache())
    if spec.warmup:
        import numpy as np
        # trace the most common fused program shape (1-row table, both
        # carries) so the first real bundle doesn't pay for it
        em._segments.run(FusedSegment(
            table=np.asarray([[1, 1, 0]], dtype=np.int32), rows=[]))
        if em.collective is not None:
            # mesh-bound variant (all three carries) for fused wire rows,
            # plus a tiny per-sample plan for barrier-fallback bundles
            em._segments.run(FusedSegment(
                table=np.asarray([[1, 1, 1]], dtype=np.int32), rows=[]))
            em.collective.plan(float(1 << 10))()
    return em, {"pid": os.getpid(), "devices": jax.device_count(),
                "mesh": None if spec.mesh is None else list(spec.mesh.shape),
                "warm": bool(spec.warmup)}


def worker_loop(conn, spec, scope: str = "worker:0") -> None:
    """Process entry point: initialize, announce readiness, serve bundles."""
    from repro.obs.recorder import FlightRecorder

    chaos = getattr(spec, "chaos", None)
    actor = chaos.actor(scope) if chaos is not None else None
    # this worker's flight recorder: drained onto every reply, so the
    # coordinator's timeline grows worker-side events (replays,
    # collective legs) as results land — a kill loses only the events
    # since the last reply, which is exactly what a crash should cost
    recorder = FlightRecorder(scope, capacity=2048)
    if actor is not None and chaos.kill_on_init:
        # the crash-loop test vector: a spec that can never come up.
        # Die before the (expensive) emulator build so the breaker is
        # exercised at spawn cadence, not jax-import cadence.
        conn.close()
        os._exit(13)
    try:
        em, info = _init(spec)
    except BaseException:  # noqa: BLE001 — report init failure, then die
        try:
            conn.send(("err", None, traceback.format_exc()))
        finally:
            conn.close()
        return
    send_lock = threading.Lock()
    hb_stop = threading.Event()
    hb_pause = threading.Event()

    def send(msg) -> None:
        with send_lock:
            conn.send(msg)

    send(("ready", info))
    heartbeat_s = getattr(spec, "heartbeat_s", 0.0)
    if heartbeat_s and heartbeat_s > 0:
        def _beat():
            # first beat fires immediately: a worker whose whole useful
            # life fits inside one interval still registers a pulse
            while True:
                if not hb_pause.is_set():  # hung workers don't heartbeat
                    try:
                        send(("ping",))
                    except (BrokenPipeError, OSError):
                        return
                if hb_stop.wait(heartbeat_s):
                    return
        threading.Thread(target=_beat, daemon=True,
                         name="fleet-heartbeat").start()
    try:
        while True:
            try:
                msg = conn.recv()
            except EOFError:          # parent died: nothing left to serve
                break
            if msg[0] == "stop":
                try:
                    send(("obs", recorder.drain()))
                except (BrokenPipeError, OSError):
                    pass
                break
            if msg[0] != "run":
                send(("err", None, f"unknown message {msg[0]!r}"))
                continue
            idx, bundle = msg[1], msg[2]
            if len(msg) > 3:            # coordinator clock echo
                recorder.last_echo = msg[3]
            if actor is not None:
                action = actor.on_dispatch()
                if action == "kill":
                    # die mid-bundle, before replying: the coordinator
                    # must notice the dead pipe, requeue idx, and charge
                    # the attempt budget
                    conn.close()
                    os._exit(17)
                if action == "fail":
                    send(("err", idx,
                          f"chaos: injected failure ({scope}, "
                          f"dispatch {actor.dispatches})",
                          recorder.drain()))
                    continue
                if isinstance(action, tuple):
                    what, seconds = action
                    if what == "hang":
                        # silent with the pipe open: no reply, no
                        # heartbeat — only the liveness watermark can
                        # tell this apart from a long bundle
                        hb_pause.set()
                        time.sleep(seconds)
                        hb_pause.clear()
                    elif what == "delay":
                        time.sleep(seconds)   # straggler: serve, but late
            try:
                rep = em.replay(bundle.rehydrate(),
                                command=bundle.command,
                                planned=bundle.planned,
                                flops_scale=bundle.flops_scale,
                                storage_scale=bundle.storage_scale,
                                mem_scale=bundle.mem_scale,
                                verify=bundle.verify)
            except BaseException:  # noqa: BLE001 — bad bundle, worker lives
                try:
                    send(("err", idx, traceback.format_exc(),
                          recorder.drain()))
                except (BrokenPipeError, OSError):
                    break             # parent reaped us mid-hang: done
                continue
            recorder.record("segment_replay", idx=idx, ttc_s=rep.ttc_s,
                            n_dispatches=rep.n_dispatches,
                            mode=rep.mode, n_samples=rep.n_samples)
            if rep.n_collective_dispatches:
                # a "collective_group" tag names the logical collective
                # this bundle's legs belong to — the trace exporter links
                # same-group legs across workers with flow arrows
                group = bundle.tags.get("collective_group")
                if group is not None:
                    recorder.record("collective_leg", idx=idx,
                                    n=rep.n_collective_dispatches,
                                    ici_bytes=rep.emulated_ici_bytes,
                                    group=group)
                else:
                    recorder.record("collective_leg", idx=idx,
                                    n=rep.n_collective_dispatches,
                                    ici_bytes=rep.emulated_ici_bytes)
            try:
                send(("ok", idx, rep, recorder.drain()))
            except (BrokenPipeError, OSError):
                break                 # parent reaped us mid-hang: done
    finally:
        hb_stop.set()
        em.storage.cleanup()
        conn.close()
