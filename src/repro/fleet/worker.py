"""Fleet worker process: build an emulator once, replay bundles forever.

Spawned (never forked — a forked child would inherit the parent's
initialized XLA backend and its single-device view) by
``repro.fleet.executor.ProcessFleet`` with one end of a pipe and a
``WorkerSpec``.  Module-level imports stay light so worker start-up cost is
dominated by exactly one thing: the child's own jax import + program
tracing, which happens once per *worker*, not once per bundle — the whole
point of shipping detached schedules.

Protocol (pickled tuples over the pipe):

  parent -> worker:  ("run", idx, ScheduleBundle) | ("stop",)
  worker -> parent:  ("ready", info_dict)
                     ("ok", idx, EmulationReport)
                     ("err", idx | None, traceback_str)

A bundle that fails to replay sends ``err`` and the worker keeps serving
(the parent decides whether to abort); a failure during initialization
sends ``err`` with ``idx=None`` and exits.
"""
from __future__ import annotations

import os
import traceback


def _init(spec):
    """Build this worker's emulator (and mesh) from its spec; returns
    (emulator, info dict for the ready message)."""
    import jax

    from repro.core.atoms import PlanCache
    from repro.core.schedule import FusedSegment

    mesh = None
    if spec.mesh is not None:
        if jax.device_count() < spec.mesh.device_count:
            raise RuntimeError(
                f"worker has {jax.device_count()} device(s) but the mesh "
                f"spec needs {spec.mesh.device_count}; the parent must set "
                "--xla_force_host_platform_device_count before spawn")
        mesh = spec.mesh.build()
    em = spec.emulator.build(mesh=mesh)
    # one plan cache per worker process: barrier-step plans (storage,
    # collectives, odd-sized legs) dedup across every bundle this worker
    # will ever replay
    em.set_plan_cache(PlanCache())
    if spec.warmup:
        import numpy as np
        # trace the most common fused program shape (1-row table, both
        # carries) so the first real bundle doesn't pay for it
        em._segments.run(FusedSegment(
            table=np.asarray([[1, 1, 0]], dtype=np.int32), rows=[]))
        if em.collective is not None:
            # mesh-bound variant (all three carries) for fused wire rows,
            # plus a tiny per-sample plan for barrier-fallback bundles
            em._segments.run(FusedSegment(
                table=np.asarray([[1, 1, 1]], dtype=np.int32), rows=[]))
            em.collective.plan(float(1 << 10))()
    return em, {"pid": os.getpid(), "devices": jax.device_count(),
                "mesh": None if spec.mesh is None else list(spec.mesh.shape),
                "warm": bool(spec.warmup)}


def worker_loop(conn, spec) -> None:
    """Process entry point: initialize, announce readiness, serve bundles."""
    try:
        em, info = _init(spec)
    except BaseException:  # noqa: BLE001 — report init failure, then die
        try:
            conn.send(("err", None, traceback.format_exc()))
        finally:
            conn.close()
        return
    conn.send(("ready", info))
    try:
        while True:
            try:
                msg = conn.recv()
            except EOFError:          # parent died: nothing left to serve
                break
            if msg[0] == "stop":
                break
            if msg[0] != "run":
                conn.send(("err", None, f"unknown message {msg[0]!r}"))
                continue
            _, idx, bundle = msg
            try:
                rep = em.replay(bundle.rehydrate(),
                                command=bundle.command,
                                planned=bundle.planned,
                                flops_scale=bundle.flops_scale,
                                storage_scale=bundle.storage_scale,
                                mem_scale=bundle.mem_scale,
                                verify=bundle.verify)
            except BaseException:  # noqa: BLE001 — bad bundle, worker lives
                conn.send(("err", idx, traceback.format_exc()))
                continue
            conn.send(("ok", idx, rep))
    finally:
        em.storage.cleanup()
        conn.close()
