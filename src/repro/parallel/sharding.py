"""Logical-axis sharding rules and activation constraints.

Model code names axes logically (``shard(x, "batch", "seq_shard", None)``);
rules bound to the active mesh resolve logical names to mesh axes.  Outside a
sharding context (single-device CPU tests) everything is a no-op, so the same
model code runs everywhere.

Rule sets implement the distribution design of DESIGN.md §5:
  * TP  : heads / ff / vocab / experts  -> 'model'
  * DP  : batch                         -> ('pod', 'data')   (pod folded into DP)
  * SP  : residual-stream seq           -> 'model' (Megatron sequence parallelism)
  * EP  : expert dim                    -> 'model'
  * decode: KV-cache length             -> 'model' (avoids kv-head padding; see DESIGN)
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]

TRAIN_RULES: Dict[str, AxisVal] = {
    # weights
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "ssm_inner": "model",
    "layers": None,
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "seq_shard": "model",       # sequence-parallel residual stream
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_ff": "model",
    "act_experts": "model",
    "act_ssm_heads": "model",
    "act_ssm_inner": "model",
    "expert_cap": None,
    "cache_seq": "model",       # decode KV cache shards length, not kv-heads
    # misc
    "stage": "pod",             # pipeline-parallel stage placement (optional path)
    "opt_shard": ("pod", "data"),  # ZeRO-1 optimizer-state sharding axes
    "fsdp": ("pod", "data"),    # ZeRO-3 secondary weight sharding axes
}

# Prefill: like training (seq-parallel residual), cache written length-sharded.
PREFILL_RULES = dict(TRAIN_RULES)

# Decode: length-1 activations replicate head/seq axes; weights stay TP-sharded;
# the model axis works on KV-cache length shards instead.
DECODE_RULES = dict(TRAIN_RULES)
DECODE_RULES.update({
    "seq_shard": None,
    "act_heads": None,
    "act_kv_heads": None,
    # Serving weights stay 2D-sharded (model × data).  A 72B/108B bf16
    # checkpoint at TP=16 alone is 13.5 GB/chip — over budget with the KV
    # cache — so the data axis must carry weight shards too; GSPMD either
    # moves activations (2D weight-stationary TP) or gathers one layer at a
    # time inside the scan.  The roofline table prices the resulting
    # collective term; see EXPERIMENTS.md §Perf for the latency trade-off.
    "fsdp": "data",
})

# Pure-FSDP (ZeRO-3) training layout: no tensor parallelism — batch shards
# over every axis, weights/optimizer shard over every axis, per-layer weight
# all-gathers replace the Megatron activation collectives.  Wins when
# tokens-per-chip is small relative to weights (qwen2-72b/train_4k: 4.1x
# less wire than TP+SP; EXPERIMENTS.md §Perf).
FSDP_RULES: Dict[str, AxisVal] = {k: None for k in TRAIN_RULES}
FSDP_RULES.update({
    "batch": ("data", "model"),
    "stage": "pod",
    "opt_shard": ("pod", "data", "model"),
    "fsdp": ("pod", "data", "model"),
})

# long_500k (global_batch=1): nothing to data-shard, so context-parallelize the
# KV cache over BOTH data and model axes (2048 positions/chip at 512k×256).
LONG_DECODE_RULES = dict(DECODE_RULES)
LONG_DECODE_RULES.update({
    "batch": None,
    "cache_seq": ("data", "model"),
})


@dataclass(frozen=True)
class Rules:
    table: Dict[str, AxisVal]
    mesh_axes: Tuple[str, ...]
    mesh_shape: Dict[str, int] = field(default_factory=dict)

    def resolve(self, logical: Optional[str]) -> AxisVal:
        if logical is None:
            return None
        if logical not in self.table:
            raise KeyError(f"unknown logical axis {logical!r}")
        v = self.table[logical]
        if v is None:
            return None
        if isinstance(v, str):
            return v if v in self.mesh_axes else None
        kept = tuple(a for a in v if a in self.mesh_axes)
        return kept if kept else None

    def axis_size(self, v: AxisVal) -> int:
        if v is None:
            return 1
        if isinstance(v, str):
            v = (v,)
        n = 1
        for a in v:
            n *= self.mesh_shape.get(a, 1)
        return n

    def pspec(self, *axes: Optional[str]) -> P:
        return P(*[self.resolve(a) for a in axes])

    def pspec_checked(self, shape: Tuple[int, ...],
                      axes: Tuple[Optional[str], ...],
                      tp_fallback: bool = False) -> P:
        """Resolve axes, dropping assignments that do not divide the dim.

        ``tp_fallback`` (weights only):
          (a) if nothing landed on 'model' and the tensor is large, place
              'model' on the largest divisible free dim — row-parallel
              fallback for head counts that don't divide TP (llama4: 40
              heads on model=16 -> shard d_model instead);
          (b) FSDP/ZeRO-3: additionally shard large weights over the 'fsdp'
              axes ('data') so parameter + optimizer memory scales with the
              full chip count; GSPMD materializes the per-layer all-gather
              inside the layer scan.
        """
        parts = []
        used = set()
        for dim, ax in zip(shape, axes):
            r = self.resolve(ax)
            names = (r,) if isinstance(r, str) else (r or ())
            if r is not None and dim % self.axis_size(r) == 0 and \
                    not (set(names) & used):
                parts.append(r)
                used.update(names)
            else:
                parts.append(None)
        numel = 1
        for d in shape:
            numel *= d
        tp_mode = self.table.get("heads") is not None
        if tp_fallback and tp_mode and "model" in self.mesh_shape and \
                "model" not in used and numel >= (1 << 20):
            cands = [(d, i) for i, (d, pspec_e) in
                     enumerate(zip(shape, parts)) if pspec_e is None and
                     d % self.mesh_shape["model"] == 0 and d > 1]
            if cands:
                _, i = max(cands)
                parts[i] = "model"
                used.add("model")
        fsdp = self.resolve("fsdp") if tp_fallback and \
            "fsdp" in self.table else None
        if fsdp is not None and numel >= (1 << 21):
            fnames = set((fsdp,) if isinstance(fsdp, str) else fsdp)
            if not (fnames & used):
                n = self.axis_size(fsdp)
                cands = [(d, i) for i, (d, pspec_e) in
                         enumerate(zip(shape, parts))
                         if pspec_e is None and d % n == 0 and d >= n]
                if cands:
                    _, i = max(cands)
                    parts[i] = fsdp
        return P(*parts)


@dataclass
class ShardingCtx:
    mesh: Mesh
    rules: Rules


_STATE = threading.local()


def current_ctx() -> Optional[ShardingCtx]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], table: Dict[str, AxisVal]):
    prev = current_ctx()
    if mesh is None:
        _STATE.ctx = None
    else:
        _STATE.ctx = ShardingCtx(mesh, _bind(mesh, table))
    try:
        yield _STATE.ctx
    finally:
        _STATE.ctx = prev


def _bind(mesh: Mesh, table: Dict[str, AxisVal]) -> Rules:
    shape = {n: int(s) for n, s in zip(mesh.axis_names, mesh.devices.shape)}
    return Rules(table, tuple(mesh.axis_names), shape)


def make_rules(mesh: Optional[Mesh], table: Dict[str, AxisVal]) -> Optional[Rules]:
    if mesh is None:
        return None
    return _bind(mesh, table)


def shard(x, *axes: Optional[str]):
    """Constrain activation ``x`` to logical axes (no-op w/o a sharding ctx)."""
    ctx = current_ctx()
    if ctx is None:
        return x
    if x.ndim != len(axes):
        raise ValueError(f"rank mismatch: {x.shape} vs axes {axes}")
    spec = ctx.rules.pspec_checked(tuple(x.shape), axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def named_sharding(*axes: Optional[str]) -> Optional[NamedSharding]:
    ctx = current_ctx()
    if ctx is None:
        return None
    return NamedSharding(ctx.mesh, ctx.rules.pspec(*axes))


def batch_axis_size(mesh: Optional[Mesh], table=TRAIN_RULES) -> int:
    """Total data-parallel degree of the mesh (pod × data)."""
    if mesh is None:
        return 1
    rules = _bind(mesh, table)
    v = rules.resolve("batch")
    if v is None:
        return 1
    if isinstance(v, str):
        v = (v,)
    n = 1
    for a in v:
        n *= mesh.shape[a]
    return n
