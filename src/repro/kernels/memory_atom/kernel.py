"""Synapse memory atom against HBM.

The paper's memory atom malloc/frees tunable buffers; on a TPU the analogous
resource is HBM<->VMEM bandwidth.  The kernel streams an array block-by-block
through VMEM (read + scale + write), so bytes_moved = 2 * size * passes and
the sustained rate is the HBM roofline.  ``block`` is the paper's tunable
block-size knob (§IV-E.3): small blocks under-utilize the DMA engines —
bench_roofline sweeps it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stream_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 1.0000001


def stream_pass(x: jax.Array, *, block: int, interpret: bool = True):
    """One read+write pass over x [n] (n % block == 0), block-tiled."""
    n = x.shape[0]
    assert n % block == 0, (n, block)
    return pl.pallas_call(
        _stream_kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(x)
