from repro.kernels.memory_atom import ops, ref  # noqa
