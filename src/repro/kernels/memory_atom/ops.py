"""Jit'd wrapper used by repro.core.atoms.MemoryAtom (backend="pallas")."""
import functools

import jax

from repro.kernels.memory_atom import kernel


@functools.partial(jax.jit, static_argnames=("iters", "block", "interpret"))
def stream(x, *, iters: int, block: int = 1 << 15,
           block_bytes: int = 0, interpret: bool = True):
    if block_bytes:
        block = min(block_bytes // x.dtype.itemsize, x.shape[0])
    block = min(block, x.shape[0])

    def body(_, y):
        return kernel.stream_pass(y, block=block, interpret=interpret)
    return jax.lax.fori_loop(0, iters, body, x)
