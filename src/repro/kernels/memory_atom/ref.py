"""Pure-jnp oracle for the memory atom."""


def stream_pass(x, *, block: int = 0):
    del block
    return x * 1.0000001


def bytes_moved(nbytes: int, passes: int) -> float:
    return 2.0 * nbytes * passes
