"""Jit'd wrapper used by repro.core.atoms.ComputeAtom (backend="pallas")."""
import functools

import jax
import jax.numpy as jnp

from repro.kernels.compute_atom import kernel


@functools.partial(jax.jit, static_argnames=("iters", "tile", "interpret"))
def _burn(x, *, iters: int, tile: int, interpret: bool = True):
    return kernel.burn_tile(x, iters=iters, interpret=interpret)


def burn(x=None, *, iters: int, tile: int = 256, interpret: bool = True):
    if x is None:
        x = jnp.eye(tile, dtype=jnp.float32) * 0.5
    return _burn(x, iters=iters, tile=tile, interpret=interpret)
