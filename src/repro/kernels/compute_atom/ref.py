"""Pure-jnp oracle for the compute atom."""
import jax
import jax.numpy as jnp


def burn_tile(x, *, iters: int):
    def body(_, y):
        y = jnp.dot(y, x, preferred_element_type=jnp.float32)
        return y * 0.5 + 0.25
    return jax.lax.fori_loop(0, iters, body, x)


def flops(tile: int, iters: int) -> float:
    return 2.0 * tile ** 3 * iters
