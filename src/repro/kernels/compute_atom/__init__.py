from repro.kernels.compute_atom import ops, ref  # noqa
