"""Synapse compute atom on the MXU.

The paper's compute atom is "a loop of assembly code that efficiently
performs a matrix multiplication", sized to stay cache-resident, whose loop
rate throttles emulated efficiency.  TPU translation: a VMEM-resident
``tile × tile`` f32 matmul chained ``iters`` times through the MXU —
the tile never leaves VMEM, so sustained FLOP/s ~ MXU peak, and ``duty``
(handled in ops.py by scaling iters) is the paper's efficiency knob.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _burn_kernel(x_ref, o_ref, *, iters: int):
    x = x_ref[...]
    def body(_, y):
        # renormalizing keeps values bounded over arbitrarily many iters
        y = jnp.dot(y, x, preferred_element_type=jnp.float32)
        return y * 0.5 + 0.25
    o_ref[...] = jax.lax.fori_loop(0, iters, body, x)


def burn_tile(x: jax.Array, *, iters: int, interpret: bool = True):
    """x: [tile, tile] f32 -> same shape; executes ``iters`` MXU matmuls."""
    tile = x.shape[0]
    assert x.shape == (tile, tile) and tile % 8 == 0, x.shape
    return pl.pallas_call(
        functools.partial(_burn_kernel, iters=iters),
        grid=(1,),
        in_specs=[pl.BlockSpec((tile, tile), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((tile, tile), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((tile, tile), jnp.float32),
        interpret=interpret,
    )(x)
