"""Pallas TPU kernels for the perf-critical hot spots.

Each kernel is a subpackage: ``kernel.py`` (pl.pallas_call + explicit
BlockSpec VMEM tiling), ``ops.py`` (jit'd public wrapper), ``ref.py``
(pure-jnp oracle).  Validated in interpret mode on CPU; TPU is the target.
"""
