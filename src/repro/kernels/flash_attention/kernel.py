"""Blocked causal attention on TPU (FlashAttention-2 forward).

Grid: (batch·q_heads, num_q_blocks, num_kv_blocks) — the last axis is the
TPU-sequential accumulation axis.  Online-softmax state (m, l, acc) lives in
VMEM scratch and persists across the kv grid steps; the output block is
written once at the last kv step.  Q/K/V blocks are VMEM-tiled via BlockSpec
(block_q×hd and block_kv×hd with hd untiled — hd is 64..256 here, a multiple
of the 128 lane width or padded by mosaic).  GQA is handled in the K/V index
maps (head h reads kv head h // group) so grouped K/V are never materialized.

Against the XLA path (models/layers.attend_blocked) the win is structural:
logits/probability blocks never leave VMEM, removing the dominant
O(S²/blk·f32) HBM traffic term from the roofline (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, window: Optional[int],
               softcap: Optional[float], block_q: int, block_kv: int,
               nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # [bq, hd]
    k = k_ref[0]                                   # [bkv, hd]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # [bq, bkv]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    qp = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                 (block_q, block_kv), 0)
    kp = ik * block_kv + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_kv), 1)
    ok = (kp <= qp) if causal else jnp.ones_like(qp, bool)
    if window is not None:
        ok = jnp.logical_and(ok, qp - kp < window)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 512, block_kv: int = 512,
                    group: int = 1, interpret: bool = True):
    """q: [BH, Sq, hd]; k, v: [BKV, Sk, hd] with BH == BKV * group."""
    BH, Sq, hd = q.shape
    BKV, Sk, _ = k.shape
    assert BH == BKV * group, (BH, BKV, group)
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Sk)
    assert Sq % block_q == 0 and Sk % block_kv == 0
    nq, nk = Sq // block_q, Sk // block_kv
    scale = hd ** -0.5

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_kv=block_kv, nk=nk)

    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_kv, hd),
                         lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, block_kv, hd),
                         lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
