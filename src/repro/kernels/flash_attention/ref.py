"""Pure-jnp oracle: dense softmax attention over [BH, S, hd] layout."""
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None, group: int = 1):
    BH, Sq, hd = q.shape
    if group > 1:
        k = jnp.repeat(k, group, axis=0)
        v = jnp.repeat(v, group, axis=0)
    Sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q, k,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    ok = (kp <= qp) if causal else jnp.ones((Sq, Sk), bool)
    if window is not None:
        ok = jnp.logical_and(ok, qp - kp < window)
    s = jnp.where(ok[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)
