"""Jit'd wrappers; ``flash_attention_grouped`` matches the model-layer
calling convention (q [B,S,Hk,G,hd], k/v [B,S,Hk,hd])."""
import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "block_q", "block_kv", "group",
    "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    block_q=512, block_kv=512, group=1, interpret=True):
    return kernel.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv, group=group, interpret=interpret)


def flash_attention_grouped(qg, k, v, *, causal=True, window=None,
                            softcap=None, block_q=512, block_kv=512,
                            interpret=True):
    """qg: [B,S,Hk,G,hd]; k/v: [B,S,Hk,hd] -> [B,S,Hk,G,hd]."""
    B, S, Hk, G, hd = qg.shape
    qf = jnp.moveaxis(qg, 1, 3).reshape(B * Hk * G, S, hd)
    kf = jnp.moveaxis(k, 1, 2).reshape(B * Hk, S, hd)
    vf = jnp.moveaxis(v, 1, 2).reshape(B * Hk, S, hd)
    out = flash_attention(qf, kf, vf, causal=causal, window=window,
                          softcap=softcap, block_q=block_q,
                          block_kv=block_kv, group=G, interpret=interpret)
    return jnp.moveaxis(out.reshape(B, Hk, G, S, hd), 3, 1)
