"""Serving steps: prefill (builds the cache, returns first sampled token) and
decode (one token for the whole batch against the cache).  Greedy argmax
sampling keeps the dry-run deterministic; the engine layer adds temperature.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.model_zoo import Model
from repro.parallel.sharding import (DECODE_RULES, PREFILL_RULES,
                                     use_sharding)


def greedy_token(model: Model, params, hidden_last):
    logits = model.logits(params, hidden_last)       # [B,1,V]
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)  # [B,1]


def make_prefill_step(model: Model, max_len: int, src_len: Optional[int] = None,
                      mesh=None, rules_table=PREFILL_RULES):
    def prefill_step(params, batch):
        with use_sharding(mesh, rules_table):
            leaf = batch.get("tokens", batch.get("tgt_tokens",
                                                 batch.get("embeds")))
            B = leaf.shape[0]
            cache = model.init_cache(B, max_len, src_len=src_len) \
                if model.cfg.family == "encdec" else \
                model.init_cache(B, max_len)
            hidden, cache, _ = model.forward(params, batch, cache=cache)
            tok = greedy_token(model, params, hidden[:, -1:])
            return tok, cache
    return prefill_step


def make_decode_step(model: Model, mesh=None, rules_table=DECODE_RULES):
    def decode_step(params, tokens, cache):
        with use_sharding(mesh, rules_table):
            hidden, cache, _ = model.forward(params, {"tokens": tokens},
                                             cache=cache, decode=True)
            tok = greedy_token(model, params, hidden)
            return tok, cache
    return decode_step
