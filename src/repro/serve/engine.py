"""Batched serving engine: continuous prefill+decode over a request queue.

Single-host reference implementation of the serving layer the decode cells
dry-run: fixed-size batch slots, greedy sampling, per-slot stop lengths.
The Synapse runtime watchers can profile ``serve_requests`` exactly like a
training run (examples/serve_profile.py), and the decode-step TTC predicted
by the roofline feeds the SLA/straggler monitor at scale.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model_zoo import Model
from repro.serve.step import make_decode_step, make_prefill_step


@dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, model: Model, params, *, batch_slots: int = 4,
                 max_len: int = 256, mesh=None):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.prefill = jax.jit(make_prefill_step(model, max_len, mesh=mesh))
        self.decode = jax.jit(make_decode_step(model, mesh=mesh),
                              donate_argnums=2)

    def serve(self, requests: List[Request]) -> List[Request]:
        """Static batching: pad the wave to batch_slots, prefill, decode to
        the longest max_new_tokens, per-request early stop bookkeeping."""
        for wave_start in range(0, len(requests), self.B):
            wave = requests[wave_start:wave_start + self.B]
            self._serve_wave(wave)
        return requests

    def _serve_wave(self, wave: List[Request]):
        B = self.B
        plen = max(len(r.prompt) for r in wave)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(wave):
            toks[i, plen - len(r.prompt):] = r.prompt   # left-pad
        tok, cache = self.prefill(self.params, {"tokens": jnp.asarray(toks)})
        steps = max(r.max_new_tokens for r in wave)
        for i, r in enumerate(wave):
            r.out_tokens.append(int(tok[i, 0]))
        for _ in range(steps - 1):
            tok, cache = self.decode(self.params, tok, cache)
            t = np.asarray(tok)
            for i, r in enumerate(wave):
                if not r.done and len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(t[i, 0]))
                else:
                    r.done = True
        for r in wave:
            r.done = True
