"""Bounded flight recorder of typed, picklable fleet events.

Every party in a fleet — the coordinator, each ``worker_loop``, each
host agent — runs one ``FlightRecorder``.  Events are small frozen
dataclasses stamped on the recording process's monotonic clock
(``obs.clock.now()``); worker/agent buffers ship home as ``ObsFrame``s
piggybacked on result/stop frames and are absorbed onto the
coordinator's timeline after a per-peer ``ClockSync`` rebase.

Determinism contract
--------------------
Event *identity* is ``(scope, kind, ordinal)``: ordinals are 1-based
per-(scope, kind) counters (the same discipline ``ChaosActor`` uses for
its per-scope fault streams), and ``Event.eid`` is a truncated sha256
of that triple.  A seeded chaos run therefore emits a deterministic
event *sequence* — rerunning the same (seed, policy, fleet shape)
yields the same kinds, scopes and ordinals even though every timestamp
differs.  ``event_sequence()`` is the canonical projection tests and CI
compare; wall-driven kinds (heartbeat cadence, respawn readiness,
queue-pressure autoscale, straggler speculation) are excluded from it
because whether and how often they fire depends on machine speed, not
on the seeded schedule.

Truncation is never silent: when the ring is full the oldest event is
dropped and ``dropped_events`` increments, and frames carry their
origin's drop count so the merged timeline can report a total.
"""
from __future__ import annotations

import hashlib
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.obs import clock

#: Event kinds with a stable meaning across the fleet.  The recorder
#: accepts any kind string (plugins may extend), but these are the ones
#: the executor/worker/agent emit and the trace exporter styles.
KINDS = (
    "enqueue",          # coordinator: bundle entered the pending queue
    "dispatch",         # coordinator/worker: bundle handed to a peer
    "requeue",          # coordinator: bundle returned for another attempt
    "done",             # coordinator: bundle's report folded
    "skip",             # coordinator: poison budget spent, hole folded
                        #   (reason="ancestor": cascade hole — a bundle
                        #   this one depends on was skipped, not itself)
    "dep_wait",         # coordinator: bundle admitted but blocked on
                        #   unmet dependency edges (frontier)
    "dep_release",      # coordinator: last unmet parent landed — the
                        #   bundle entered the dispatchable frontier
    "heartbeat",        # any: liveness pulse observed (excluded from seq)
    "scale_up",         # coordinator: pool grew
    "scale_down",       # coordinator: pool shrank (drain or midstream)
    "fault_opened",     # coordinator: a peer died / went silent
    "fault_repaired",   # coordinator: replacement became ready
    "segment_replay",   # worker: one bundle replayed (per-bundle costs)
    "collective_leg",   # worker: bundle carried collective dispatches
    "speculate",        # coordinator: straggler double-dispatched
    "crash_loop",       # coordinator: respawn breaker opened
)

#: Kinds whose occurrence depends on wall time rather than the seeded
#: schedule — heartbeat cadence, whether a respawn warmed before the
#: stream drained, queue-pressure autoscale, straggler quantiles —
#: excluded from the canonical determinism sequence.  (``fault_opened``
#: stays in: chaos kills are dispatch-counted, so deaths are part of
#: the schedule.)
TIMER_KINDS = frozenset({"heartbeat", "fault_repaired", "scale_up",
                         "scale_down", "speculate"})


def _eid(scope: str, kind: str, ordinal: int) -> str:
    h = hashlib.sha256(f"{scope}|{kind}|{ordinal}".encode())
    return h.hexdigest()[:12]


@dataclass(frozen=True)
class Event:
    """One recorded fact.  ``t`` is monotonic in the *recorder's* clock
    domain until absorbed (rebased) onto another timeline."""
    kind: str
    scope: str
    ordinal: int
    t: float
    data: Tuple[Tuple[str, object], ...] = ()
    eid: str = ""

    def get(self, key: str, default=None):
        for k, v in self.data:
            if k == key:
                return v
        return default

    def to_dict(self) -> dict:
        return {"kind": self.kind, "scope": self.scope,
                "ordinal": self.ordinal, "t": self.t, "eid": self.eid,
                "data": dict(self.data)}

    @classmethod
    def from_dict(cls, d: dict) -> "Event":
        return cls(kind=d["kind"], scope=d["scope"], ordinal=d["ordinal"],
                   t=d["t"], data=tuple(sorted(d.get("data", {}).items())),
                   eid=d.get("eid", ""))


@dataclass(frozen=True)
class ObsFrame:
    """A drained buffer in flight: origin scope, its events (origin
    clock domain), how many that origin has dropped so far, and a clock
    echo — ``echo_t`` is the last coordinator-domain stamp the sender
    saw (from a dispatch frame), ``sent_at`` the sender's clock when
    the frame was built.  The receiving side turns the pair plus its
    own arrival stamp into a ``ClockSync`` observation."""
    scope: str
    events: Tuple[Event, ...] = ()
    dropped: int = 0
    echo_t: Optional[float] = None
    sent_at: float = 0.0


class FlightRecorder:
    """Bounded ring buffer of events with deterministic ordinals.

    Not thread-safe by itself; callers that record from multiple
    threads (the executor's collect loop vs. timing callbacks) must
    serialize — in practice every recording site in the fleet already
    runs on one thread per recorder.
    """

    def __init__(self, scope: str, capacity: int = 4096) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.scope = scope
        self.capacity = capacity
        self.dropped_events = 0          # oldest-evicted, never silent
        self._ring: deque = deque()
        self._ordinals: Dict[Tuple[str, str], int] = {}
        #: drop counts reported by absorbed foreign frames, by scope
        self.foreign_dropped: Dict[str, int] = {}
        #: coordinator-domain stamp of the most recent dispatch echo —
        #: workers copy it into the frames they ship home
        self.last_echo: Optional[float] = None

    # -- recording -----------------------------------------------------
    def record(self, kind: str, t: Optional[float] = None,
               scope: Optional[str] = None, **data) -> Event:
        """Append one event; ordinal is the next in this recorder's
        per-(scope, kind) stream."""
        sc = scope if scope is not None else self.scope
        key = (sc, kind)
        ordinal = self._ordinals.get(key, 0) + 1
        self._ordinals[key] = ordinal
        ev = Event(kind=kind, scope=sc, ordinal=ordinal,
                   t=clock.now() if t is None else t,
                   data=tuple(sorted(data.items())),
                   eid=_eid(sc, kind, ordinal))
        self._append(ev)
        return ev

    def _append(self, ev: Event) -> None:
        if len(self._ring) >= self.capacity:
            self._ring.popleft()
            self.dropped_events += 1
        self._ring.append(ev)

    # -- shipping ------------------------------------------------------
    def drain(self, echo_t: Optional[float] = None) -> ObsFrame:
        """Package and clear the buffer for piggybacking on a reply.
        ``dropped`` carries the lifetime drop count (idempotent to
        re-report; receivers keep the max per scope)."""
        frame = ObsFrame(scope=self.scope, events=tuple(self._ring),
                         dropped=self.dropped_events,
                         echo_t=echo_t if echo_t is not None
                         else self.last_echo,
                         sent_at=clock.now())
        self._ring.clear()
        return frame

    def absorb(self, frame: ObsFrame,
               to_local: Optional[Callable[[float], float]] = None) -> None:
        """Merge a foreign frame onto this timeline, rebasing stamps
        through ``to_local`` (a ``ClockSync.to_local`` bound method, or
        identity for same-process sources).  Foreign ordinals are kept:
        they were assigned by the origin recorder under its own scope,
        so they cannot clash with local streams."""
        self.foreign_dropped[frame.scope] = max(
            self.foreign_dropped.get(frame.scope, 0), frame.dropped)
        for ev in frame.events:
            t = to_local(ev.t) if to_local is not None else ev.t
            if t != ev.t:
                ev = Event(kind=ev.kind, scope=ev.scope, ordinal=ev.ordinal,
                           t=t, data=ev.data, eid=ev.eid)
            self._append(ev)

    # -- reading -------------------------------------------------------
    def events(self) -> List[Event]:
        return list(self._ring)

    def __len__(self) -> int:
        return len(self._ring)

    @property
    def total_dropped(self) -> int:
        """Local drops plus every absorbed origin's reported drops."""
        return self.dropped_events + sum(self.foreign_dropped.values())

    def tail(self, n: int) -> List[Event]:
        """Last ``n`` events in arrival order (postmortem dump)."""
        if n <= 0:
            return []
        return list(self._ring)[-n:]

    def snapshot(self, last_n: Optional[int] = None) -> dict:
        """JSON-able view for ``FleetReport.obs``."""
        evs = self.events() if last_n is None else self.tail(last_n)
        return {
            "schema": 1,
            "scope": self.scope,
            "events": [e.to_dict() for e in evs],
            "dropped_events": self.total_dropped,
            "clock": {"anchor_mono": clock.anchor()[0],
                      "anchor_wall": clock.anchor()[1]},
        }


def event_sequence(events: Iterable[Event],
                   exclude: frozenset = TIMER_KINDS
                   ) -> List[Tuple[str, str, int]]:
    """Canonical determinism projection: ``(scope, kind, ordinal)``
    triples, timestamps excluded, timer-driven kinds excluded, sorted —
    two seeded runs of the same fleet must produce identical lists."""
    return sorted((e.scope, e.kind, e.ordinal)
                  for e in events if e.kind not in exclude)
