"""Chrome trace-event JSON export of a merged fleet timeline.

Perfetto (https://ui.perfetto.dev) and ``chrome://tracing`` both load
the JSON object format: ``{"traceEvents": [...]}`` with microsecond
timestamps.  The mapping here:

* one *process* per fleet run (pid 1), one *thread track* per scope
  (coordinator, ``worker:N``, ``agent``, ...), named via ``"M"``
  metadata events;
* complete spans (``"ph": "X"``) for bundle lifecycle — a ``queue``
  span from enqueue→dispatch on the coordinator track and a ``replay``
  span from dispatch→done/requeue on the serving scope's track (a
  requeued bundle therefore shows *two* dispatch spans, the second on
  its rescue worker);
* instant events (``"ph": "i"``) for faults, scales, skips, crash
  loops;
* flow arrows (``"ph": "s"``/``"f"`` pairs) along dependency edges —
  from a parent bundle's ``done`` on its serving worker's track to the
  child's first subsequent ``dispatch`` on *its* worker's track, so a
  DAG run's fork-join structure is visible as arrows crossing worker
  tracks in Perfetto — and between the legs of one collective
  (``collective_leg`` events sharing a ``group``) across workers, the
  same mechanism linking the spans of a single logical collective;
* counter tracks (``"ph": "C"``) for SLO windows (p50/p99/p999 ms)
  when the caller passes the ``SLOEngine`` report.

Timestamps arrive monotonic (coordinator domain, post-``ClockSync``
rebase); the exporter shifts them so the earliest event is t=0.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Sequence

from repro.obs.recorder import Event

_PID = 1
#: stable track (tid) order: coordinator first, then workers/agents
#: in first-appearance order.
_COORD_TID = 0

#: instantaneous kinds and their trace category
_INSTANT_KINDS = {
    "enqueue": "queue", "requeue": "sched", "skip": "sched",
    "scale_up": "scale", "scale_down": "scale",
    "fault_opened": "fault", "fault_repaired": "fault",
    "speculate": "sched", "crash_loop": "fault",
    "heartbeat": "liveness",
    "dep_wait": "dag", "dep_release": "dag",
}


def _us(t: float, t0: float) -> float:
    return round((t - t0) * 1e6, 3)


def to_chrome_trace(events: Sequence[Event],
                    slo_windows: Optional[Sequence[dict]] = None,
                    meta: Optional[dict] = None) -> dict:
    """Render a merged event timeline as a Chrome trace-event object.

    ``slo_windows`` takes ``SLOEngine.report()["windows"]`` (dicts with
    ``t0``/``t1`` wall offsets and ``p50_ms``/``p99_ms``/``p999_ms``)
    and becomes counter tracks.  ``meta`` lands under ``"metadata"``.
    """
    events = sorted(events, key=lambda e: (e.t, e.scope, e.ordinal))
    t0 = events[0].t if events else 0.0
    tids: Dict[str, int] = {}

    def tid(scope: str) -> int:
        if scope not in tids:
            tids[scope] = _COORD_TID if scope == "coordinator" \
                else len(tids) + (0 if "coordinator" in tids else 1)
        return tids[scope]

    out: List[dict] = []
    # -- bundle lifecycle spans ---------------------------------------
    # enqueue -> dispatch (queue span, coordinator track), then per
    # dispatch: dispatch -> next (requeue|done|skip) for the same idx
    # (replay span on the dispatched scope's track).
    by_idx: Dict[int, List[Event]] = {}
    for e in events:
        idx = e.get("idx")
        if idx is not None and e.kind in (
                "enqueue", "dispatch", "requeue", "done", "skip"):
            by_idx.setdefault(idx, []).append(e)
    for idx, evs in by_idx.items():
        pending_enq: Optional[Event] = None
        open_disp: Optional[Event] = None
        for e in evs:
            if e.kind in ("enqueue", "requeue"):
                pending_enq = e
            elif e.kind == "dispatch":
                if pending_enq is not None:
                    out.append({
                        "name": f"queue b{idx}", "cat": "queue",
                        "ph": "X", "pid": _PID, "tid": tid("coordinator"),
                        "ts": _us(pending_enq.t, t0),
                        "dur": _us(e.t, pending_enq.t),
                        "args": {"idx": idx,
                                 "attempt": e.get("attempt", 1)}})
                    pending_enq = None
                open_disp = e
            # a requeue both closes the failed attempt's replay span
            # (above the enqueue/requeue branch re-opened queue wait)
            if e.kind in ("done", "requeue", "skip") and open_disp is not None:
                scope = open_disp.get("peer", open_disp.scope)
                out.append({
                    "name": f"replay b{idx}", "cat": "replay",
                    "ph": "X", "pid": _PID, "tid": tid(str(scope)),
                    "ts": _us(open_disp.t, t0),
                    "dur": _us(e.t, open_disp.t),
                    "args": {"idx": idx, "outcome": e.kind,
                             "attempt": open_disp.get("attempt", 1)}})
                open_disp = None
    # -- dependency flow arrows ---------------------------------------
    # one s/f pair per edge: start at the parent's done (on the track
    # that served it), finish at the child's first dispatch at-or-after
    # it (on the child's serving track) — Perfetto draws the arrow
    # between the two replay spans, making the DAG visible across
    # worker tracks.  bp="e" binds the finish to its enclosing slice.
    flow_id = 0
    done_ev: Dict[int, Event] = {}
    parents_of: Dict[int, Sequence[int]] = {}
    for e in events:
        idx = e.get("idx")
        if idx is None:
            continue
        if e.kind == "done" and idx not in done_ev:
            done_ev[idx] = e
        elif e.kind == "enqueue" and e.get("parents") and \
                idx not in parents_of:
            parents_of[idx] = e.get("parents")
    for idx in sorted(parents_of):
        for p in parents_of[idx]:
            dn = done_ev.get(p)
            if dn is None:
                continue        # parent skipped/unfinished: no arrow
            disp = next((e for e in by_idx.get(idx, ())
                         if e.kind == "dispatch" and e.t >= dn.t),
                        next((e for e in by_idx.get(idx, ())
                              if e.kind == "dispatch"), None))
            if disp is None:
                continue
            flow_id += 1
            out.append({
                "name": "dep", "cat": "dag", "ph": "s", "id": flow_id,
                "pid": _PID, "tid": tid(str(dn.get("peer", dn.scope))),
                "ts": _us(dn.t, t0), "args": {"parent": p, "child": idx}})
            out.append({
                "name": "dep", "cat": "dag", "ph": "f", "bp": "e",
                "id": flow_id, "pid": _PID,
                "tid": tid(str(disp.get("peer", disp.scope))),
                "ts": _us(max(disp.t, dn.t), t0),
                "args": {"parent": p, "child": idx}})
    # -- collective span links ----------------------------------------
    # legs of one logical collective share a ``group`` tag; chain them
    # in time order with the same flow mechanism so the legs a single
    # collective lands on different workers read as one linked operation
    groups: Dict[str, List[Event]] = {}
    for e in events:
        if e.kind == "collective_leg" and e.get("group") is not None:
            groups.setdefault(str(e.get("group")), []).append(e)
    for g in sorted(groups):
        legs = sorted(groups[g], key=lambda e: (e.t, e.scope, e.ordinal))
        if len(legs) < 2 or len({e.scope for e in legs}) < 2:
            continue            # one worker's legs already share a track
        for a, b in zip(legs, legs[1:]):
            flow_id += 1
            out.append({
                "name": "collective_link", "cat": "collective",
                "ph": "s", "id": flow_id, "pid": _PID,
                "tid": tid(a.scope), "ts": _us(a.t, t0),
                "args": {"group": g}})
            out.append({
                "name": "collective_link", "cat": "collective",
                "ph": "f", "bp": "e", "id": flow_id, "pid": _PID,
                "tid": tid(b.scope), "ts": _us(max(b.t, a.t), t0),
                "args": {"group": g}})
    # -- worker-side spans and instants -------------------------------
    for e in events:
        if e.kind == "segment_replay":
            dur = float(e.get("ttc_s", 0.0) or 0.0)
            out.append({
                "name": f"segments b{e.get('idx', '?')}", "cat": "worker",
                "ph": "X", "pid": _PID, "tid": tid(e.scope),
                "ts": _us(e.t - dur, t0), "dur": _us(e.t, e.t - dur),
                "args": dict(e.data)})
        elif e.kind == "collective_leg":
            out.append({
                "name": "collective", "cat": "worker", "ph": "i",
                "s": "t", "pid": _PID, "tid": tid(e.scope),
                "ts": _us(e.t, t0), "args": dict(e.data)})
        elif e.kind in _INSTANT_KINDS:
            out.append({
                "name": e.kind, "cat": _INSTANT_KINDS[e.kind], "ph": "i",
                "s": "g" if e.kind.startswith(("fault", "scale", "crash"))
                else "t",
                "pid": _PID, "tid": tid(e.scope), "ts": _us(e.t, t0),
                "args": dict(e.data)})
    # -- SLO counter tracks -------------------------------------------
    for w in slo_windows or []:
        ts = _us(float(w.get("t0", 0.0)), 0.0)
        args = {k: float(w[k]) for k in ("p50_ms", "p99_ms", "p999_ms")
                if w.get(k) is not None}
        if args:
            out.append({"name": "slo_latency_ms", "cat": "slo", "ph": "C",
                        "pid": _PID, "tid": 0, "ts": ts, "args": args})
    # -- track naming metadata ----------------------------------------
    for scope, t in sorted(tids.items(), key=lambda kv: kv[1]):
        out.append({"name": "thread_name", "ph": "M", "pid": _PID,
                    "tid": t, "args": {"name": scope}})
    out.append({"name": "process_name", "ph": "M", "pid": _PID, "tid": 0,
                "args": {"name": "repro fleet"}})
    trace = {"traceEvents": out, "displayTimeUnit": "ms"}
    if meta:
        trace["metadata"] = meta
    return trace


def slo_windows_ms(slo_report: dict) -> List[dict]:
    """``SLOEngine.report()`` → counter-track window dicts.

    The engine reports window quantiles in seconds; the counter track
    renders milliseconds (the unit the SLO itself is declared in)."""
    out = []
    for w in slo_report.get("windows", ()):
        out.append({"t0": float(w.get("t0", 0.0)),
                    "p50_ms": 1e3 * float(w.get("p50", 0.0)),
                    "p99_ms": 1e3 * float(w.get("p99", 0.0)),
                    "p999_ms": 1e3 * float(w.get("p999", 0.0))})
    return out


_REQUIRED = {"X": ("name", "ph", "pid", "tid", "ts", "dur"),
             "i": ("name", "ph", "pid", "tid", "ts"),
             "C": ("name", "ph", "pid", "ts", "args"),
             "M": ("name", "ph", "pid", "args"),
             # flow arrows: start / finish (finish also carries bp="e")
             "s": ("name", "cat", "id", "pid", "tid", "ts"),
             "f": ("name", "cat", "id", "pid", "tid", "ts")}


def validate_trace(trace: dict) -> None:
    """Strict structural check of a trace-event object (the schema
    Perfetto's JSON importer requires).  Raises ``ValueError`` on the
    first violation; returning means loadable."""
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace must be an object with 'traceEvents'")
    evs = trace["traceEvents"]
    if not isinstance(evs, list):
        raise ValueError("'traceEvents' must be a list")
    for i, e in enumerate(evs):
        if not isinstance(e, dict):
            raise ValueError(f"traceEvents[{i}] is not an object")
        ph = e.get("ph")
        if ph not in _REQUIRED:
            raise ValueError(f"traceEvents[{i}]: unsupported ph {ph!r}")
        for k in _REQUIRED[ph]:
            if k not in e:
                raise ValueError(f"traceEvents[{i}] (ph={ph}): missing {k!r}")
        for k in ("ts", "dur"):
            if k in e:
                v = e[k]
                if not isinstance(v, (int, float)):
                    raise ValueError(
                        f"traceEvents[{i}]: {k} must be a number, got "
                        f"{type(v).__name__}")
                if k == "dur" and v < 0:
                    raise ValueError(f"traceEvents[{i}]: negative dur {v}")
        if ph == "i" and e.get("s", "t") not in ("t", "p", "g"):
            raise ValueError(f"traceEvents[{i}]: bad instant scope "
                             f"{e.get('s')!r}")
    json.dumps(trace)   # must be serializable as-is


def write_trace(path: str, trace: dict) -> str:
    """Validate then write a trace file Perfetto can open directly."""
    validate_trace(trace)
    with open(path, "w") as f:
        json.dump(trace, f, separators=(",", ":"))
    return path
