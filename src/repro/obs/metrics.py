"""Minimal Prometheus text-format metrics registry.

No client library dependency: a registry of counters, gauges and
histograms whose ``render()`` emits text exposition format 0.0.4 —
what ``repro.service``'s ``/metrics`` endpoint serves and what CI's
strict ``parse_promtext`` checker re-reads.  Histograms are backed by
the service layer's ``LatencySketch`` (bounded log-bucket memory,
exact-associative merge) with a *coarse* growth factor: Prometheus
buckets are cumulative ``le`` lines in the scrape body, so ~20 buckets
(growth 2.0 over 1ms–1h) beats the sketch's quantile-grade ~450.

Metric names must match ``[a-zA-Z_:][a-zA-Z0-9_:]*``; labels are
passed as a dict and serialized sorted, so a (name, labels) pair is a
stable series identity.
"""
from __future__ import annotations

import math
import re
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple

if TYPE_CHECKING:                       # pragma: no cover
    from repro.service.slo import LatencySketch

# The sketch import is deferred to first use: repro.service's package
# init pulls in the fleet executor, and the executor imports repro.obs
# — a module-level import here would close that cycle.


def _make_sketch(lo: float, hi: float, growth: float):
    from repro.service.slo import LatencySketch
    return LatencySketch(lo, hi, growth)

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def _fmt(v: float) -> str:
    """Prometheus float formatting: integers bare, +Inf spelled."""
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if v != v:                      # NaN
        return "NaN"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _labelstr(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    parts = []
    for k in sorted(labels):
        v = str(labels[k]).replace("\\", r"\\").replace(
            '"', r"\"").replace("\n", r"\n")
        parts.append(f'{k}="{v}"')
    return "{" + ",".join(parts) + "}"


def _series_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class _Metric:
    def __init__(self, name: str, help_: str, kind: str) -> None:
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        self.name = name
        self.help = help_
        self.kind = kind
        self._series: Dict[Tuple, object] = {}

    def _get(self, labels: Dict[str, str], mk):
        for k in labels:
            if not _LABEL_RE.match(k):
                raise ValueError(f"invalid label name {k!r}")
        key = _series_key(labels)
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = mk()
        return s

    def _header(self) -> List[str]:
        lines = []
        if self.help:
            esc = self.help.replace("\\", r"\\").replace("\n", r"\n")
            lines.append(f"# HELP {self.name} {esc}")
        lines.append(f"# TYPE {self.name} {self.kind}")
        return lines


class Counter(_Metric):
    def __init__(self, name: str, help_: str = "") -> None:
        super().__init__(name, help_, "counter")

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        key = _series_key(labels)
        self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        return float(self._series.get(_series_key(labels), 0.0))

    def render(self) -> List[str]:
        lines = self._header()
        for key, v in sorted(self._series.items()):
            lines.append(f"{self.name}{_labelstr(dict(key))} {_fmt(v)}")
        return lines


class Gauge(_Metric):
    def __init__(self, name: str, help_: str = "") -> None:
        super().__init__(name, help_, "gauge")

    def set(self, value: float, **labels) -> None:
        self._series[_series_key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = _series_key(labels)
        self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        return float(self._series.get(_series_key(labels), 0.0))

    def render(self) -> List[str]:
        lines = self._header()
        for key, v in sorted(self._series.items()):
            lines.append(f"{self.name}{_labelstr(dict(key))} {_fmt(v)}")
        return lines


class Histogram(_Metric):
    """Cumulative-bucket histogram over a coarse ``LatencySketch``.

    The sketch's geometric buckets become Prometheus ``le`` bounds; the
    exposition is cumulative per the format, and ``le="+Inf"`` always
    equals ``_count``.  ``observe()`` takes seconds (the Prometheus
    base-unit convention)."""

    def __init__(self, name: str, help_: str = "", *,
                 lo: float = 1e-3, hi: float = 3600.0,
                 growth: float = 2.0) -> None:
        super().__init__(name, help_, "histogram")
        self._geometry = (lo, hi, growth)

    def observe(self, seconds: float, **labels) -> None:
        lo, hi, growth = self._geometry
        sk = self._get(labels, lambda: _make_sketch(lo, hi, growth))
        sk.add(max(0.0, seconds))

    def sketch(self, **labels) -> Optional["LatencySketch"]:
        return self._series.get(_series_key(labels))

    def absorb(self, sketch: "LatencySketch", **labels) -> None:
        """Merge a foreign sketch (e.g. a run's SLO sketch) into this
        series.  Matching geometry merges exactly; a finer foreign
        sketch is re-bucketed through each bucket's geometric midpoint
        (count-exact, value error bounded by this histogram's growth),
        so the quantile-grade SLO sketch folds into the ~20-bucket
        scrape body instead of bloating it."""
        lo, hi, growth = self._geometry
        cur = self._get(labels, lambda: _make_sketch(lo, hi, growth))
        if (sketch.lo, sketch.hi, sketch.growth) == (cur.lo, cur.hi,
                                                     cur.growth):
            self._series[_series_key(labels)] = cur.merge(sketch)
            return
        for i, c in enumerate(sketch.counts):
            if not c:
                continue
            if i == 0:                    # underflow: below sketch.lo
                v = sketch.min if sketch.min is not None else sketch.lo
            elif i == sketch.n_buckets - 1:
                v = sketch.max if sketch.max is not None else sketch.hi
            else:
                edge = sketch.lo * sketch.growth ** (i - 1)
                v = edge * math.sqrt(sketch.growth)
            cur.counts[cur._bucket(v)] += c
        cur.count += sketch.count
        cur.total += sketch.total         # exact, not re-derived
        mins = [m for m in (cur.min, sketch.min) if m is not None]
        maxs = [m for m in (cur.max, sketch.max) if m is not None]
        cur.min = min(mins) if mins else None
        cur.max = max(maxs) if maxs else None

    def render(self) -> List[str]:
        lines = self._header()
        for key, sk in sorted(self._series.items()):
            labels = dict(key)
            cum = 0
            # counts[0] is the underflow bucket (< lo): fold it into the
            # first finite bound; counts[-1] is overflow -> +Inf only.
            for i in range(sk.n_buckets - 1):
                cum += sk.counts[i]
                le = sk.lo * sk.growth ** i
                lines.append(
                    f"{self.name}_bucket"
                    f"{_labelstr(dict(labels, le=_fmt(le)))} {cum}")
            cum += sk.counts[-1]
            lines.append(f"{self.name}_bucket"
                         f"{_labelstr(dict(labels, le='+Inf'))} {cum}")
            lines.append(f"{self.name}_sum{_labelstr(labels)} "
                         f"{_fmt(sk.total)}")
            lines.append(f"{self.name}_count{_labelstr(labels)} "
                         f"{sk.count}")
        return lines


class MetricsRegistry:
    """Order-preserving collection of metrics with one scrape body."""

    def __init__(self) -> None:
        self._metrics: Dict[str, _Metric] = {}

    def counter(self, name: str, help_: str = "") -> Counter:
        return self._register(name, lambda: Counter(name, help_), Counter)

    def gauge(self, name: str, help_: str = "") -> Gauge:
        return self._register(name, lambda: Gauge(name, help_), Gauge)

    def histogram(self, name: str, help_: str = "", **kw) -> Histogram:
        return self._register(
            name, lambda: Histogram(name, help_, **kw), Histogram)

    def _register(self, name, mk, cls):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = mk()
        elif not isinstance(m, cls):
            raise ValueError(
                f"metric {name!r} already registered as {m.kind}")
        return m

    def render(self) -> str:
        """Text exposition format 0.0.4 (trailing newline included)."""
        lines: List[str] = []
        for m in self._metrics.values():
            lines.extend(m.render())
        return "\n".join(lines) + ("\n" if lines else "")

    def snapshot(self) -> Dict[str, Dict]:
        """JSON-able {name: {kind, series: {labelstr: value-ish}}}."""
        out: Dict[str, Dict] = {}
        for name, m in self._metrics.items():
            series = {}
            for key, v in m._series.items():
                lbl = _labelstr(dict(key)) or "{}"
                if hasattr(v, "quantile"):      # a histogram's sketch
                    series[lbl] = {"count": v.count, "sum": v.total,
                                   "p50": v.quantile(0.5),
                                   "p99": v.quantile(0.99)}
                else:
                    series[lbl] = v
            out[name] = {"kind": m.kind, "series": series}
        return out


_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?P<labels>\{[^{}]*\})?"
    r"\s+(?P<value>[^\s]+)"
    r"(?:\s+(?P<ts>-?\d+))?$")
_LABELPAIR_RE = re.compile(
    r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_promtext(text: str) -> Dict[str, Dict]:
    """Strict parser for text exposition format 0.0.4.

    Returns ``{metric_family: {"type": ..., "samples":
    {(sample_name, labelstr): float}}}`` and raises ``ValueError`` on
    any malformed line, unknown TYPE, sample before its TYPE line,
    non-monotonic histogram buckets, or ``le="+Inf"``/``_count``
    mismatch — the checks CI's obs-smoke job relies on."""
    families: Dict[str, Dict] = {}
    typed: Dict[str, str] = {}
    for ln, raw in enumerate(text.split("\n"), 1):
        line = raw.rstrip()
        if not line:
            continue
        if line.startswith("# HELP "):
            continue
        if line.startswith("# TYPE "):
            parts = line.split(None, 3)
            if len(parts) < 4:
                raise ValueError(f"line {ln}: malformed TYPE line")
            _, _, name, kind = parts
            if kind not in ("counter", "gauge", "histogram", "summary",
                            "untyped"):
                raise ValueError(f"line {ln}: unknown type {kind!r}")
            if name in typed:
                raise ValueError(f"line {ln}: duplicate TYPE for {name}")
            typed[name] = kind
            families[name] = {"type": kind, "samples": {}}
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {ln}: malformed sample {line!r}")
        sname = m.group("name")
        base = sname
        for suffix in ("_bucket", "_sum", "_count"):
            if sname.endswith(suffix) and sname[:-len(suffix)] in typed:
                base = sname[:-len(suffix)]
                break
        if base not in typed:
            raise ValueError(f"line {ln}: sample {sname!r} before its "
                             "TYPE line")
        val_s = m.group("value")
        if val_s == "+Inf":
            value = math.inf
        elif val_s == "-Inf":
            value = -math.inf
        else:
            try:
                value = float(val_s)
            except ValueError:
                raise ValueError(f"line {ln}: bad value {val_s!r}")
        labels = m.group("labels") or ""
        if labels:
            body = labels[1:-1]
            if body and not re.fullmatch(
                    r'\s*' + _LABELPAIR_RE.pattern +
                    r'(\s*,\s*' + _LABELPAIR_RE.pattern + r')*\s*,?\s*',
                    body):
                raise ValueError(f"line {ln}: malformed labels {labels!r}")
        families[base]["samples"][(sname, labels)] = value
    # histogram invariants: buckets cumulative, +Inf == _count
    for name, fam in families.items():
        if fam["type"] != "histogram":
            continue
        series: Dict[str, List[Tuple[float, float]]] = {}
        counts: Dict[str, float] = {}
        for (sname, labels), value in fam["samples"].items():
            if sname == name + "_bucket":
                pairs = dict(_LABELPAIR_RE.findall(labels))
                le = pairs.get("le")
                if le is None:
                    raise ValueError(f"{name}: bucket sample missing le")
                rest = ",".join(f"{k}={v}" for k, v in sorted(pairs.items())
                                if k != "le")
                bound = math.inf if le == "+Inf" else float(le)
                series.setdefault(rest, []).append((bound, value))
            elif sname == name + "_count":
                pairs = dict(_LABELPAIR_RE.findall(labels))
                rest = ",".join(f"{k}={v}"
                                for k, v in sorted(pairs.items()))
                counts[rest] = value
        for rest, buckets in series.items():
            buckets.sort()
            if not buckets or buckets[-1][0] != math.inf:
                raise ValueError(f"{name}: missing le=\"+Inf\" bucket")
            cum = [c for _, c in buckets]
            if any(b > a for a, b in zip(cum[1:], cum)):
                raise ValueError(f"{name}: non-cumulative buckets")
            if rest in counts and buckets[-1][1] != counts[rest]:
                raise ValueError(
                    f"{name}: le=\"+Inf\" ({buckets[-1][1]}) != _count "
                    f"({counts[rest]})")
    return families
