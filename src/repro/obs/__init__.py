"""Flight recorder + distributed trace/metrics layer.

The fleet's fidelity story — what dispatched where, which worker died,
when the breaker opened, how the SLO windows moved — used to live in
scattered post-hoc dicts (``FleetReport.recovery``/``scaling``,
``BundleTiming``, ``SLOEngine`` windows, chaos ``fault_events``).  This
package gives it one spine:

``clock``
    One clock domain for every stamp: a monotonic base with a wall
    anchor (``now()``/``wall()``), so queue/replay durations can never
    go negative under wall-clock steps, plus ``ClockSync`` — a per-peer
    offset estimator (handshake echo, min-RTT sample) that rebases
    worker/agent timestamps onto the coordinator timeline.

``recorder``
    ``FlightRecorder``: a bounded ring buffer of typed, picklable
    ``Event``s (dispatch, requeue, heartbeat, scale_up/down,
    fault_opened/repaired, segment_replay, collective_leg, ...) with
    sha256-scoped per-(scope, kind) ordinals, so a seeded chaos run
    emits a deterministic event *sequence* — timestamps vary, identity
    does not.  Coordinator, ``worker_loop`` and the host agent each run
    one; worker/agent buffers ship home piggybacked on result/stop
    frames as ``ObsFrame``s and merge onto the coordinator timeline.

``trace``
    Chrome trace-event JSON export (Perfetto-loadable): one track per
    worker/agent, spans from ``BundleTiming`` enqueue→dispatch→done,
    instant events for faults/scales, SLO windows as counter tracks.

``metrics``
    A small Prometheus text-format registry (counters / gauges /
    histograms backed by the service layer's ``LatencySketch``),
    scraped at ``repro.service``'s ``/metrics`` endpoint and
    snapshotted into ``FleetReport.obs``.

Nothing here imports jax: events are plain picklable dataclasses and
the exporters are pure-Python, so the recorder rides inside worker
processes and over the framed-TCP transport for free.
"""
from repro.obs.clock import ClockSync, anchor, now, wall
from repro.obs.metrics import MetricsRegistry, parse_promtext
from repro.obs.recorder import Event, FlightRecorder, ObsFrame
from repro.obs.trace import (slo_windows_ms, to_chrome_trace,
                             validate_trace, write_trace)

__all__ = [
    "ClockSync", "anchor", "now", "wall",
    "Event", "FlightRecorder", "ObsFrame",
    "slo_windows_ms", "to_chrome_trace", "validate_trace", "write_trace",
    "MetricsRegistry", "parse_promtext",
]
