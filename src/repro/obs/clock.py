"""One clock domain for every stamp the fleet takes.

Timestamps used to mix ``time.time()`` call sites across coordinator
and workers: a wall-clock step (NTP slew, suspend/resume, a test
freezing time) could make ``queue_s``/``replay_s`` negative.  This
module fixes the domain once:

* ``now()`` is the stamp everything records — ``time.monotonic()``, so
  durations between any two local stamps are non-negative by
  construction.
* ``wall(t)`` maps a monotonic stamp back to an absolute wall time via
  an anchor pair captured at import (``anchor()`` exposes it), for
  humans and trace viewers that want real dates.
* ``ClockSync`` estimates a remote peer's clock offset from handshake
  echoes so remote monotonic stamps rebase onto the local timeline.

Monotonic clocks are *per-process* (arbitrary epoch), so a raw remote
stamp is meaningless locally — every remote event must pass through a
``ClockSync`` before it lands on the coordinator timeline.
"""
from __future__ import annotations

import time
from typing import Optional, Tuple

# Captured once at import: the pair that lets any monotonic stamp in
# this process be rendered as a wall time.
_ANCHOR_MONO: float = time.monotonic()
_ANCHOR_WALL: float = time.time()


def now() -> float:
    """Monotonic stamp — the one clock every event/timing records."""
    return time.monotonic()


def wall(t_mono: Optional[float] = None) -> float:
    """Render a local monotonic stamp as absolute wall time."""
    if t_mono is None:
        t_mono = now()
    return _ANCHOR_WALL + (t_mono - _ANCHOR_MONO)


def anchor() -> Tuple[float, float]:
    """This process's (monotonic, wall) anchor pair."""
    return (_ANCHOR_MONO, _ANCHOR_WALL)


class ClockSync:
    """Per-peer clock-offset estimator (NTP-style, min-RTT sample).

    Each observation is one echo: the local side stamps ``t_sent``,
    the peer replies carrying its own clock reading ``t_remote``, and
    the local side stamps ``t_recv`` on arrival.  Assuming symmetric
    paths the peer read its clock at local time ``(t_sent+t_recv)/2``,
    so ``offset = t_remote - midpoint``.  The estimate with the
    smallest round-trip bounds the error tightest, so only the min-RTT
    sample is kept — piggybacking an echo on every result frame keeps
    refining it for free.

    Plain picklable attributes: syncs ride inside reports.
    """

    def __init__(self) -> None:
        self.offset: float = 0.0   # remote_clock - local_clock
        self.rtt: Optional[float] = None   # best (smallest) RTT seen
        self.samples: int = 0

    def observe(self, t_sent: float, t_remote: float, t_recv: float) -> None:
        """Fold in one echo (all stamps monotonic, each in its own
        process's domain)."""
        rtt = max(0.0, t_recv - t_sent)
        self.samples += 1
        if self.rtt is None or rtt < self.rtt:
            self.rtt = rtt
            self.offset = t_remote - (t_sent + t_recv) / 2.0

    @property
    def synced(self) -> bool:
        return self.samples > 0

    def to_local(self, t_remote: float) -> float:
        """Rebase a remote monotonic stamp onto the local clock."""
        return t_remote - self.offset

    def to_dict(self) -> dict:
        return {"offset": self.offset, "rtt": self.rtt,
                "samples": self.samples}
