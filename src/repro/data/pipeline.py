"""Deterministic synthetic token pipeline — sharded, seedable, resumable.

The stream is a counter-based PRF (threefry via jax.random, folded on the
global step), so (a) any batch is reproducible from (seed, step) alone —
exact-resume needs only the step number in the checkpoint manifest; (b) each
data shard draws a disjoint slice of the global batch, so multi-host loading
needs no coordination (every host computes its own slice), the property that
actually matters at 1000+ nodes.

The "language" generated is a tiny order-k Markov chain over the vocab, so
cross-entropy has learnable structure (loss decreases measurably within a
few hundred steps — used by tests and the train example).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: float = 0.8      # P(follow the Markov rule) vs uniform noise


class SyntheticLM:
    """tokens[t+1] = (a * tokens[t] + b) mod V with prob ``structure``."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        v = cfg.vocab_size
        self.a = 31 % v or 1
        self.b = 17 % v

    def batch_at(self, step: int, *, shard_index: int = 0,
                 num_shards: int = 1) -> Dict[str, jnp.ndarray]:
        cfg = self.cfg
        assert cfg.global_batch % num_shards == 0
        local_b = cfg.global_batch // num_shards
        key = jax.random.fold_in(jax.random.key(cfg.seed), step)
        key = jax.random.fold_in(key, shard_index)
        k1, k2, k3 = jax.random.split(key, 3)
        v = cfg.vocab_size
        first = jax.random.randint(k1, (local_b, 1), 0, v)
        noise = jax.random.randint(k2, (local_b, cfg.seq_len), 0, v)
        follow = jax.random.bernoulli(k3, self.cfg.structure,
                                      (local_b, cfg.seq_len))

        def step_fn(tok, inp):
            nz, fl = inp
            nxt = jnp.where(fl, (self.a * tok + self.b) % v, nz)
            return nxt, nxt

        _, seq = jax.lax.scan(
            step_fn, first[:, 0],
            (noise.T, follow.T))
        seq = jnp.concatenate([first, seq.T], axis=1)    # [b, S+1]
        return {"tokens": seq[:, :-1].astype(jnp.int32),
                "targets": seq[:, 1:].astype(jnp.int32)}

    def iterate(self, start_step: int = 0, *, shard_index: int = 0,
                num_shards: int = 1) -> Iterator[Dict[str, jnp.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step, shard_index=shard_index,
                                num_shards=num_shards)
            step += 1

    def state(self, step: int) -> Dict:
        """Everything needed for exact resume (goes into the ckpt manifest)."""
        return {"seed": self.cfg.seed, "step": step,
                "structure": self.cfg.structure}
