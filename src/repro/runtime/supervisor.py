"""Fault-tolerance harness: step supervision, straggler detection, restart.

Synapse closes the loop here: the predictor's TTC estimate for the profiled
step becomes the straggler deadline (deadline = predicted-or-EMA step time ×
tolerance).  The supervisor:

  * runs steps through a watchdog; a step exceeding its deadline is a
    straggler event (on a real pod: re-slice the mesh / evict the host;
    here: recorded + pluggable callback),
  * catches step failures (injected via ``FailurePlan`` in tests/benches,
    or real exceptions), restores the last committed checkpoint, rebuilds
    on the surviving mesh (elastic re-layout via CheckpointManager's
    unsharded manifest + new shardings), and replays,
  * checkpoints every ``ckpt_every`` steps, asynchronously.

This is the single-process skeleton of the multi-controller loop: at scale
each host runs this supervisor; coordination happens through the checkpoint
store and the (external) scheduler, which is exactly how jax multi-host
restarts work in practice.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.checkpoint.ckpt import CheckpointManager


class InjectedFailure(RuntimeError):
    pass


@dataclass
class FailurePlan:
    """Deterministic failure injection for tests/benchmarks."""
    fail_at_steps: Dict[int, str] = field(default_factory=dict)  # step->kind
    fired: set = field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise InjectedFailure(f"{self.fail_at_steps[step]}@{step}")


@dataclass
class SupervisorConfig:
    ckpt_every: int = 50
    keep: int = 3
    straggler_tolerance: float = 3.0     # × expected step time
    predicted_step_s: Optional[float] = None   # from Synapse predictor
    ema_alpha: float = 0.2
    max_restarts: int = 5


@dataclass
class SupervisorReport:
    steps_run: int = 0
    restarts: int = 0
    straggler_events: List[Dict] = field(default_factory=list)
    failures: List[str] = field(default_factory=list)
    restored_from: List[int] = field(default_factory=list)
    step_times: List[float] = field(default_factory=list)


class Supervisor:
    def __init__(self, ckpt: CheckpointManager, cfg: SupervisorConfig,
                 on_straggler: Optional[Callable[[Dict], None]] = None):
        self.ckpt = ckpt
        self.cfg = cfg
        self.on_straggler = on_straggler
        self.report = SupervisorReport()
        self._ema: Optional[float] = cfg.predicted_step_s

    # -- straggler detection ---------------------------------------------------

    def _deadline(self) -> Optional[float]:
        base = self._ema if self._ema is not None else \
            self.cfg.predicted_step_s
        return None if base is None else base * self.cfg.straggler_tolerance

    def _observe(self, dt: float, step: int):
        self.report.step_times.append(dt)
        dl = self._deadline()
        if dl is not None and dt > dl:
            ev = {"step": step, "duration_s": dt, "deadline_s": dl}
            self.report.straggler_events.append(ev)
            if self.on_straggler:
                self.on_straggler(ev)
        a = self.cfg.ema_alpha
        self._ema = dt if self._ema is None else (1 - a) * self._ema + a * dt

    # -- main loop ---------------------------------------------------------------

    def run(self, *, state, step_fn, batch_fn, num_steps: int,
            start_step: int = 0, failure_plan: Optional[FailurePlan] = None,
            restore_fn: Optional[Callable[[int], Any]] = None,
            extra_fn: Optional[Callable[[int], Dict]] = None):
        """Runs ``num_steps`` with checkpoint/restart.

        step_fn(state, batch) -> (state, metrics);  batch_fn(step) -> batch;
        restore_fn(step) -> state (defaults to CheckpointManager.restore).
        """
        step = start_step
        restarts = 0
        metrics = {}
        while step < start_step + num_steps:
            try:
                if failure_plan is not None:
                    failure_plan.check(step)
                t0 = time.perf_counter()
                state, metrics = step_fn(state, batch_fn(step))
                self._observe(time.perf_counter() - t0, step)
                self.report.steps_run += 1
                step += 1
                if step % self.cfg.ckpt_every == 0:
                    extra = {"step": step}
                    if extra_fn:
                        extra.update(extra_fn(step))
                    self.ckpt.save_async(step, state, extra)
            except Exception as e:  # noqa: BLE001 — restart path
                self.report.failures.append(f"{type(e).__name__}: {e}")
                restarts += 1
                self.report.restarts = restarts
                if restarts > self.cfg.max_restarts:
                    raise
                self.ckpt.wait()
                last = self.ckpt.latest_step()
                if last is None:
                    # no checkpoint yet: restart from the caller's initial state
                    step = start_step
                    continue
                if restore_fn is not None:
                    state = restore_fn(last)
                else:
                    state, _ = self.ckpt.restore(last)
                self.report.restored_from.append(last)
                step = last
        self.ckpt.wait()
        return state, metrics
