"""Sharded, atomic, async checkpoints with elastic restore.

Layout:  <root>/step_<N>/
             manifest.json        (tree structure, shapes, dtypes, hashes,
                                   data-pipeline state, rng, mesh at save)
             <leaf-path>.npy      (one file per tensor leaf)
             COMMIT               (written last; a checkpoint without COMMIT
                                   is garbage-collected on restore)

* Atomicity: write into step_<N>.tmp then os.replace to step_<N>, COMMIT last.
* Async: ``save_async`` snapshots to host memory (device_get) synchronously
  — cheap — and does file I/O on a worker thread so the train loop continues.
* Elastic restore: tensors are stored UNSHARDED (gathered logical arrays);
  ``restore`` re-shards onto whatever mesh/rules are alive, so a job can
  come back on a different pod count (DESIGN.md §5).  At 1000+-node scale
  the same manifest format supports per-shard files; the writer interface
  (``leaf_writer``) is pluggable for that.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro.obs import clock as obs_clock

MANIFEST = "manifest.json"
COMMIT = "COMMIT"


def _leaf_paths(tree, prefix=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _leaf_paths(tree[k], prefix + (str(k),))
    else:
        yield prefix, tree


def _set_path(root, path, value):
    cur = root
    for p in path[:-1]:
        cur = cur.setdefault(p, {})
    cur[path[-1]] = value


def tree_flatten_named(tree) -> Dict[str, Any]:
    return {"/".join(p): v for p, v in _leaf_paths(tree)}


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save -----------------------------------------------------------------

    def save(self, step: int, state, extra: Optional[Dict] = None):
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self._write(step, host, extra or {})

    def save_async(self, step: int, state, extra: Optional[Dict] = None):
        """Snapshot synchronously (device->host), write on a worker thread."""
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def work():
            try:
                self._write(step, host, extra or {})
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host_tree, extra: Dict):
        final = os.path.join(self.root, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = tree_flatten_named(host_tree)
        manifest = {"step": step, "created_at": obs_clock.wall(), "extra": extra,
                    "leaves": {}}
        for name, arr in leaves.items():
            fn = name.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"][name] = {
                "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
                "sha1": hashlib.sha1(arr.tobytes()).hexdigest()[:12],
            }
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        with open(os.path.join(final, COMMIT), "w") as f:
            f.write(str(step))
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def all_steps(self):
        out = []
        for d in sorted(os.listdir(self.root)):
            if d.startswith("step_") and not d.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.root, d, COMMIT)):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, *, shardings=None,
                verify: bool = True) -> Tuple[Any, Dict]:
        """Returns (state_tree, manifest_extra).

        ``shardings``: optional tree of jax.sharding.Sharding (matching the
        state structure) — leaves are placed onto the *current* mesh, which
        may differ from the mesh at save time (elastic restore).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {self.root}")
        d = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(d, MANIFEST)) as f:
            manifest = json.load(f)
        named_shardings = tree_flatten_named(shardings) if shardings is not \
            None else {}
        tree: Dict = {}
        for name, meta in manifest["leaves"].items():
            arr = np.load(os.path.join(d, meta["file"]))
            if verify:
                h = hashlib.sha1(arr.tobytes()).hexdigest()[:12]
                if h != meta["sha1"]:
                    raise IOError(f"checkpoint corruption in {name}: "
                                  f"{h} != {meta['sha1']}")
            sh = named_shardings.get(name)
            val = jax.device_put(arr, sh) if sh is not None else \
                jax.numpy.asarray(arr)
            _set_path(tree, tuple(name.split("/")), val)
        return tree, manifest.get("extra", {})
