"""Hardware descriptions used for emulation targeting and TTC prediction.

The paper predicts TTC on machines the user cannot access from a
resource-consumption profile + a hardware description; these specs are that
description for TPU pods (assignment constants: 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI) and for the local CPU host (calibrated at runtime by
``repro.core.calibrate`` so emulation atoms can hit a target consumption).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float            # per chip, bf16
    hbm_bw: float                # bytes/s per chip
    ici_bw: float                # bytes/s per link per chip
    ici_links: int = 4           # v5e: 4 links per chip (2D torus x2 dirs)
    mem_per_chip: float = 16e9
    chips: int = 1
    storage_bw: float = 0.0      # host/remote storage bytes/s (0 = ignore)
    # Derated "achievable" fractions (roofline ceilings are theoretical;
    # predictors may apply these):
    flops_derate: float = 1.0
    hbm_derate: float = 1.0
    ici_derate: float = 1.0

    def with_chips(self, n: int) -> "HardwareSpec":
        return replace(self, chips=n)


TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops=197e12,           # bf16 per chip (assignment constant)
    hbm_bw=819e9,                # bytes/s (assignment constant)
    ici_bw=50e9,                 # bytes/s per link (assignment constant)
    ici_links=4,
    mem_per_chip=16e9,
)

TPU_V5E_POD = TPU_V5E.with_chips(256)          # 16x16 single pod
TPU_V5E_2POD = TPU_V5E.with_chips(512)         # 2 pods (DCI between pods)

# The paper's experiment hosts, approximated for the portability study
# (bench_emulation_portability): profiles taken on one host are replayed
# against others and the dominant resource flips (paper Fig. 3).
HOST_I7_M620 = HardwareSpec(name="i7_m620", peak_flops=21e9, hbm_bw=17e9,
                            ici_bw=0.0, ici_links=0, mem_per_chip=8e9,
                            storage_bw=200e6)     # Intel 320 SSD
HOST_STAMPEDE_NODE = HardwareSpec(name="stampede_e5_2680", peak_flops=346e9,
                                  hbm_bw=51e9, ici_bw=0.0, ici_links=0,
                                  mem_per_chip=32e9, storage_bw=120e6)  # HDD
HOST_ARCHER_NODE = HardwareSpec(name="archer_e5_2697v2", peak_flops=518e9,
                                hbm_bw=59e9, ici_bw=0.0, ici_links=0,
                                mem_per_chip=64e9, storage_bw=150e6)

REGISTRY: Dict[str, HardwareSpec] = {
    s.name: s for s in [TPU_V5E, HOST_I7_M620, HOST_STAMPEDE_NODE,
                        HOST_ARCHER_NODE]
}


def get_spec(name: str, chips: int = 1) -> HardwareSpec:
    return REGISTRY[name].with_chips(chips)
