"""Synapse core: the paper's contribution as a composable JAX layer.

profile once (static watcher over compiled HLO, or runtime /proc watchers)
-> store (tagged, chunked, statistics over repeats)
-> emulate anywhere (resource atoms on any host/mesh)
-> predict TTC on hardware you don't have (roofline terms per sample).
"""
from repro.core.atoms import (CollectiveAtom, CollectiveQuant,  # noqa
                              CollectiveSpec, ComputeAtom, ComputeSpec,
                              MemoryAtom, MemorySpec, Plan, PlanCache,
                              StorageAtom, StorageSpec, collective_factor)
from repro.core.calibrate import HostCalibration, calibrate  # noqa
from repro.core.emulator import (EmulationReport, Emulator,  # noqa
                                 EmulatorSpec, FleetReport)
from repro.core.schedule import (BarrierStep, CompiledSchedule,  # noqa
                                 FusedSegment, SegmentRunner,
                                 compile_schedule, rehydrate_schedule)
from repro.core.hardware import (HOST_ARCHER_NODE, HOST_I7_M620,  # noqa
                                 HOST_STAMPEDE_NODE, TPU_V5E, TPU_V5E_2POD,
                                 TPU_V5E_POD, HardwareSpec, get_spec)
from repro.core.hlo_analysis import (HloCost, ModuleCost, analyze_hlo,  # noqa
                                     attribute_axes, sample_breakdown)
from repro.core.metrics import (ResourceVector, Sample,  # noqa
                                SynapseProfile)
from repro.core.predictor import (Prediction, RooflineTerms, compare,  # noqa
                                  from_dryrun_artifact, llm_request_resources,
                                  predict, predict_fleet, predict_resources,
                                  terms_for)
from repro.core.static_profiler import profile_compiled, profile_step  # noqa
from repro.core.store import ProfileStore  # noqa
from repro.core.watchers import (CPUWatcher, IOWatcher, MemWatcher,  # noqa
                                 RuntimeProfiler, WatcherBase, host_sysinfo)
