"""TTC / roofline prediction from a Synapse profile + a HardwareSpec.

The paper estimates time-to-completion on resources the user has no access
to.  On a TPU pod the three per-chip roofline terms per the assignment:

    compute_s    = FLOPs_per_chip    / peak_FLOP/s
    memory_s     = HBM_bytes_per_chip/ HBM_bw
    collective_s = ICI_wire_bytes_per_chip / link_bw

Per-sample combination is ``max`` (perfect overlap — XLA/TPU overlaps DMA,
MXU and ICI) or ``sum`` (fully serial); the truth lies in between, exactly
the paper's §IV-D concurrency discussion, so both bounds are reported.
The dominant term per sample is the paper's Fig.-3 "dominant resource",
which flips across hardware — ``compare()`` reproduces that flip.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.hardware import HardwareSpec
from repro.core.metrics import ResourceVector, SynapseProfile


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    storage_s: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s, "storage": self.storage_s}
        return max(terms, key=terms.get)

    @property
    def t_max(self) -> float:           # perfect-overlap bound
        return max(self.compute_s, self.memory_s, self.collective_s,
                   self.storage_s)

    @property
    def t_sum(self) -> float:           # serial bound
        return (self.compute_s + self.memory_s + self.collective_s +
                self.storage_s)

    def to_dict(self):
        return {"compute_s": self.compute_s, "memory_s": self.memory_s,
                "collective_s": self.collective_s, "storage_s": self.storage_s,
                "dominant": self.dominant, "t_max": self.t_max,
                "t_sum": self.t_sum}


@dataclass
class Prediction:
    hw: str
    terms: RooflineTerms                 # totals
    per_sample: List[RooflineTerms] = field(default_factory=list)
    dominant_histogram: Dict[str, int] = field(default_factory=dict)

    @property
    def ttc_max(self) -> float:
        """Overlap-per-sample, ordered across samples (emulation contract)."""
        return sum(t.t_max for t in self.per_sample) if self.per_sample \
            else self.terms.t_max

    @property
    def ttc_sum(self) -> float:
        return self.terms.t_sum

    def roofline_fraction(self) -> float:
        """Fraction of TTC spent at the dominant-term ceiling: 1.0 means the
        workload saturates its bottleneck resource perfectly."""
        d = self.terms.dominant
        val = getattr(self.terms, f"{d}_s")
        return val / self.ttc_max if self.ttc_max else 0.0


def terms_for(r: ResourceVector, hw: HardwareSpec,
              storage_bps: Optional[float] = None) -> RooflineTerms:
    peak = hw.peak_flops * hw.flops_derate
    bw = hw.hbm_bw * hw.hbm_derate
    ici = hw.ici_bw * hw.ici_derate
    if storage_bps is None and hw.storage_bw:
        storage_bps = hw.storage_bw
    return RooflineTerms(
        compute_s=r.flops / peak if peak else 0.0,
        memory_s=r.hbm_bytes / bw if bw else 0.0,
        collective_s=r.ici_total / ici if ici else 0.0,
        storage_s=((r.storage_read_bytes + r.storage_write_bytes) /
                   storage_bps) if storage_bps else 0.0)


def predict(profile: SynapseProfile, hw: HardwareSpec,
            storage_bps: Optional[float] = None) -> Prediction:
    per_sample = [terms_for(s.resources, hw, storage_bps)
                  for s in profile.samples]
    total = terms_for(profile.totals, hw, storage_bps)
    hist: Dict[str, int] = {}
    for t in per_sample:
        hist[t.dominant] = hist.get(t.dominant, 0) + 1
    return Prediction(hw=hw.name, terms=total, per_sample=per_sample,
                      dominant_histogram=hist)


def predict_resources(r: ResourceVector, hw: HardwareSpec,
                      storage_bps: Optional[float] = None) -> Prediction:
    t = terms_for(r, hw, storage_bps)
    return Prediction(hw=hw.name, terms=t, per_sample=[t],
                      dominant_histogram={t.dominant: 1})


def compare(profile: SynapseProfile, specs: List[HardwareSpec]) -> Dict:
    """Paper Fig. 3: same profile, different machines — the dominant resource
    per sample flips while total consumption is invariant."""
    out = {}
    for hw in specs:
        p = predict(profile, hw)
        out[hw.name] = {"ttc_max": p.ttc_max, "ttc_sum": p.ttc_sum,
                        "dominant_total": p.terms.dominant,
                        "dominant_histogram": p.dominant_histogram}
    return out


def llm_request_resources(prefill_tokens: int, decode_tokens: int,
                          n_params: float, bytes_per_param: float = 2.0,
                          kv_bytes_per_token: float = 0.0
                          ) -> Tuple[ResourceVector, ResourceVector]:
    """Map one serving request to (prefill, decode) resource vectors.

    The standard LLM roofline split: prefill does 2·P flops per prompt token
    against one weight read (compute-bound for long prompts); decode does
    2·P flops per generated token but re-reads every weight byte per token
    (memory-bound).  ``terms_for`` on the returned vectors reproduces that
    dominant-resource flip on any HardwareSpec.
    """
    weight_bytes = n_params * bytes_per_param
    prefill = ResourceVector(
        flops=2.0 * n_params * prefill_tokens,
        hbm_bytes=weight_bytes + kv_bytes_per_token * prefill_tokens)
    # decode token i reads a context of prefill + i tokens; summed over the
    # generation that's an average context of prefill + decode/2
    decode = ResourceVector(
        flops=2.0 * n_params * decode_tokens,
        hbm_bytes=decode_tokens * (weight_bytes + kv_bytes_per_token *
                                   (prefill_tokens + decode_tokens / 2.0)))
    return prefill, decode


def predict_fleet(profiles: List[SynapseProfile], hw: HardwareSpec,
                  storage_bps: Optional[float] = None) -> Dict:
    """TTC bounds for a fleet of profiles sharing one machine.

    ``serial_s`` replays them back-to-back (sum of ordered-overlap TTCs);
    ``concurrent_lower_s`` is the roofline on the *summed* resource totals —
    no schedule can beat it on this hardware, so the pair brackets any real
    fleet execution.
    """
    preds = [predict(p, hw, storage_bps) for p in profiles]
    total = ResourceVector()
    for p in profiles:
        total = total.add(p.totals)
    agg = terms_for(total, hw, storage_bps)
    return {"hw": hw.name, "n_profiles": len(profiles),
            "serial_s": sum(p.ttc_max for p in preds),
            "concurrent_lower_s": agg.t_max,
            "dominant_total": agg.dominant,
            "per_profile": [{"ttc_max": p.ttc_max, "ttc_sum": p.ttc_sum,
                             "dominant": p.terms.dominant} for p in preds]}


def from_dryrun_artifact(rec: Dict) -> ResourceVector:
    """Per-chip ResourceVector from a dry-run JSON artifact (walker section).

    Memory term uses dot_bytes (MXU-streaming bytes) as primary — see
    DESIGN.md §2 caveats; hbm_bytes (all fusion boundaries) is the
    pessimistic bound kept in the artifact.
    """
    w = rec["walker"]
    return ResourceVector(
        flops=w["flops"],
        hbm_bytes=w.get("dot_bytes", w["hbm_bytes"]),
        ici_bytes=dict(w.get("collective_bytes", {})))
