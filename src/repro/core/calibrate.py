"""Host calibration microbenchmarks.

The paper's compute atom is an assembly matmul loop whose throughput defines
"the maximum efficiency Synapse can emulate"; equivalently we measure what
this host actually sustains (matmul FLOP/s, stream bytes/s, file I/O bytes/s)
once, cache it on disk, and atoms use it to convert a resource amount into
loop iterations.  On a TPU the same role is played by the Pallas atoms +
HardwareSpec peaks.
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from dataclasses import asdict, dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

CACHE_PATH = os.path.join(tempfile.gettempdir(), "synapse_host_calib.json")


@dataclass(frozen=True)
class HostCalibration:
    flops_per_s: float
    stream_bytes_per_s: float
    storage_write_bps: float
    storage_read_bps: float

    def to_json(self):
        return json.dumps(asdict(self))


def _time(fn, min_s=0.2, warmup=1):
    for _ in range(warmup):
        fn()
    n, t0 = 0, time.perf_counter()
    while True:
        fn()
        n += 1
        dt = time.perf_counter() - t0
        if dt > min_s:
            return dt / n


def measure_flops(m: int = 512) -> float:
    a = jnp.ones((m, m), jnp.float32)
    f = jax.jit(lambda x: x @ x)
    f(a).block_until_ready()
    dt = _time(lambda: f(a).block_until_ready())
    return 2.0 * m ** 3 / dt


def measure_stream(nbytes: int = 1 << 26) -> float:
    n = nbytes // 4
    a = jnp.ones((n,), jnp.float32)
    f = jax.jit(lambda x: x * 1.0000001)
    f(a).block_until_ready()
    dt = _time(lambda: f(a).block_until_ready())
    return 2.0 * nbytes / dt              # read + write


def measure_storage(nbytes: int = 1 << 24, block: int = 1 << 20):
    buf = os.urandom(block)
    path = os.path.join(tempfile.gettempdir(), "synapse_cal.bin")

    def wr():
        with open(path, "wb") as f:
            for _ in range(nbytes // block):
                f.write(buf)
            f.flush()
            os.fsync(f.fileno())

    dt_w = _time(wr, min_s=0.3, warmup=0)

    def rd():
        with open(path, "rb") as f:
            while f.read(block):
                pass

    dt_r = _time(rd, min_s=0.1)
    os.unlink(path)
    return nbytes / dt_w, nbytes / dt_r


def calibrate(force: bool = False) -> HostCalibration:
    if not force and os.path.exists(CACHE_PATH):
        try:
            with open(CACHE_PATH) as f:
                return HostCalibration(**json.load(f))
        except Exception:  # noqa: BLE001
            pass
    flops = measure_flops()
    stream = measure_stream()
    wr, rd = measure_storage()
    cal = HostCalibration(flops_per_s=flops, stream_bytes_per_s=stream,
                          storage_write_bps=wr, storage_read_bps=rd)
    with open(CACHE_PATH, "w") as f:
        f.write(cal.to_json())
    return cal
