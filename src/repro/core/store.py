"""Tagged profile store (the paper's MongoDB replaced by chunked JSON files).

Keys are (command, tags) exactly as in the paper §IV: repeated profiles of the
same key accumulate for statistical analysis (mean/σ per metric).  Documents
are chunked at ~14 MB to stay under the paper's infamous 16 MB MongoDB
document limit (§IV-E.9) — kept here as a compatibility contract so profiles
can round-trip into a real MongoDB later.
"""
from __future__ import annotations

import hashlib
import json
import math
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core.metrics import SynapseProfile

DOC_LIMIT_BYTES = 14 * 1024 * 1024


def _key_hash(command: str, tags: Dict[str, str]) -> str:
    tag = ",".join(f"{k}={v}" for k, v in sorted(tags.items()))
    return hashlib.sha1(f"{command}|{tag}".encode()).hexdigest()[:16]


@dataclass
class ProfileStats:
    n: int
    mean: Dict[str, float]
    std: Dict[str, float]


class ProfileStore:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._index_path = os.path.join(root, "index.json")

    # -- index ---------------------------------------------------------------

    def _load_index(self) -> Dict:
        if os.path.exists(self._index_path):
            with open(self._index_path) as f:
                return json.load(f)
        return {}

    def _save_index(self, idx: Dict):
        tmp = self._index_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(idx, f, indent=1)
        os.replace(tmp, self._index_path)

    # -- API -----------------------------------------------------------------

    def add(self, profile: SynapseProfile) -> str:
        h = _key_hash(profile.command, profile.tags)
        idx = self._load_index()
        ent = idx.setdefault(h, {"command": profile.command,
                                 "tags": profile.tags, "runs": []})
        run_id = f"{h}-{len(ent['runs']):04d}"
        doc = profile.to_json()
        n_chunks = max(1, math.ceil(len(doc) / DOC_LIMIT_BYTES))
        paths = []
        for c in range(n_chunks):
            p = os.path.join(self.root, f"{run_id}.{c}.json")
            with open(p, "w") as f:
                f.write(doc[c * DOC_LIMIT_BYTES:(c + 1) * DOC_LIMIT_BYTES])
            paths.append(os.path.basename(p))
        ent["runs"].append({"id": run_id, "chunks": paths,
                            "created_at": profile.created_at})
        self._save_index(idx)
        return run_id

    def query(self, command: str, tags: Optional[Dict[str, str]] = None
              ) -> List[SynapseProfile]:
        h = _key_hash(command, tags or {})
        idx = self._load_index()
        ent = idx.get(h)
        if not ent:
            return []
        return self._load_runs(ent)

    def _load_run(self, run: Dict) -> SynapseProfile:
        doc = ""
        for chunk in run["chunks"]:
            with open(os.path.join(self.root, chunk)) as f:
                doc += f.read()
        return SynapseProfile.from_json(doc)

    def _load_runs(self, ent: Dict) -> List[SynapseProfile]:
        return [self._load_run(run) for run in ent["runs"]]

    def latest(self, command: str, tags=None) -> Optional[SynapseProfile]:
        profiles = self.query(command, tags)
        return profiles[-1] if profiles else None

    def find(self, tags: Dict[str, str], command: Optional[str] = None
             ) -> List[SynapseProfile]:
        """All profiles whose tags are a superset of ``tags``.

        Cross-key lookup the exact-(command, tags) ``query`` can't do: e.g.
        every stored run with ``{"scenario": "serving_traffic"}`` regardless
        of the parameter tags it was generated with.  Eager form of
        ``stream`` — prefer ``stream`` when the result set may be large.
        """
        return list(self.stream(tags, command))

    def stream(self, tags: Optional[Dict[str, str]] = None,
               command: Optional[str] = None):
        """Lazily yield stored profiles one at a time, oldest run first
        within each key (superset tag match, like ``find``; no filter
        streams the whole store).

        This is the fleet-feeding path: ``run_fleet(profiles=
        store.stream(tags))`` (or ``repro.scenarios fleet --from-store``)
        replays a store's worth of captured profiles without
        materializing every document up front — the first step toward
        replay-the-production-day fleets that outsize memory.  The index
        is snapshotted once at the first ``next()``; runs added
        afterwards appear in the next ``stream`` call.
        """
        idx = self._load_index()
        for _, ent in sorted(idx.items()):
            if command is not None and ent["command"] != command:
                continue
            if not all(ent["tags"].get(k) == v
                       for k, v in (tags or {}).items()):
                continue
            for run in ent["runs"]:
                yield self._load_run(run)

    def keys(self) -> List[Dict]:
        idx = self._load_index()
        return [{"command": v["command"], "tags": v["tags"],
                 "n_runs": len(v["runs"])} for v in idx.values()]

    # -- statistics over repeated runs (paper: mean/σ per metric) ------------

    def stats(self, command: str, tags=None) -> Optional[ProfileStats]:
        profiles = self.query(command, tags)
        if not profiles:
            return None
        rows = []
        for p in profiles:
            t = p.totals
            row = {"flops": t.flops, "hbm_bytes": t.hbm_bytes,
                   "ici_bytes": t.ici_total,
                   "storage_read_bytes": t.storage_read_bytes,
                   "storage_write_bytes": t.storage_write_bytes,
                   "peak_mem_bytes": t.peak_mem_bytes,
                   "n_samples": float(len(p.samples))}
            if p.wall_time_s is not None:
                row["wall_time_s"] = p.wall_time_s
            rows.append(row)
        keys = set().union(*[set(r) for r in rows])
        mean, std = {}, {}
        for k in keys:
            vals = [r[k] for r in rows if k in r]
            mu = sum(vals) / len(vals)
            mean[k] = mu
            std[k] = (sum((v - mu) ** 2 for v in vals) / len(vals)) ** 0.5
        return ProfileStats(n=len(rows), mean=mean, std=std)
