"""Black-box compile-time profiler ("static watcher family").

``profile_compiled`` turns a compiled XLA executable into a SynapseProfile:
the trip-count-aware HLO walker supplies per-chip resource consumption, and
the entry computation's execution order supplies the *sample sequence* (one
sample per straight-line segment, trip-count samples per scan) — the static
analog of the paper's time-sampled profiling.  Granularities:

  * "step"  — a single sample for the whole step (paper: 1 sample/run —
              coarse, loses ordering, like the low-rate end of Fig. 6)
  * "scan"  — samples follow program structure (default; the layer loop
              becomes L ordered samples exactly like the paper's per-100ms
              samples follow execution phases)

``profile_step`` is the one-call convenience: jit → lower → compile →
profile, returning (profile, compiled).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax

from repro.core import hlo_analysis
from repro.core.metrics import ResourceVector, Sample, SynapseProfile
from repro.obs import clock as obs_clock


def _rv(cost: hlo_analysis.HloCost, use_dot_bytes: bool = True) -> ResourceVector:
    return ResourceVector(
        flops=cost.flops,
        hbm_bytes=cost.dot_bytes if use_dot_bytes else cost.hbm_bytes,
        ici_bytes=cost.collective_bytes())


def profile_compiled(compiled, *, command: str, tags: Optional[Dict] = None,
                     granularity: str = "scan", mesh=None,
                     use_dot_bytes: bool = True) -> SynapseProfile:
    text = compiled.as_text()
    sysinfo: Dict[str, Any] = {"backend": "xla-static"}
    if mesh is not None:
        sysinfo["mesh"] = {n: int(s) for n, s in
                           zip(mesh.axis_names, mesh.devices.shape)}
        sysinfo["n_devices"] = int(mesh.devices.size)

    samples = []
    if granularity == "step":
        cost = hlo_analysis.analyze_hlo(text)
        samples.append(Sample(index=0, resources=_rv(cost, use_dot_bytes),
                              label="step"))
    else:
        idx = 0
        for label, cost, count in hlo_analysis.sample_breakdown(text):
            rv = _rv(cost, use_dot_bytes)
            for _ in range(count):
                samples.append(Sample(index=idx, resources=rv, label=label))
                idx += 1

    prof = SynapseProfile(command=command, tags=tags or {}, samples=samples,
                          sysinfo=sysinfo)
    ma = compiled.memory_analysis()
    if ma is not None:
        prof.meta["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
        }
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):         # older jaxlib: list per device
        ca = ca[0] if ca else None
    if ca:
        prof.meta["xla_cost_flops"] = float(ca.get("flops", -1.0))
    return prof


def profile_step(fn, *args, command: str, tags=None, mesh=None,
                 granularity: str = "scan", donate_argnums=(),
                 ) -> Tuple[SynapseProfile, Any]:
    """Lower + compile ``fn(*args)`` (abstract or concrete) and profile it."""
    t0 = obs_clock.now()
    lowered = jax.jit(fn, donate_argnums=donate_argnums).lower(*args)
    compiled = lowered.compile()
    prof = profile_compiled(compiled, command=command, tags=tags,
                            granularity=granularity, mesh=mesh)
    prof.meta["lower_compile_s"] = obs_clock.now() - t0
    return prof, compiled
