"""Fused schedule compiler: whole-profile emulation in O(segments) dispatches.

The per-sample replay loop pays one Python→XLA round trip per atom per
sample with a blocking sync inside every thunk — the dispatch-overhead trap
that dominates emulation cost at fine granularity (paper §IV-B, Fig. 2:
fidelity wants *finer* samples, the old loop made them *more* expensive).
This module lowers a collapsed run list into a small number of fused device
programs instead:

  * contiguous **storage-free, collective-free** runs are packed into a
    ``FusedSegment``: an int32 iteration table with one row per run
    (compute-burn iters, memory-stream iters), quantized exactly like the
    atoms quantize (``ComputeAtom.iters_for`` / ``MemoryAtom.iters_for``,
    applied to the count-scaled run amounts).  A segment executes as ONE
    jitted ``lax.scan`` over its table — the scan carries the compute tile
    and memory block through every row in order, so the cross-sample
    ordering contract holds *inside* the program and an M-sample profile
    costs O(storage-segment boundaries) dispatches instead of O(M × atoms).
  * runs with a storage leg (host I/O worker interleave) or an executable
    collective (bound to its mesh via shard_map) stay ``BarrierStep``s and
    replay through the legacy per-sample path, splitting the segments
    around them — exactly where the ordering contract demands a real
    barrier.

Tables are padded to power-of-two lengths with (0, 0) no-op rows, so one
``SegmentRunner`` compiles at most O(log max-segment-length) programs per
(tile, block) configuration and every segment of a profile — and of every
profile in a fleet sharing the runner — reuses them.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.atoms import (ComputeAtom, MemoryAtom, compute_burn_body,
                              compute_operand, memory_operand,
                              memory_stream_body)
from repro.core.metrics import ResourceVector


@dataclass
class FusedSegment:
    """Contiguous storage/collective-free runs packed into one dispatch.

    ``table`` row i holds (compute_iters, memory_iters) for the i-th run;
    ``rows`` holds the matching consumed ``ResourceVector`` per run, already
    count-scaled, in profile order (the emulator adds them in sequence so
    consumed totals are bit-identical to the per-sample path).
    """
    table: np.ndarray                     # (n_rows, 2) int32
    rows: List[ResourceVector] = field(default_factory=list)

    @property
    def n_rows(self) -> int:
        return int(self.table.shape[0])

    @property
    def compute_iters(self) -> int:
        return int(self.table[:, 0].sum())

    @property
    def memory_iters(self) -> int:
        return int(self.table[:, 1].sum())


@dataclass
class BarrierStep:
    """A collapsed run the fused path must replay per-sample: it carries a
    storage leg (I/O worker interleave) or an executable collective."""
    resources: ResourceVector
    count: int = 1


ScheduleStep = Union[FusedSegment, BarrierStep]


@dataclass
class CompiledSchedule:
    """A profile lowered to fused segments split by barrier steps."""
    steps: List[ScheduleStep] = field(default_factory=list)

    def detach(self) -> Dict:
        """Lower this schedule to a plain-data payload (ints, floats, dicts,
        one int32 ndarray per segment) with no references to atoms, meshes
        or jitted programs — safe to pickle across a process boundary and
        cheap to ship to fleet workers.  ``rehydrate_schedule`` is the exact
        inverse: resource vectors round-trip bit-identically (float fields
        are copied, never re-derived), which is what lets a process-fleet
        replay report consumed totals equal to an in-process replay."""
        steps = []
        for s in self.steps:
            if isinstance(s, FusedSegment):
                steps.append({"kind": "segment",
                              "table": np.asarray(s.table, dtype=np.int32),
                              "rows": [r.to_dict() for r in s.rows]})
            else:
                steps.append({"kind": "barrier",
                              "resources": s.resources.to_dict(),
                              "count": int(s.count)})
        return {"version": 1, "steps": steps}

    @property
    def segments(self) -> List[FusedSegment]:
        return [s for s in self.steps if isinstance(s, FusedSegment)]

    @property
    def barriers(self) -> List[BarrierStep]:
        return [s for s in self.steps if isinstance(s, BarrierStep)]

    @property
    def n_rows(self) -> int:
        return sum(s.n_rows for s in self.segments)

    def describe(self) -> Dict[str, int]:
        return {"n_steps": len(self.steps),
                "n_segments": len(self.segments),
                "n_barriers": len(self.barriers),
                "n_rows": self.n_rows,
                "compute_iters": sum(s.compute_iters for s in self.segments),
                "memory_iters": sum(s.memory_iters for s in self.segments)}


def rehydrate_schedule(payload: Dict) -> CompiledSchedule:
    """Rebuild a ``CompiledSchedule`` from a ``CompiledSchedule.detach()``
    payload.  Tables and resource vectors come back bit-identical."""
    if not isinstance(payload, dict) or payload.get("version") != 1:
        raise ValueError(f"unsupported schedule payload: "
                         f"{payload.get('version') if isinstance(payload, dict) else payload!r}")
    steps: List[ScheduleStep] = []
    for s in payload["steps"]:
        kind = s.get("kind")
        if kind == "segment":
            table = np.asarray(s["table"], dtype=np.int32).reshape(-1, 2)
            steps.append(FusedSegment(
                table=table,
                rows=[ResourceVector.from_dict(r) for r in s["rows"]]))
        elif kind == "barrier":
            steps.append(BarrierStep(
                resources=ResourceVector.from_dict(s["resources"]),
                count=int(s["count"])))
        else:
            raise ValueError(f"unknown schedule step kind {kind!r}")
    return CompiledSchedule(steps=steps)


def compile_schedule(runs, *, compute: ComputeAtom, memory: MemoryAtom,
                     collective=None, flops_scale: float = 1.0,
                     mem_scale: float = 1.0, speed: float = 1.0,
                     keep_collectives: Optional[bool] = None
                     ) -> CompiledSchedule:
    """Lower collapsed (ResourceVector, count) runs into a CompiledSchedule.

    Quantization mirrors the per-sample path exactly: a run is scaled by its
    count first (the legacy fuse semantics for identical consecutive
    samples), then each amount is scaled and quantized by the owning atom's
    ``iters_for``.  Amounts below one iteration lower to a no-op row, same
    as the atoms' zero-iteration plans.

    ``keep_collectives`` overrides whether runs with wire bytes lower to
    ``BarrierStep``s (executable collective legs) or fold into fused
    segments (accounting only).  The default follows ``collective``: with
    no collective atom there is nothing to execute them on.  A schedule
    compiled for a process fleet passes ``True`` — the *workers* own
    meshes even when this process does not.
    """
    if keep_collectives is None:
        keep_collectives = collective is not None
    steps: List[ScheduleStep] = []
    table_rows: List = []
    vecs: List[ResourceVector] = []

    def flush():
        if table_rows:
            steps.append(FusedSegment(
                table=np.asarray(table_rows, dtype=np.int32).reshape(-1, 2),
                rows=list(vecs)))
            table_rows.clear()
            vecs.clear()

    for r, count in runs:
        has_storage = (r.storage_read_bytes > 0 or r.storage_write_bytes > 0)
        has_collective = keep_collectives and r.ici_total > 0
        if has_storage or has_collective:
            flush()
            steps.append(BarrierStep(resources=r, count=count))
            continue
        rr = r.scale(count) if count > 1 else r
        ci = compute.iters_for(rr.flops * flops_scale / speed) \
            if rr.flops > 0 else 0
        mi = memory.iters_for(rr.hbm_bytes * mem_scale / speed) \
            if rr.hbm_bytes > 0 else 0
        table_rows.append((ci, mi))
        vecs.append(rr)
    flush()
    return CompiledSchedule(steps=steps)


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


class SegmentRunner:
    """Executes FusedSegment iteration tables, one device dispatch each.

    Programs are specialized to the carries a segment actually needs —
    a compute-only segment must not drag the (potentially tens-of-MB)
    memory block through its scan, matching the per-sample path where a
    zero-iteration amount plans to a noop.  One program per (padded
    length, needs-compute, needs-memory); safe to share across fleet
    worker threads: the program dict and operand init are guarded, jitted
    callables are thread-safe, and operands are read-only.
    """

    def __init__(self, tile: int = 256, block_bytes: int = 1 << 24):
        self.tile = tile
        self.block_bytes = block_bytes
        self._fns: Dict[tuple, object] = {}
        self._lock = threading.Lock()
        self._xc = None
        self._xm = None

    def _operands(self):
        if self._xm is None:
            with self._lock:
                if self._xm is None:
                    # atom-shared constructors: a fused iteration must cost
                    # exactly what an atom iteration costs.  _xm is the
                    # publish flag — it is assigned last, so a racing reader
                    # never sees one operand without the other.
                    self._xc = compute_operand(self.tile)
                    self._xm = memory_operand(self.block_bytes)
        return self._xc, self._xm

    def _fn(self, padded_len: int, with_c: bool, with_m: bool):
        key = (padded_len, with_c, with_m)
        fn = self._fns.get(key)
        if fn is None:
            with self._lock:
                fn = self._fns.get(key)
                if fn is None:
                    def segment(carry, table):
                        def body(carry, row):
                            if with_c and with_m:
                                c, m = carry
                                c = jax.lax.fori_loop(0, row[0],
                                                      compute_burn_body, c)
                                m = jax.lax.fori_loop(0, row[1],
                                                      memory_stream_body, m)
                                return (c, m), jnp.int32(0)
                            if with_c:
                                return jax.lax.fori_loop(
                                    0, row[0], compute_burn_body,
                                    carry), jnp.int32(0)
                            return jax.lax.fori_loop(
                                0, row[1], memory_stream_body,
                                carry), jnp.int32(0)
                        out, _ = jax.lax.scan(body, carry, table)
                        return out
                    fn = jax.jit(segment)
                    self._fns[key] = fn
        return fn

    @property
    def n_programs(self) -> int:
        return len(self._fns)

    def launch(self, segment: FusedSegment):
        """Dispatch the whole segment asynchronously; returns the unsynced
        carry (sync with ``jax.block_until_ready``), or ``None`` when every
        row quantized to zero iterations (nothing to dispatch)."""
        with_c = segment.compute_iters > 0
        with_m = segment.memory_iters > 0
        if not (with_c or with_m):
            return None
        padded = _next_pow2(segment.n_rows)
        table = np.zeros((padded, 2), dtype=np.int32)
        table[:segment.n_rows] = segment.table
        xc, xm = self._operands()
        carry = (xc, xm) if (with_c and with_m) else (xc if with_c else xm)
        return self._fn(padded, with_c, with_m)(carry, table)

    def run(self, segment: FusedSegment) -> bool:
        """Dispatch and sync: the segment's samples are done on return.
        Returns False when the segment was all-noop (no dispatch issued)."""
        token = self.launch(segment)
        if token is None:
            return False
        jax.block_until_ready(token)
        return True
