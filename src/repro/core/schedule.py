"""Fused schedule compiler: whole-profile emulation in O(segments) dispatches.

The per-sample replay loop pays one Python→XLA round trip per atom per
sample with a blocking sync inside every thunk — the dispatch-overhead trap
that dominates emulation cost at fine granularity (paper §IV-B, Fig. 2:
fidelity wants *finer* samples, the old loop made them *more* expensive).
This module lowers a collapsed run list into a small number of fused device
programs instead:

  * contiguous **storage-free** runs are packed into a ``FusedSegment``:
    an int32 iteration table with one row per run (compute-burn iters,
    memory-stream iters, collective iters), quantized exactly like the
    atoms quantize (``ComputeAtom.iters_for`` / ``MemoryAtom.iters_for`` /
    ``CollectiveQuant.iters_for``, applied to the count-scaled run
    amounts).  A segment executes as ONE jitted ``lax.scan`` over its
    table — the scan carries the compute tile, the memory block, and (for
    **mesh-bound** segments, i.e. those with wire-byte rows) a fixed
    shard_map-collective block through every row in order, so the
    cross-sample ordering contract holds *inside* the program and an
    M-sample profile costs O(storage-segment boundaries) dispatches
    instead of O(M × atoms) — communication-heavy profiles included.
  * runs with a storage leg (host I/O worker interleave) stay
    ``BarrierStep``s and replay through the legacy per-sample path,
    splitting the segments around them — exactly where the ordering
    contract demands a real barrier.  ``keep_collectives=True`` lowers
    wire-byte runs to barrier steps too: the fallback for meshless parents
    that cannot quantize a collective (no mesh, no ``CollectiveQuant``).

Wire-byte quantization is a picklable ``CollectiveQuant`` (axis size +
kind + block), so a parent with *no mesh at all* compiles tables
bit-identical to the ones its mesh-owning fleet workers would compile —
mesh-bound segments ship through ``detach()``/``rehydrate_schedule`` like
any other, and the quant rides along for the worker to validate against
its own mesh.

Tables are padded to power-of-two lengths with all-zero no-op rows, so one
``SegmentRunner`` compiles at most O(log max-segment-length) programs per
(tile, block, mesh) configuration and every segment of a profile — and of
every profile in a fleet sharing the runner — reuses them.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.atoms import (CollectiveQuant, ComputeAtom, MemoryAtom,
                              compute_burn_body, compute_operand,
                              memory_operand, memory_stream_body)
from repro.core.metrics import ResourceVector


@dataclass
class FusedSegment:
    """Contiguous storage-free runs packed into one dispatch.

    ``table`` row i holds (compute_iters, memory_iters, collective_iters)
    for the i-th run; ``rows`` holds the matching consumed
    ``ResourceVector`` per run, already count-scaled, in profile order
    (the emulator adds them in sequence so consumed totals are
    bit-identical to the per-sample path).  A segment with any nonzero
    collective iters is **mesh-bound**: executing it needs a
    ``SegmentRunner`` whose emulator owns a mesh.  Legacy two-column
    tables (pre-collective payloads, hand-built warmup tables) normalize
    to three columns with a zero wire column.
    """
    table: np.ndarray                     # (n_rows, 3) int32
    rows: List[ResourceVector] = field(default_factory=list)

    def __post_init__(self):
        t = np.asarray(self.table, dtype=np.int32)
        if t.ndim != 2 or t.shape[1] not in (2, 3):
            raise ValueError(f"segment table must be 2-D with 2 or 3 "
                             f"columns, got shape {t.shape}")
        if t.shape[1] == 2:
            t = np.concatenate(
                [t, np.zeros((t.shape[0], 1), dtype=np.int32)], axis=1)
        self.table = t

    @property
    def n_rows(self) -> int:
        return int(self.table.shape[0])

    @property
    def compute_iters(self) -> int:
        return int(self.table[:, 0].sum())

    @property
    def memory_iters(self) -> int:
        return int(self.table[:, 1].sum())

    @property
    def collective_iters(self) -> int:
        return int(self.table[:, 2].sum())

    @property
    def mesh_bound(self) -> bool:
        return self.collective_iters > 0


@dataclass
class BarrierStep:
    """A collapsed run the fused path must replay per-sample: it carries a
    storage leg (I/O worker interleave) or an executable collective."""
    resources: ResourceVector
    count: int = 1


ScheduleStep = Union[FusedSegment, BarrierStep]


@dataclass
class CompiledSchedule:
    """A profile lowered to fused segments split by barrier steps.

    ``collective_quant`` is the wire-byte quantization the tables were
    built with — present whenever wire runs were fused into mesh-bound
    segments, so a replaying emulator can validate that its own mesh
    matches the one the schedule was quantized for.
    """
    steps: List[ScheduleStep] = field(default_factory=list)
    collective_quant: Optional[CollectiveQuant] = None

    def detach(self) -> Dict:
        """Lower this schedule to a plain-data payload (ints, floats, dicts,
        one int32 ndarray per segment) with no references to atoms, meshes
        or jitted programs — safe to pickle across a process boundary and
        cheap to ship to fleet workers.  ``rehydrate_schedule`` is the exact
        inverse: resource vectors round-trip bit-identically (float fields
        are copied, never re-derived), which is what lets a process-fleet
        replay report consumed totals equal to an in-process replay."""
        steps = []
        for s in self.steps:
            if isinstance(s, FusedSegment):
                steps.append({"kind": "segment",
                              "table": np.asarray(s.table, dtype=np.int32),
                              "rows": [r.to_dict() for r in s.rows]})
            else:
                steps.append({"kind": "barrier",
                              "resources": s.resources.to_dict(),
                              "count": int(s.count)})
        payload = {"version": 2, "steps": steps}
        if self.collective_quant is not None:
            payload["collective"] = self.collective_quant.to_dict()
        return payload

    @property
    def segments(self) -> List[FusedSegment]:
        return [s for s in self.steps if isinstance(s, FusedSegment)]

    @property
    def barriers(self) -> List[BarrierStep]:
        return [s for s in self.steps if isinstance(s, BarrierStep)]

    @property
    def n_rows(self) -> int:
        return sum(s.n_rows for s in self.segments)

    @property
    def mesh_bound(self) -> bool:
        """True when any segment carries executable collective rows."""
        return any(s.mesh_bound for s in self.segments)

    def describe(self) -> Dict[str, int]:
        return {"n_steps": len(self.steps),
                "n_segments": len(self.segments),
                "n_barriers": len(self.barriers),
                "n_rows": self.n_rows,
                "compute_iters": sum(s.compute_iters for s in self.segments),
                "memory_iters": sum(s.memory_iters for s in self.segments),
                "collective_iters": sum(s.collective_iters
                                        for s in self.segments)}


def rehydrate_schedule(payload: Dict) -> CompiledSchedule:
    """Rebuild a ``CompiledSchedule`` from a ``CompiledSchedule.detach()``
    payload.  Tables and resource vectors come back bit-identical.
    Version-1 payloads (two-column tables, pre-fused-collectives) load
    with a zero wire column."""
    if not isinstance(payload, dict) or payload.get("version") not in (1, 2):
        raise ValueError(f"unsupported schedule payload: "
                         f"{payload.get('version') if isinstance(payload, dict) else payload!r}")
    steps: List[ScheduleStep] = []
    for s in payload["steps"]:
        kind = s.get("kind")
        if kind == "segment":
            steps.append(FusedSegment(
                table=np.asarray(s["table"], dtype=np.int32),
                rows=[ResourceVector.from_dict(r) for r in s["rows"]]))
        elif kind == "barrier":
            steps.append(BarrierStep(
                resources=ResourceVector.from_dict(s["resources"]),
                count=int(s["count"])))
        else:
            raise ValueError(f"unknown schedule step kind {kind!r}")
    quant = (CollectiveQuant.from_dict(payload["collective"])
             if payload.get("collective") is not None else None)
    return CompiledSchedule(steps=steps, collective_quant=quant)


def compile_schedule(runs, *, compute: ComputeAtom, memory: MemoryAtom,
                     collective=None, flops_scale: float = 1.0,
                     mem_scale: float = 1.0, speed: float = 1.0,
                     keep_collectives: Optional[bool] = None,
                     collective_quant: Optional[CollectiveQuant] = None
                     ) -> CompiledSchedule:
    """Lower collapsed (ResourceVector, count) runs into a CompiledSchedule.

    Quantization mirrors the per-sample path exactly: a run is scaled by its
    count first (the legacy fuse semantics for identical consecutive
    samples), then each amount is scaled and quantized by the owning atom's
    ``iters_for``.  Amounts below one iteration lower to a no-op row, same
    as the atoms' zero-iteration plans.

    Runs with wire bytes lower three ways:

      * **fused** (default when a quantization is available): the run
        becomes a segment row whose third column holds collective
        iterations — the whole run executes inside the segment's one
        dispatch, on the replaying emulator's mesh.  The quantization
        comes from ``collective_quant`` if given, else from ``collective``
        when it is mesh-bound; it is recorded on the schedule so a
        replayer on a *different* mesh fails loudly instead of emulating
        skewed wire amounts.
      * **barrier** (``keep_collectives=True``): the run stays a
        ``BarrierStep`` replayed per-sample through ``CollectiveAtom`` —
        the fallback for meshless parents that cannot quantize.
      * **folded** (``keep_collectives=False``, or no quantization
        source): wire bytes are accounted in the row's resources but
        execute nothing — there is no mesh to move them on.
    """
    quant = collective_quant
    if quant is None and collective is not None \
            and getattr(collective, "mesh", None) is not None:
        quant = collective.quant()
    fuse_wire = keep_collectives is None and quant is not None
    steps: List[ScheduleStep] = []
    table_rows: List = []
    vecs: List[ResourceVector] = []

    def flush():
        if table_rows:
            steps.append(FusedSegment(
                table=np.asarray(table_rows, dtype=np.int32).reshape(-1, 3),
                rows=list(vecs)))
            table_rows.clear()
            vecs.clear()

    for r, count in runs:
        has_storage = (r.storage_read_bytes > 0 or r.storage_write_bytes > 0)
        has_collective = bool(keep_collectives) and r.ici_total > 0
        if has_storage or has_collective:
            flush()
            steps.append(BarrierStep(resources=r, count=count))
            continue
        rr = r.scale(count) if count > 1 else r
        ci = compute.iters_for(rr.flops * flops_scale / speed) \
            if rr.flops > 0 else 0
        mi = memory.iters_for(rr.hbm_bytes * mem_scale / speed) \
            if rr.hbm_bytes > 0 else 0
        wi = quant.iters_for(rr.ici_total / speed) \
            if fuse_wire and rr.ici_total > 0 else 0
        table_rows.append((ci, mi, wi))
        vecs.append(rr)
    flush()
    return CompiledSchedule(steps=steps,
                            collective_quant=quant if fuse_wire else None)


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


class SegmentRunner:
    """Executes FusedSegment iteration tables, one device dispatch each.

    Programs are specialized to the carries a segment actually needs —
    a compute-only segment must not drag the (potentially tens-of-MB)
    memory block (or a shard_map'd collective) through its scan, matching
    the per-sample path where a zero-iteration amount plans to a noop.
    One program per (padded length, needs-compute, needs-memory,
    needs-collective); safe to share across fleet worker threads: the
    program dict and operand init are guarded, jitted callables are
    thread-safe, and operands are read-only.

    ``collective`` (a mesh-bound ``CollectiveAtom``) supplies the
    shard_map'd per-iteration wire step and its fixed-block operand;
    without one, launching a mesh-bound segment raises — a meshless
    replayer must recompile with ``keep_collectives=True`` instead of
    silently dropping wire work.
    """

    def __init__(self, tile: int = 256, block_bytes: int = 1 << 24,
                 collective=None):
        self.tile = tile
        self.block_bytes = block_bytes
        self.collective = collective
        self._fns: Dict[tuple, object] = {}
        self._lock = threading.Lock()
        self._xc = None
        self._xm = None
        self._xcoll = None

    def _operands(self):
        if self._xm is None:
            with self._lock:
                if self._xm is None:
                    # atom-shared constructors: a fused iteration must cost
                    # exactly what an atom iteration costs.  _xm is the
                    # publish flag — it is assigned last, so a racing reader
                    # never sees one operand without the other.
                    self._xc = compute_operand(self.tile)
                    self._xm = memory_operand(self.block_bytes)
        return self._xc, self._xm

    def set_collective(self, atom) -> None:
        """Swap the collective atom, dropping every mesh-bound program and
        the collective operand — they close over the OLD atom's shard_map
        mesh, and the program key carries no mesh identity."""
        with self._lock:
            self.collective = atom
            self._xcoll = None
            self._fns = {k: v for k, v in self._fns.items() if not k[3]}

    def _coll_operand(self):
        if self._xcoll is None:
            with self._lock:
                if self._xcoll is None:
                    self._xcoll = self.collective.loop_operand()
        return self._xcoll

    def _fn(self, padded_len: int, with_c: bool, with_m: bool,
            with_coll: bool):
        key = (padded_len, with_c, with_m, with_coll)
        fn = self._fns.get(key)
        if fn is None:
            with self._lock:
                fn = self._fns.get(key)
                if fn is None:
                    # one fori_loop block per carried operand, in carry
                    # order; each burns exactly what the owning atom's
                    # iteration burns
                    blocks = []
                    if with_c:
                        blocks.append(lambda v, row: jax.lax.fori_loop(
                            0, row[0], compute_burn_body, v))
                    if with_m:
                        blocks.append(lambda v, row: jax.lax.fori_loop(
                            0, row[1], memory_stream_body, v))
                    if with_coll:
                        coll_step = self.collective.loop_body()
                        blocks.append(lambda v, row: jax.lax.fori_loop(
                            0, row[2], lambda _, x: coll_step(x), v))

                    def segment(carry, table):
                        def body(c, row):
                            return tuple(b(v, row) for b, v
                                         in zip(blocks, c)), jnp.int32(0)
                        out, _ = jax.lax.scan(body, carry, table)
                        return out
                    fn = jax.jit(segment)
                    self._fns[key] = fn
        return fn

    @property
    def n_programs(self) -> int:
        return len(self._fns)

    def launch(self, segment: FusedSegment):
        """Dispatch the whole segment asynchronously; returns the unsynced
        carry (sync with ``jax.block_until_ready``), or ``None`` when every
        row quantized to zero iterations (nothing to dispatch)."""
        with_c = segment.compute_iters > 0
        with_m = segment.memory_iters > 0
        with_coll = segment.collective_iters > 0
        if not (with_c or with_m or with_coll):
            return None
        if with_coll and (self.collective is None
                          or self.collective.mesh is None):
            raise RuntimeError(
                "mesh-bound segment (collective iterations in its table) "
                "but this runner has no mesh-bound CollectiveAtom; "
                "recompile the schedule with keep_collectives=True to "
                "replay wire legs per-sample, or give the emulator a mesh")
        padded = _next_pow2(segment.n_rows)
        table = np.zeros((padded, 3), dtype=np.int32)
        table[:segment.n_rows] = segment.table
        carry = []
        if with_c or with_m:       # wire-only segments skip the (big)
            xc, xm = self._operands()  # compute/memory operands entirely
            if with_c:
                carry.append(xc)
            if with_m:
                carry.append(xm)
        if with_coll:
            carry.append(self._coll_operand())
        return self._fn(padded, with_c, with_m, with_coll)(tuple(carry),
                                                           table)

    def run(self, segment: FusedSegment) -> bool:
        """Dispatch and sync: the segment's samples are done on return.
        Returns False when the segment was all-noop (no dispatch issued)."""
        token = self.launch(segment)
        if token is None:
            return False
        jax.block_until_ready(token)
        return True
