"""Synapse datamodel: resource vectors, samples, profiles.

Mirrors the paper's Table I, adapted to the TPU resource types of DESIGN.md §2:
compute (FLOPs on the MXU), memory (HBM bytes), collective (ICI wire bytes per
collective kind), storage (host I/O bytes), plus peak/live memory.  A profile
is an *ordered* sequence of samples (the paper's partial-order contract:
sample n may only depend on samples < n), plus totals, system info and tags.
"""
from __future__ import annotations

import dataclasses
import json
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")


@dataclass
class ResourceVector:
    """Per-chip resource consumption (the unit Synapse atoms replay)."""
    flops: float = 0.0
    hbm_bytes: float = 0.0
    ici_bytes: Dict[str, float] = field(default_factory=dict)  # by kind
    storage_read_bytes: float = 0.0
    storage_write_bytes: float = 0.0
    host_mem_bytes: float = 0.0          # runtime watcher: resident memory
    peak_mem_bytes: float = 0.0

    @property
    def ici_total(self) -> float:
        return float(sum(self.ici_bytes.values()))

    def add(self, other: "ResourceVector") -> "ResourceVector":
        ici = dict(self.ici_bytes)
        for k, v in other.ici_bytes.items():
            ici[k] = ici.get(k, 0.0) + v
        return ResourceVector(
            flops=self.flops + other.flops,
            hbm_bytes=self.hbm_bytes + other.hbm_bytes,
            ici_bytes=ici,
            storage_read_bytes=self.storage_read_bytes + other.storage_read_bytes,
            storage_write_bytes=self.storage_write_bytes + other.storage_write_bytes,
            host_mem_bytes=max(self.host_mem_bytes, other.host_mem_bytes),
            peak_mem_bytes=max(self.peak_mem_bytes, other.peak_mem_bytes),
        )

    def scale(self, f: float) -> "ResourceVector":
        return ResourceVector(
            flops=self.flops * f, hbm_bytes=self.hbm_bytes * f,
            ici_bytes={k: v * f for k, v in self.ici_bytes.items()},
            storage_read_bytes=self.storage_read_bytes * f,
            storage_write_bytes=self.storage_write_bytes * f,
            host_mem_bytes=self.host_mem_bytes,
            peak_mem_bytes=self.peak_mem_bytes)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d) -> "ResourceVector":
        return ResourceVector(**d)


@dataclass
class Sample:
    """One profiling sample: a ResourceVector plus ordering/duration info.

    ``label`` identifies the program phase for phase-sampled (static) profiles
    or the wall-clock bucket index for time-sampled (runtime) profiles.
    """
    index: int
    resources: ResourceVector
    duration_s: Optional[float] = None   # known only for runtime samples
    label: str = ""

    def to_dict(self):
        return {"index": self.index, "resources": self.resources.to_dict(),
                "duration_s": self.duration_s, "label": self.label}

    @staticmethod
    def from_dict(d):
        return Sample(index=d["index"],
                      resources=ResourceVector.from_dict(d["resources"]),
                      duration_s=d.get("duration_s"), label=d.get("label", ""))


@dataclass
class SynapseProfile:
    """command + tags identify the workload (paper §IV: profile store keys)."""
    command: str
    tags: Dict[str, str] = field(default_factory=dict)
    samples: List[Sample] = field(default_factory=list)
    sysinfo: Dict[str, Any] = field(default_factory=dict)
    created_at: float = field(default_factory=time.time)
    meta: Dict[str, Any] = field(default_factory=dict)   # free-form extras

    @property
    def totals(self) -> ResourceVector:
        t = ResourceVector()
        for s in self.samples:
            t = t.add(s.resources)
        return t

    @property
    def wall_time_s(self) -> Optional[float]:
        ds = [s.duration_s for s in self.samples]
        if any(d is None for d in ds) or not ds:
            return None
        return float(sum(ds))

    def to_json(self) -> str:
        return json.dumps({
            "command": self.command, "tags": self.tags,
            "samples": [s.to_dict() for s in self.samples],
            "sysinfo": self.sysinfo, "created_at": self.created_at,
            "meta": self.meta,
        })

    @staticmethod
    def from_json(s: str) -> "SynapseProfile":
        d = json.loads(s)
        return SynapseProfile(
            command=d["command"], tags=d.get("tags", {}),
            samples=[Sample.from_dict(x) for x in d.get("samples", [])],
            sysinfo=d.get("sysinfo", {}), created_at=d.get("created_at", 0.0),
            meta=d.get("meta", {}))

    def key(self) -> str:
        tag = ",".join(f"{k}={v}" for k, v in sorted(self.tags.items()))
        return f"{self.command}|{tag}"
