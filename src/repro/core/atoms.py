"""Emulation atoms: small self-contained consumers of one resource type.

Paper §IV-B, adapted per DESIGN.md §2:

  * ComputeAtom    — MXU/FPU matmul burn loop.  ``efficiency`` < 1
                     throttles it exactly like the paper's loop-rate knob
                     (emulate an app running below peak).  Backends: jnp
                     (XLA loop) or the Pallas kernel in
                     ``repro.kernels.compute_atom`` (TPU target).
  * MemoryAtom     — streams a target byte count through the memory system
                     (Pallas: HBM→VMEM block copies; jnp: scaled copy loop).
  * CollectiveAtom — moves an exact wire-byte count over a mesh axis with
                     psum/all_gather/ppermute under shard_map (the paper's
                     "planned" network atom, first-class here).
  * StorageAtom    — block-wise file write/read (libc read/write, unchanged
                     from the paper; block size is the tunable the paper
                     discusses in §IV-E.3).

Atoms expose ``plan(amount) -> Plan`` so the emulator can pre-compile, and
``seconds(amount, hw)`` — the model cost used by the TTC predictor.  A
``Plan`` separates *launch* (enqueue device work, returns an unsynced jax
value; host plans do the work and return ``None``) from *sync*, so the
emulator can dispatch every atom of a sample asynchronously and block once
at the sample barrier; calling the plan is the legacy blocking contract.
"""
from __future__ import annotations

import math
import os
import tempfile
import threading
import time
from dataclasses import dataclass
from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.calibrate import HostCalibration
from repro.core.hardware import HardwareSpec


class Plan:
    """One planned resource consumption.

    ``launch()`` enqueues the work: device plans return the unsynced jax
    value (dispatch only — caller syncs at the sample barrier), host plans
    (storage) do the work inline and return ``None``.  Calling the plan is
    the blocking contract older callers rely on: launch, sync, and return
    the amount the plan actually emulates (quantized, so cache sharers
    agree on what was consumed).
    """

    __slots__ = ("launch", "amount")

    def __init__(self, launch: Callable[[], object], amount: float):
        self.launch = launch
        self.amount = float(amount)

    def __call__(self) -> float:
        token = self.launch()
        if token is not None:
            jax.block_until_ready(token)
        return self.amount

    @staticmethod
    def noop() -> "Plan":
        return Plan(lambda: None, 0.0)


class PlanCache:
    """Shared, keyed memo of planned atom thunks (fleet emulation).

    Keys are the atom's full plan signature — (kind, backend/config knobs,
    quantized amount) — so identical (atom, amount) plans across a fleet of
    concurrently-replayed profiles are built, and their XLA programs traced,
    exactly once.  Builds hold a per-key guard, not the cache-wide lock:
    concurrent fleet workers building *different* plans trace concurrently,
    while a second worker asking for a key mid-build waits for the first
    builder instead of constructing a duplicate.  The returned plans are
    safe to execute concurrently (jitted callables with read-only operands).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._plans: Dict[Tuple, Plan] = {}
        self._building: Dict[Tuple, threading.Event] = {}
        self.plans_built = 0
        self.hits = 0

    def get_or_build(self, key: Tuple,
                     builder: Callable[[], Plan]) -> Plan:
        while True:
            with self._lock:
                plan = self._plans.get(key)
                if plan is not None:
                    self.hits += 1
                    return plan
                done = self._building.get(key)
                if done is None:
                    done = threading.Event()
                    self._building[key] = done
                    owner = True
                else:
                    owner = False
            if not owner:
                # someone else is building this key: wait, then re-check
                # (a failed build wakes us with no plan — we take over)
                done.wait()
                continue
            try:
                plan = builder()
            except BaseException:
                with self._lock:
                    self._building.pop(key, None)
                done.set()
                raise
            with self._lock:
                self._plans[key] = plan
                self.plans_built += 1
                self._building.pop(key, None)
            done.set()
            return plan

    def __len__(self) -> int:
        return len(self._plans)

    def stats(self) -> Dict[str, int]:
        return {"plans_built": self.plans_built, "hits": self.hits,
                "size": len(self._plans)}


# ---------------------------------------------------------------------------
# Picklable atom configs: the knob surface of an atom, detached from its
# live state (calibration, jitted programs, meshes, scratch buffers).  A
# spec crosses a process boundary and ``build()``s a fresh atom on the far
# side — fleet workers receive these instead of atoms.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ComputeSpec:
    tile: int = 256
    efficiency: float = 1.0
    backend: str = "jnp"

    def build(self, calib=None) -> "ComputeAtom":
        return ComputeAtom(calib, tile=self.tile, efficiency=self.efficiency,
                           backend=self.backend)


@dataclass(frozen=True)
class MemorySpec:
    block_bytes: int = 1 << 24
    backend: str = "jnp"

    def build(self, calib=None) -> "MemoryAtom":
        return MemoryAtom(calib, block_bytes=self.block_bytes,
                          backend=self.backend)


@dataclass(frozen=True)
class StorageSpec:
    block_bytes: int = 1 << 20
    # no directory: scratch files belong to the host the atom runs on

    def build(self, calib=None) -> "StorageAtom":
        return StorageAtom(calib, block_bytes=self.block_bytes)


#: per-shard float32 elements one fused collective iteration moves (the
#: collective analogue of ComputeAtom.tile / MemoryAtom.block_bytes — the
#: schedule compiler quantizes wire bytes into repeats of this block)
COLL_BLOCK_ELEMS = 1 << 15


def collective_factor(kind: str, n: int) -> float:
    """Ring-model wire bytes per chip per shard byte for a collective over
    an ``n``-way axis (all-reduce moves ``2*(n-1)/n`` of the shard, …)."""
    return {"all-reduce": 2.0 * (n - 1) / n,
            "all-gather": (n - 1) / n,
            "collective-permute": 1.0}.get(kind, 2.0 * (n - 1) / n)


@dataclass(frozen=True)
class CollectiveQuant:
    """Picklable wire-byte quantization for fused collective segments.

    Derivable from a live ``CollectiveAtom`` (``atom.quant()``) *or* from a
    (``CollectiveSpec``, mesh-spec) pair on a host that owns no mesh at all
    (``CollectiveSpec.quant_for``) — which is what lets a meshless parent
    compile schedule tables bit-identical to the ones its mesh-owning fleet
    workers would compile.  One iteration is one shard_map'd collective call
    over a fixed ``block_elems``-per-shard float32 block, so the emulated
    wire amount is ``iters * wire_bytes_per_iter`` — quantized exactly like
    compute flops and memory bytes are.
    """
    n: int                               # collective axis size
    kind: str = "all-reduce"
    block_elems: int = COLL_BLOCK_ELEMS

    @property
    def factor(self) -> float:
        return collective_factor(self.kind, self.n)

    @property
    def wire_bytes_per_iter(self) -> float:
        return self.factor * 4.0 * self.block_elems

    def iters_for(self, wire_bytes: float) -> int:
        per_iter = self.wire_bytes_per_iter
        if per_iter <= 0.0:        # n == 1: there is no wire to move
            return 0
        return max(int(round(wire_bytes / per_iter)), 0)

    def emulated_bytes(self, iters: int) -> float:
        return iters * self.wire_bytes_per_iter

    def to_dict(self) -> Dict:
        return {"n": self.n, "kind": self.kind,
                "block_elems": self.block_elems}

    @staticmethod
    def from_dict(d) -> "CollectiveQuant":
        return CollectiveQuant(n=int(d["n"]), kind=str(d["kind"]),
                               block_elems=int(d["block_elems"]))


@dataclass(frozen=True)
class CollectiveSpec:
    axis: Optional[str] = None           # None: the mesh's last axis
    kind: str = "all-reduce"

    def build(self, mesh) -> "CollectiveAtom":
        return CollectiveAtom(mesh, axis=self.axis, kind=self.kind)

    def quant_for(self, mesh_spec) -> CollectiveQuant:
        """Quantization for the mesh a *worker* will build from
        ``mesh_spec`` (anything with ``shape``/``axes``, e.g.
        ``repro.fleet.MeshSpec``) — no live mesh required."""
        axes = tuple(mesh_spec.axes)
        axis = self.axis if self.axis is not None else axes[-1]
        if axis not in axes:
            raise ValueError(f"collective axis {axis!r} not in mesh axes "
                             f"{axes}")
        return CollectiveQuant(n=int(mesh_spec.shape[axes.index(axis)]),
                               kind=self.kind)


class Atom:
    resource = "abstract"
    cache: Optional[PlanCache] = None      # set by fleet-mode emulators

    def plan(self, amount: float) -> Plan:
        """Returns a Plan that consumes ``amount`` (quantized) when called."""
        raise NotImplementedError

    def seconds(self, amount: float, hw: HardwareSpec) -> float:
        raise NotImplementedError

    def _cached(self, key: Tuple, builder: Callable[[], Plan]) -> Plan:
        if self.cache is None:
            return builder()
        return self.cache.get_or_build(key, builder)


def compute_burn_body(_, c):
    """One compute-atom iteration: tile matmul kept bounded by tanh.
    Shared with the fused schedule compiler so both paths burn
    identically per iteration."""
    return jnp.tanh(c @ c) * 0.5 + 0.5


def compute_operand(tile: int):
    """The burn loop's carry; shared with the schedule compiler so a fused
    iteration costs exactly what an atom iteration costs."""
    return jnp.eye(tile, dtype=jnp.float32) * 0.5


def memory_stream_body(_, c):
    """One memory-atom iteration: a full read+write pass over the block."""
    return c * 1.0000001


def memory_operand(block_bytes: int):
    """The stream loop's carry (one block); shared with the schedule
    compiler for the same reason as ``compute_operand``."""
    return jnp.ones((block_bytes // 4,), jnp.float32)


# ---------------------------------------------------------------------------
# Compute
# ---------------------------------------------------------------------------

class ComputeAtom(Atom):
    resource = "flops"

    def __init__(self, calib: Optional[HostCalibration] = None,
                 tile: int = 256, efficiency: float = 1.0,
                 backend: str = "jnp"):
        """``efficiency``: the paper's loop-rate knob — the profiled
        application's measured efficiency (achieved/peak); the atom burns
        flops/efficiency raw loop flops so wall time matches an application
        running that far below the atom's own (near-peak) rate."""
        self.calib = calib
        self.tile = tile
        self.efficiency = max(efficiency, 1e-6)
        self.backend = backend
        self._fn: Optional[Callable] = None
        self._fn_lock = threading.Lock()

    def _loop_fn(self):
        # iters is a traced argument: ONE compilation serves every sample.
        # Guarded: per-key PlanCache builds run concurrently, and two
        # distinct-key builders must still share one jitted program.
        with self._fn_lock:
            return self._loop_fn_locked()

    def _loop_fn_locked(self):
        if self._fn is None:
            if self.backend == "pallas":
                from repro.kernels.compute_atom import ops as catom_ops
                tile = self.tile

                def burn(x, iters):
                    del iters  # pallas path: static per-call (rarely used)
                    return catom_ops.burn(x, iters=1, tile=tile)
                self._fn = burn
            else:
                def burn(x, iters):
                    return jax.lax.fori_loop(0, iters, compute_burn_body, x)
                self._fn = jax.jit(burn)
        return self._fn

    def spec(self) -> ComputeSpec:
        return ComputeSpec(tile=self.tile, efficiency=self.efficiency,
                           backend=self.backend)

    def flops_per_iter(self) -> float:
        return 2.0 * self.tile ** 3

    def iters_for(self, flops: float) -> int:
        """Quantize a raw flop amount into burn-loop iterations (the same
        rounding the fused schedule compiler uses for its tables)."""
        return max(int(round(flops / self.flops_per_iter()
                             / self.efficiency)), 0)

    def plan(self, flops: float) -> Plan:
        iters = self.iters_for(flops)
        if iters == 0:
            return Plan.noop()
        # Key on the quantized amount (iters), not the raw flops: amounts
        # that round to the same loop count are the same plan, and the plan
        # reports the amount it actually emulates so sharers agree.
        key = ("compute", self.backend, self.tile, self.efficiency, iters)
        return self._cached(key, lambda: self._build_plan(iters))

    def _build_plan(self, iters: int) -> Plan:
        fn = self._loop_fn()
        x = compute_operand(self.tile)
        emulated = iters * self.flops_per_iter() * self.efficiency
        return Plan(lambda: fn(x, iters), emulated)

    def seconds(self, flops: float, hw: HardwareSpec) -> float:
        peak = hw.peak_flops * hw.flops_derate
        return flops / peak if peak else 0.0


# ---------------------------------------------------------------------------
# Memory
# ---------------------------------------------------------------------------

class MemoryAtom(Atom):
    resource = "hbm_bytes"

    def __init__(self, calib: Optional[HostCalibration] = None,
                 block_bytes: int = 1 << 24, backend: str = "jnp"):
        self.calib = calib
        self.block_bytes = block_bytes
        self.backend = backend
        self._fns: Dict[int, Callable] = {}
        self._fn_lock = threading.Lock()

    def _stream_fn(self):
        # guarded like ComputeAtom._loop_fn: concurrent distinct-key plan
        # builds must share one jitted program
        with self._fn_lock:
            return self._stream_fn_locked()

    def _stream_fn_locked(self):
        if not self._fns:
            if self.backend == "pallas":
                from repro.kernels.memory_atom import ops as matom_ops
                bb = self.block_bytes

                def stream(x, iters):
                    return matom_ops.stream(x, iters=int(iters),
                                            block_bytes=bb)
                self._fns[0] = stream
            else:
                def stream(x, iters):
                    return jax.lax.fori_loop(0, iters, memory_stream_body, x)
                self._fns[0] = jax.jit(stream)
        return self._fns[0]

    def spec(self) -> MemorySpec:
        return MemorySpec(block_bytes=self.block_bytes, backend=self.backend)

    def bytes_per_iter(self) -> float:
        return 2.0 * self.block_bytes              # read + write per pass

    def iters_for(self, nbytes: float) -> int:
        """Quantize a byte amount into stream-loop iterations (shared with
        the fused schedule compiler's tables)."""
        return max(int(round(nbytes / self.bytes_per_iter())), 0)

    def plan(self, nbytes: float) -> Plan:
        iters = self.iters_for(nbytes)
        if iters == 0:
            return Plan.noop()
        key = ("memory", self.backend, self.block_bytes, iters)
        return self._cached(key, lambda: self._build_plan(iters))

    def _build_plan(self, iters: int) -> Plan:
        fn = self._stream_fn()
        x = memory_operand(self.block_bytes)
        return Plan(lambda: fn(x, iters), iters * self.bytes_per_iter())

    def seconds(self, nbytes: float, hw: HardwareSpec) -> float:
        bw = hw.hbm_bw * hw.hbm_derate
        return nbytes / bw if bw else 0.0


# ---------------------------------------------------------------------------
# Collective (network)
# ---------------------------------------------------------------------------

class CollectiveAtom(Atom):
    resource = "ici_bytes"

    def __init__(self, mesh=None, axis: Optional[str] = None,
                 kind: str = "all-reduce"):
        self.mesh = mesh
        self.axis = axis or (mesh.axis_names[-1] if mesh is not None else None)
        self.kind = kind
        self._fns: Dict[int, Callable] = {}
        self._loop_fn: Optional[Callable] = None

    def spec(self) -> CollectiveSpec:
        return CollectiveSpec(axis=self.axis, kind=self.kind)

    def quant(self) -> CollectiveQuant:
        """This atom's fused-segment quantization (needs the mesh)."""
        return CollectiveQuant(n=self.mesh.shape[self.axis], kind=self.kind)

    def loop_operand(self, block_elems: int = COLL_BLOCK_ELEMS):
        """The fused scan's collective carry: one fixed block per shard."""
        n = self.mesh.shape[self.axis]
        return jnp.ones((n * block_elems,), jnp.float32)

    def loop_body(self) -> Callable:
        """One fused collective iteration: a shape-invariant shard_map'd
        collective over the fixed block — unlike ``_coll_fn`` (whose
        all-gather grows its output), the result always matches the input
        shape so ``lax.scan``/``fori_loop`` can carry it.  Values are kept
        bounded (psum rescaled by 1/n) because one segment may loop
        thousands of iterations."""
        if self._loop_fn is None:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            mesh, axis, kind = self.mesh, self.axis, self.kind
            n = mesh.shape[axis]

            def local(x):
                if kind == "all-gather":
                    return jax.lax.all_gather(x, axis)[0]
                if kind == "collective-permute":
                    perm = [(i, (i + 1) % n) for i in range(n)]
                    return jax.lax.ppermute(x, axis, perm)
                return jax.lax.psum(x, axis) * (1.0 / n)

            self._loop_fn = shard_map(local, mesh=mesh, in_specs=P(axis),
                                      out_specs=P(axis), check_rep=False)
        return self._loop_fn

    def _coll_fn(self, n_elems: int):
        if n_elems not in self._fns:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            mesh, axis, kind = self.mesh, self.axis, self.kind

            def local(x):
                if kind == "all-gather":
                    return jax.lax.all_gather(x, axis)
                if kind == "collective-permute":
                    n = mesh.shape[axis]
                    perm = [(i, (i + 1) % n) for i in range(n)]
                    return jax.lax.ppermute(x, axis, perm)
                return jax.lax.psum(x, axis)

            fn = shard_map(local, mesh=mesh, in_specs=P(axis),
                           out_specs=P(axis) if kind not in
                           ("all-gather",) else P(axis, None),
                           check_rep=False)
            self._fns[n_elems] = jax.jit(fn)
        return self._fns[n_elems]

    def quantized_wire_bytes(self, n_elems: int) -> float:
        """The wire bytes an ``n_elems``-operand plan actually emulates
        (the ring model applied to the quantized per-chip shard) — note
        tiny amounts clamp UP to one element per shard, so a sub-``4n``-byte
        leg emulates more than it consumes; the emulator reports this as
        ``emulated_ici_bytes`` so predicted-vs-emulated stays honest."""
        n = self.mesh.shape[self.axis]
        factor = collective_factor(self.kind, n)
        return factor * 4.0 * n_elems / n

    def plan(self, wire_bytes: float) -> Plan:
        if self.mesh is None or wire_bytes <= 0:
            return Plan.noop()
        n = self.mesh.shape[self.axis]
        # invert the ring model on the PER-CHIP shard:
        # wire/chip = factor * shard_bytes  (all-reduce: 2*(n-1)/n)
        factor = collective_factor(self.kind, n)
        shard_bytes = wire_bytes / max(factor, 1e-9)
        n_elems = max(int(shard_bytes / 4) * n, n)
        n_elems = (n_elems // n) * n or n
        # Quantized key: amounts rounding to the same shard size share one
        # plan, and — like ComputeAtom/MemoryAtom — the plan reports the
        # QUANTIZED amount it emulates, never the builder's raw wire_bytes,
        # so every cache sharer agrees on what was moved (the emulator
        # tracks *consumption* from the profile, and *emulation* from this).
        # Mesh identity is part of the key: a shared cache may serve
        # emulators on different meshes, and a shard_map is bound to its.
        mesh_id = (tuple(sorted(self.mesh.shape.items())),
                   tuple(d.id for d in self.mesh.devices.flat))
        key = ("collective", self.kind, self.axis, mesh_id, n_elems)
        return self._cached(key, lambda: self._build_plan(n_elems))

    def _build_plan(self, n_elems: int) -> Plan:
        fn = self._coll_fn(n_elems)
        x = jnp.ones((n_elems,), jnp.float32)
        return Plan(lambda: fn(x), self.quantized_wire_bytes(n_elems))

    def seconds(self, wire_bytes: float, hw: HardwareSpec) -> float:
        bw = hw.ici_bw * hw.ici_derate
        return wire_bytes / bw if bw else 0.0


# ---------------------------------------------------------------------------
# Storage
# ---------------------------------------------------------------------------

class StorageAtom(Atom):
    resource = "storage_bytes"

    def __init__(self, calib: Optional[HostCalibration] = None,
                 block_bytes: int = 1 << 20, directory: Optional[str] = None):
        self.calib = calib
        self.block_bytes = block_bytes
        self.dir = directory or tempfile.gettempdir()
        self._buf = os.urandom(block_bytes)
        self._paths: set = set()

    def spec(self) -> StorageSpec:
        return StorageSpec(block_bytes=self.block_bytes)

    def _path(self) -> str:
        # Keyed by planning thread so concurrent fleet workers never write
        # the same scratch file; one worker reuses its file across samples.
        # Tracked so fleet runs can clean up (thread idents churn per pool).
        p = os.path.join(self.dir, f"synapse_atom_{os.getpid()}_"
                                   f"{threading.get_ident()}.bin")
        self._paths.add(p)
        return p

    def cleanup(self) -> None:
        """Remove scratch files created by past plans."""
        while self._paths:
            p = self._paths.pop()
            try:
                os.unlink(p)
            except OSError:
                pass

    def plan_write(self, nbytes: float) -> Plan:
        blocks = max(int(nbytes // self.block_bytes), 0)
        if blocks == 0:
            return Plan.noop()
        path = self._path()

        def launch():
            with open(path, "wb") as f:
                for _ in range(blocks):
                    f.write(self._buf)
                f.flush()
                os.fsync(f.fileno())
            return None
        return Plan(launch, blocks * self.block_bytes)

    def plan_read(self, nbytes: float, precreate: bool = True) -> Plan:
        blocks = max(int(nbytes // self.block_bytes), 0)
        if blocks == 0:
            return Plan.noop()
        path = self._path()
        # Populate the scratch file at *plan* time: the timed read leg must
        # not pay a hidden write on first use (and an empty file would spin
        # the wrap-around read loop forever).  Callers whose sample carries
        # a write leg that runs first pass ``precreate=False`` — that write
        # populates the file and plan-time bytes would be wasted I/O.
        def populate():
            with open(path, "wb") as f:
                for _ in range(blocks):
                    f.write(self._buf)

        if precreate and (not os.path.exists(path)
                          or os.path.getsize(path) == 0):
            populate()

        def launch():
            # the scratch file can vanish between plan and launch (another
            # replay's cleanup()); re-populate rather than fail the leg
            if not os.path.exists(path) or os.path.getsize(path) == 0:
                populate()
            done = 0
            with open(path, "rb") as f:
                while done < blocks * self.block_bytes:
                    chunk = f.read(self.block_bytes)
                    if not chunk:
                        f.seek(0)
                        continue
                    done += len(chunk)
            return None
        return Plan(launch, blocks * self.block_bytes)

    def plan(self, nbytes: float):
        return self.plan_write(nbytes)

    def seconds(self, nbytes: float, hw: HardwareSpec) -> float:
        if self.calib is None:
            return 0.0
        return nbytes / self.calib.storage_write_bps
