"""Sample-ordered emulation driver (paper §IV-B, §IV-D).

Replays a SynapseProfile through the atoms: within one sample all resource
types start together (storage on a worker thread, compute+memory on the
accelerator stream); the next sample starts only when every consumption of
the current sample finished.  Ordering across samples is the fidelity
contract that implicitly preserves inter-resource dependencies; concurrency
inside a sample may *speed up* emulation relative to the original serial
execution, shrinking with finer sampling (paper Fig. 2) — the granularity
experiment in benchmarks/ reproduces that effect.

Identical consecutive samples (a layer scan) are planned once and executed
count times, so emulation compile cost is O(distinct samples).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.atoms import (CollectiveAtom, ComputeAtom, MemoryAtom,
                              StorageAtom)
from repro.core.calibrate import HostCalibration, calibrate
from repro.core.hardware import HardwareSpec
from repro.core.metrics import ResourceVector, Sample, SynapseProfile


@dataclass
class EmulationReport:
    command: str
    ttc_s: float
    n_samples: int
    consumed: ResourceVector
    per_sample_s: List[float] = field(default_factory=list)
    planned: Optional[ResourceVector] = None

    def summary(self) -> Dict:
        return {"command": self.command, "ttc_s": self.ttc_s,
                "n_samples": self.n_samples,
                "flops": self.consumed.flops,
                "hbm_bytes": self.consumed.hbm_bytes,
                "storage_write_bytes": self.consumed.storage_write_bytes}


class Emulator:
    def __init__(self, calib: Optional[HostCalibration] = None, mesh=None,
                 backend: str = "jnp", compute_tile: int = 256,
                 mem_block: int = 1 << 24, storage_block: int = 1 << 20,
                 efficiency: float = 1.0, speed: float = 1.0):
        """``efficiency``: paper's CPU-efficiency knob (see ComputeAtom);
        ``speed`` scales resource amounts (emulate faster/slower hosts:
        the portability benchmark throttles CPU/disk independently via
        ``flops_scale``/``storage_scale`` instead)."""
        self.calib = calib or calibrate()
        self.compute = ComputeAtom(self.calib, tile=compute_tile,
                                   efficiency=efficiency, backend=backend)
        self.memory = MemoryAtom(self.calib, block_bytes=mem_block,
                                 backend=backend)
        self.storage = StorageAtom(self.calib, block_bytes=storage_block)
        self.collective = CollectiveAtom(mesh) if mesh is not None else None
        self.speed = speed

    def _plan_sample(self, r: ResourceVector, flops_scale=1.0,
                     storage_scale=1.0, mem_scale=1.0):
        thunks = []
        if r.flops > 0:
            thunks.append(self.compute.plan(r.flops * flops_scale / self.speed))
        if r.hbm_bytes > 0:
            thunks.append(self.memory.plan(r.hbm_bytes * mem_scale / self.speed))
        wire = r.ici_total
        if wire > 0 and self.collective is not None:
            thunks.append(self.collective.plan(wire / self.speed))
        storage_thunks = []
        if r.storage_write_bytes > 0:
            storage_thunks.append(self.storage.plan_write(
                r.storage_write_bytes * storage_scale / self.speed))
        if r.storage_read_bytes > 0:
            storage_thunks.append(self.storage.plan_read(
                r.storage_read_bytes * storage_scale / self.speed))
        return thunks, storage_thunks

    def emulate(self, profile: SynapseProfile, *, flops_scale: float = 1.0,
                storage_scale: float = 1.0, mem_scale: float = 1.0,
                verify: bool = True) -> EmulationReport:
        runs = _collapse(profile.samples)
        consumed = ResourceVector()
        per_sample = []
        t_start = time.perf_counter()
        for r, count in runs:
            # Consecutive identical samples with no storage leg execute as a
            # single fused consumption (count × amounts): ordering semantics
            # only bind *distinct* samples, and per-dispatch overhead would
            # otherwise dominate fine-grained (per-layer) profiles.
            fuse = count > 1 and r.storage_read_bytes == 0 and \
                r.storage_write_bytes == 0
            reps = 1 if fuse else count
            rr = r.scale(count) if fuse else r
            thunks, storage_thunks = self._plan_sample(
                rr, flops_scale, storage_scale, mem_scale)
            for _ in range(reps):
                t0 = time.perf_counter()
                results = {}

                def io_worker():
                    results["io"] = sum(t() for t in storage_thunks)

                th = None
                if storage_thunks:
                    th = threading.Thread(target=io_worker)
                    th.start()
                for t in thunks:        # device-side consumptions
                    t()
                if th is not None:
                    th.join()
                per_sample.append(time.perf_counter() - t0)
                if verify:
                    consumed = consumed.add(rr)
        ttc = time.perf_counter() - t_start
        return EmulationReport(command=profile.command, ttc_s=ttc,
                               n_samples=len(per_sample), consumed=consumed,
                               per_sample_s=per_sample,
                               planned=profile.totals)


def _collapse(samples: List[Sample]):
    """Group consecutive samples with identical resource vectors."""
    runs = []
    for s in samples:
        if runs and _same(runs[-1][0], s.resources):
            runs[-1][1] += 1
        else:
            runs.append([s.resources, 1])
    return [(r, c) for r, c in runs]


def _same(a: ResourceVector, b: ResourceVector) -> bool:
    return (a.flops == b.flops and a.hbm_bytes == b.hbm_bytes and
            a.ici_bytes == b.ici_bytes and
            a.storage_read_bytes == b.storage_read_bytes and
            a.storage_write_bytes == b.storage_write_bytes)
