"""Sample-ordered emulation driver (paper §IV-B, §IV-D).

Replays a SynapseProfile through the atoms: within one sample all resource
types start together (storage on a worker thread, compute+memory dispatched
asynchronously on the accelerator stream with ONE sync at the sample
barrier); the next sample starts only when every consumption of the current
sample finished.  Ordering across samples is the fidelity contract that
implicitly preserves inter-resource dependencies; concurrency inside a
sample may *speed up* emulation relative to the original serial execution,
shrinking with finer sampling (paper Fig. 2) — the granularity experiment
in benchmarks/ reproduces that effect.

Two execution paths share that contract:

  * **fused** (default, jnp backend): the schedule compiler
    (``repro.core.schedule``) packs contiguous storage/collective-free runs
    into iteration tables executed as ONE jitted ``lax.scan`` per segment,
    so an M-sample profile costs O(storage-segment boundaries) device
    dispatches instead of O(M × atoms); sample ordering is preserved inside
    the scan.  Runs with storage or executable-collective legs replay
    per-sample between segments (the I/O interleave is the point of the
    barrier).  ``benchmarks/bench_dispatch.py`` measures the win.
  * **per-sample** (``fused=False``, or pallas backends): one plan per atom
    per collapsed run.  Identical consecutive samples (a layer scan) are
    planned once and executed as a single scaled consumption, so compile
    cost is O(distinct samples).

Both paths consume the profile's resource vectors in the same order with
the same count-scaling, so reported ``consumed`` totals are bit-identical
(``tests/test_schedule.py`` pins this equivalence).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor
from concurrent.futures import wait as futures_wait
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional

import jax

from repro.core.atoms import (CollectiveAtom, CollectiveSpec, ComputeAtom,
                              ComputeSpec, MemoryAtom, MemorySpec, PlanCache,
                              StorageAtom, StorageSpec)
from repro.core.calibrate import HostCalibration, calibrate
from repro.core.hardware import HardwareSpec
from repro.core.metrics import ResourceVector, Sample, SynapseProfile
from repro.core.schedule import (CompiledSchedule, FusedSegment,
                                 SegmentRunner, compile_schedule)

#: fleet backends ``emulate_many``/``run_fleet`` accept (see ``repro.fleet``
#: for the decision matrix)
VALID_EXECUTORS = ("thread", "process", "remote")


class _Unset:
    """Sentinel type for 'legacy fleet kwarg not passed', so explicitly
    passed defaults fold into a ``FleetConfig`` (with the deprecation
    warning) while silence does not.  Lives here rather than in
    ``repro.fleet.config`` so ``emulate_many`` can use it in its signature
    without a core→fleet module-level import."""

    def __repr__(self) -> str:  # pragma: no cover - repr only
        return "<unset>"


UNSET = _Unset()


@dataclass
class EmulationReport:
    command: str
    ttc_s: float
    n_samples: int
    consumed: ResourceVector
    per_sample_s: List[float] = field(default_factory=list)
    planned: Optional[ResourceVector] = None
    mode: str = "per_sample"             # "fused" | "per_sample"
    n_dispatches: int = 0                # device dispatches issued
    #: executed wire legs (fused rows / barrier launches), counted the same
    #: on every path — fused, barrier fallback, and fleet workers — for
    #: legs of at least one quantization iteration.  Below that the paths
    #: quantize at different granularities and honestly diverge: a fused
    #: row rounds sub-half-block legs to a no-op (like compute/memory
    #: rows), while CollectiveAtom.plan clamps up to one element per shard
    #: (tests/test_collectives_fused.py pins both).
    n_collective_dispatches: int = 0
    #: wire bytes actually moved after quantization — tiny legs clamp UP
    #: (CollectiveAtom pads sub-4n-byte amounts to one element per shard),
    #: so this can exceed consumed.ici_total; comparing predicted vs
    #: emulated must use this, not the profile amount
    emulated_ici_bytes: float = 0.0

    def summary(self) -> Dict:
        return {"command": self.command, "ttc_s": self.ttc_s,
                "n_samples": self.n_samples,
                "mode": self.mode, "n_dispatches": self.n_dispatches,
                "n_collective_dispatches": self.n_collective_dispatches,
                "flops": self.consumed.flops,
                "hbm_bytes": self.consumed.hbm_bytes,
                "ici_bytes": self.consumed.ici_total,
                "emulated_ici_bytes": self.emulated_ici_bytes,
                "storage_read_bytes": self.consumed.storage_read_bytes,
                "storage_write_bytes": self.consumed.storage_write_bytes}

    def to_dict(self) -> Dict:
        """Lossless JSON-able form (``from_dict`` round-trips it)."""
        return {"command": self.command, "ttc_s": self.ttc_s,
                "n_samples": self.n_samples,
                "consumed": self.consumed.to_dict(),
                "per_sample_s": list(self.per_sample_s),
                "planned": (None if self.planned is None
                            else self.planned.to_dict()),
                "mode": self.mode, "n_dispatches": self.n_dispatches,
                "n_collective_dispatches": self.n_collective_dispatches,
                "emulated_ici_bytes": self.emulated_ici_bytes}

    @classmethod
    def from_dict(cls, d: Dict) -> "EmulationReport":
        return cls(command=d["command"], ttc_s=d["ttc_s"],
                   n_samples=d["n_samples"],
                   consumed=ResourceVector.from_dict(d["consumed"]),
                   per_sample_s=list(d.get("per_sample_s", ())),
                   planned=(None if d.get("planned") is None
                            else ResourceVector.from_dict(d["planned"])),
                   mode=d.get("mode", "per_sample"),
                   n_dispatches=d.get("n_dispatches", 0),
                   n_collective_dispatches=d.get(
                       "n_collective_dispatches", 0),
                   emulated_ici_bytes=d.get("emulated_ici_bytes", 0.0))


@dataclass
class FleetReport:
    """Result of ``Emulator.emulate_many``: K profiles replayed concurrently.

    ``max_workers`` is the *effective* pool size (requested workers capped
    at the number of profiles, so tiny fleets don't spawn idle threads; an
    autoscaled fleet reports its ceiling).  ``totals``/``n_samples``/
    ``n_replayed`` are aggregates folded in bundle-index order as reports
    complete — they are the whole result in ``collect="totals"`` mode,
    where ``reports`` stays empty so coordinator memory is bounded by the
    compile-ahead window, not the stream length.  ``scaling`` carries the
    elasticity record of the run (scale_ups/scale_downs/peak_workers/
    peak_queue_depth/peak_window) when the executor streams through
    ``FleetBase``.  ``recovery`` carries the fault-recovery accounting of
    the run (worker_deaths/hung_reaped/requeued/requeue_latency_s/
    lost_replay_s/mttr_s/skipped/speculative_dispatches/speculative_wins/
    heartbeats) — what every fault cost, not just that recovery happened.
    ``obs`` is the observability snapshot (``repro.obs``): the merged
    flight-recorder timeline (bounded), drop accounting, and a metrics
    snapshot — populated by the ``FleetBase`` executors.
    ``dag`` is the critical-path accounting of a dependency-structured
    run (``critical_path_s``/``makespan_s``/``sum_work_s``/
    ``parallelism``/``critical_nodes``/per-node ``slack_s`` — see
    ``repro.fleet.dag.critical_path``); empty for linear runs.
    """
    reports: List[EmulationReport]
    wall_s: float                        # concurrent fleet wall time
    serial_s: float                      # sum of per-profile TTCs
    max_workers: int
    cache_stats: Dict[str, int] = field(default_factory=dict)
    totals: Optional[ResourceVector] = None
    n_samples: int = 0                   # profile samples replayed
    n_replayed: int = 0                  # profiles replayed (any collect=)
    scaling: Dict[str, int] = field(default_factory=dict)
    recovery: Dict = field(default_factory=dict)
    obs: Dict = field(default_factory=dict)
    dag: Dict = field(default_factory=dict)

    @property
    def n_profiles(self) -> int:
        return self.n_replayed or len(self.reports)

    @property
    def speedup(self) -> float:
        """Estimated concurrency win: sum of per-profile TTCs over fleet
        wall time.  Per-profile TTCs are measured *under* concurrent
        contention, so on a saturated host this over-states the true
        back-to-back-vs-fleet ratio; ``bench_scenarios`` measures real
        serial replay separately for the honest number."""
        return self.serial_s / self.wall_s if self.wall_s else 0.0

    def summary(self) -> Dict:
        out = {"n_profiles": self.n_profiles, "wall_s": self.wall_s,
               "serial_s": self.serial_s, "speedup": self.speedup,
               "max_workers": self.max_workers, **self.cache_stats}
        if self.n_samples:
            out["n_samples"] = self.n_samples
        if self.totals is not None:
            out["total_flops"] = self.totals.flops
            out["total_hbm_bytes"] = self.totals.hbm_bytes
            out["total_ici_bytes"] = self.totals.ici_total
        if self.scaling:
            out["scaling"] = dict(self.scaling)
        if self.recovery:
            out["recovery"] = dict(self.recovery)
        if self.dag:
            out["critical_path_s"] = self.dag.get("critical_path_s")
            out["makespan_s"] = self.dag.get("makespan_s")
            out["parallelism"] = self.dag.get("parallelism")
        return out

    #: schema version of ``to_json``; bump on any breaking field change
    SCHEMA = 1

    def to_json(self, *, reports: bool = True) -> Dict:
        """Stable JSON-able form with a schema version field.

        Everything round-trips through ``from_json`` — scaling, recovery
        (fault_events tuples become lists, as JSON requires), the obs
        snapshot, and (unless ``reports=False``, the bounded-memory
        service mode) the per-profile reports.
        """
        rec = dict(self.recovery)
        if "fault_events" in rec:
            rec["fault_events"] = [list(fe) for fe in rec["fault_events"]]
        dag = dict(self.dag)
        if "slack_s" in dag:
            # JSON object keys are strings; from_json restores the ints
            dag["slack_s"] = {str(k): v for k, v in dag["slack_s"].items()}
        return {
            "schema": self.SCHEMA,
            "reports": ([r.to_dict() for r in self.reports]
                        if reports else []),
            "wall_s": self.wall_s, "serial_s": self.serial_s,
            "max_workers": self.max_workers,
            "cache_stats": dict(self.cache_stats),
            "totals": (None if self.totals is None
                       else self.totals.to_dict()),
            "n_samples": self.n_samples, "n_replayed": self.n_replayed,
            "scaling": dict(self.scaling), "recovery": rec,
            "obs": self.obs, "dag": dag,
        }

    @classmethod
    def from_json(cls, d: Dict) -> "FleetReport":
        schema = d.get("schema")
        if schema != cls.SCHEMA:
            raise ValueError(
                f"FleetReport schema {schema!r} is not supported "
                f"(this build reads schema {cls.SCHEMA})")
        rec = dict(d.get("recovery", {}))
        if "fault_events" in rec:
            rec["fault_events"] = [tuple(fe) for fe in rec["fault_events"]]
        dag = dict(d.get("dag", {}))
        if "slack_s" in dag:
            dag["slack_s"] = {int(k): v for k, v in dag["slack_s"].items()}
        return cls(
            reports=[EmulationReport.from_dict(r)
                     for r in d.get("reports", ())],
            wall_s=d["wall_s"], serial_s=d["serial_s"],
            max_workers=d["max_workers"],
            cache_stats=dict(d.get("cache_stats", {})),
            totals=(None if d.get("totals") is None
                    else ResourceVector.from_dict(d["totals"])),
            n_samples=d.get("n_samples", 0),
            n_replayed=d.get("n_replayed", 0),
            scaling=dict(d.get("scaling", {})), recovery=rec,
            obs=dict(d.get("obs", {})), dag=dag)


class ReportFold:
    """Order-stable aggregate folder for streamed fleet results.

    Workers complete bundles in whatever order the fleet's load (and any
    autoscaling) dictates, but float summation is not associative-in-
    practice: folding ``consumed`` totals in completion order would make
    the aggregate depend on pool size and scale events.  ``ReportFold``
    buffers out-of-order arrivals and folds strictly in bundle-index
    order, so the aggregate totals of a streamed, autoscaled fleet are
    bit-identical to a fixed-size (or fully materialized) run over the
    same profiles.  The reorder buffer is bounded by the compile-ahead
    window: index ``i`` can only be outstanding while it is inside the
    window, so at most ``window`` reports are ever buffered.

    ``keep_reports=False`` (``collect="totals"``) drops each report after
    folding — the bounded-coordinator-memory soak mode.
    """

    def __init__(self, keep_reports: bool = True):
        self.keep_reports = keep_reports
        self.reports: List[EmulationReport] = []
        self.totals = ResourceVector()
        self.serial_s = 0.0
        self.n_done = 0
        self.n_skipped = 0
        self.n_skipped_ancestor = 0
        self._next = 0
        self._pending: Dict[int, EmulationReport] = {}
        self._holes: set = set()

    def add(self, idx: int, report: EmulationReport) -> None:
        self._pending[idx] = report
        self._drain()

    def skip(self, idx: int, *, ancestor: bool = False) -> None:
        """Index ``idx`` will never arrive (degraded-mode skip): fold past
        the hole so later indices still aggregate in order — without this
        one skipped bundle would stall the fold and buffer the rest of the
        stream.  ``ancestor=True`` marks a *cascade* hole — a bundle
        skipped because an ancestor in its dependency chain was, not
        because it failed itself — tallied separately in
        ``n_skipped_ancestor`` (always also counted in ``n_skipped``)."""
        self.n_skipped += 1
        if ancestor:
            self.n_skipped_ancestor += 1
        self._holes.add(idx)
        self._drain()

    def _drain(self) -> None:
        while True:
            if self._next in self._holes:
                self._holes.discard(self._next)
                self._next += 1
                continue
            if self._next not in self._pending:
                break
            rep = self._pending.pop(self._next)
            self._next += 1
            self.totals = self.totals.add(rep.consumed)
            self.serial_s += rep.ttc_s
            self.n_done += 1
            if self.keep_reports:
                self.reports.append(rep)


@dataclass(frozen=True)
class EmulatorSpec:
    """Picklable recipe for an ``Emulator``: calibration + atom configs.

    ``build()`` reconstructs an equivalent emulator anywhere — same
    quantization (tile/block sizes), same efficiency/speed knobs, and the
    *parent's* host calibration, so fleet workers neither re-calibrate nor
    drift from the emulator that compiled their schedules.  ``mesh`` (a live
    jax Mesh, built on the destination from its own devices) attaches a
    CollectiveAtom per the collective spec.
    """
    calib: HostCalibration
    compute: ComputeSpec = ComputeSpec()
    memory: MemorySpec = MemorySpec()
    storage: StorageSpec = StorageSpec()
    collective: Optional[CollectiveSpec] = None
    speed: float = 1.0

    def build(self, mesh=None) -> "Emulator":
        em = Emulator(calib=self.calib, backend=self.compute.backend,
                      compute_tile=self.compute.tile,
                      mem_block=self.memory.block_bytes,
                      storage_block=self.storage.block_bytes,
                      efficiency=self.compute.efficiency, speed=self.speed)
        if mesh is not None:
            em.attach_collective(
                (self.collective or CollectiveSpec()).build(mesh))
        return em


class Emulator:
    def __init__(self, calib: Optional[HostCalibration] = None, mesh=None,
                 backend: str = "jnp", compute_tile: int = 256,
                 mem_block: int = 1 << 24, storage_block: int = 1 << 20,
                 efficiency: float = 1.0, speed: float = 1.0,
                 plan_cache: Optional[PlanCache] = None):
        """``efficiency``: paper's CPU-efficiency knob (see ComputeAtom);
        ``speed`` scales resource amounts (emulate faster/slower hosts:
        the portability benchmark throttles CPU/disk independently via
        ``flops_scale``/``storage_scale`` instead); ``plan_cache``: share
        compiled atom plans across emulators / fleet workers (see
        ``emulate_many``)."""
        self.calib = calib or calibrate()
        self.compute = ComputeAtom(self.calib, tile=compute_tile,
                                   efficiency=efficiency, backend=backend)
        self.memory = MemoryAtom(self.calib, block_bytes=mem_block,
                                 backend=backend)
        self.storage = StorageAtom(self.calib, block_bytes=storage_block)
        self.collective = CollectiveAtom(mesh) if mesh is not None else None
        self.speed = speed
        self.plan_cache = None
        self._fleet_lock = threading.Lock()
        # Fused segments need table-driven loop counts, which the pallas
        # atom kernels don't take; those backends fall back to per-sample.
        self._fusable = backend == "jnp"
        self._segments = SegmentRunner(tile=compute_tile,
                                       block_bytes=mem_block,
                                       collective=self.collective)
        if plan_cache is not None:
            self.set_plan_cache(plan_cache)

    def set_plan_cache(self, cache: Optional[PlanCache]) -> None:
        """Route compute/memory/collective plans through a shared cache
        (``None`` detaches it — plans go back to per-call construction)."""
        self.plan_cache = cache
        self.compute.cache = cache
        self.memory.cache = cache
        if self.collective is not None:
            self.collective.cache = cache

    def attach_collective(self, atom: CollectiveAtom) -> None:
        """Install a (mesh-bound) collective atom after construction,
        keeping the segment runner's mesh-bound programs and the plan
        cache routing in sync — ``EmulatorSpec.build`` uses this to give
        fleet workers their per-worker mesh."""
        self.collective = atom
        self._segments.set_collective(atom)
        if self.plan_cache is not None:
            atom.cache = self.plan_cache

    def spec(self) -> EmulatorSpec:
        """This emulator's picklable recipe (see ``EmulatorSpec``)."""
        return EmulatorSpec(
            calib=self.calib, compute=self.compute.spec(),
            memory=self.memory.spec(), storage=self.storage.spec(),
            collective=(self.collective.spec()
                        if self.collective is not None else None),
            speed=self.speed)

    def compile(self, profile: SynapseProfile, *, flops_scale: float = 1.0,
                mem_scale: float = 1.0,
                keep_collectives: Optional[bool] = None,
                mesh_spec=None) -> CompiledSchedule:
        """Lower a profile to its fused schedule (inspection / pre-warm /
        detach-and-ship).  ``mesh_spec`` quantizes wire-byte runs into
        mesh-bound segment rows for the mesh the *workers* will build —
        this process needs no mesh of its own.  ``keep_collectives=True``
        is the barrier-step fallback instead: wire runs replay per-sample
        through the replaying emulator's CollectiveAtom."""
        quant = None
        if mesh_spec is not None:
            spec = (self.collective.spec() if self.collective is not None
                    else CollectiveSpec())
            quant = spec.quant_for(mesh_spec)
        return compile_schedule(_collapse(profile.samples),
                                compute=self.compute, memory=self.memory,
                                collective=self.collective,
                                flops_scale=flops_scale,
                                mem_scale=mem_scale, speed=self.speed,
                                keep_collectives=keep_collectives,
                                collective_quant=quant)

    def _plan_sample(self, r: ResourceVector, flops_scale=1.0,
                     storage_scale=1.0, mem_scale=1.0):
        """Plan one sample's device legs as (resource kind, Plan) pairs plus
        its host-side storage plans."""
        thunks = []
        if r.flops > 0:
            thunks.append(("flops",
                           self.compute.plan(r.flops * flops_scale / self.speed)))
        if r.hbm_bytes > 0:
            thunks.append(("hbm",
                           self.memory.plan(r.hbm_bytes * mem_scale / self.speed)))
        wire = r.ici_total
        if wire > 0 and self.collective is not None:
            thunks.append(("ici", self.collective.plan(wire / self.speed)))
        storage_thunks = []
        if r.storage_write_bytes > 0:
            storage_thunks.append(self.storage.plan_write(
                r.storage_write_bytes * storage_scale / self.speed))
        if r.storage_read_bytes > 0:
            # the write leg (if any) runs first on the I/O worker and
            # populates the scratch file; plan-time pre-creation would be
            # wasted bytes then
            writes = storage_thunks and storage_thunks[0].amount > 0
            storage_thunks.append(self.storage.plan_read(
                r.storage_read_bytes * storage_scale / self.speed,
                precreate=not writes))
        return thunks, storage_thunks

    def _run_per_sample(self, r: ResourceVector, count: int, flops_scale,
                        storage_scale, mem_scale, consumed, per_sample,
                        verify: bool):
        """Replay one collapsed run the per-sample way; returns the updated
        consumed vector, the number of device dispatches issued, how many
        of those were executable collectives, and the quantized wire bytes
        those collectives emulated.

        Consecutive identical samples with no storage leg execute as a
        single fused consumption (count × amounts): ordering semantics only
        bind *distinct* samples, and per-dispatch overhead would otherwise
        dominate fine-grained (per-layer) profiles.  Device thunks are
        launched asynchronously and synced once at the sample barrier;
        storage overlaps on the I/O worker thread.
        """
        fuse = count > 1 and r.storage_read_bytes == 0 and \
            r.storage_write_bytes == 0
        reps = 1 if fuse else count
        rr = r.scale(count) if fuse else r
        thunks, storage_thunks = self._plan_sample(
            rr, flops_scale, storage_scale, mem_scale)
        dispatches = 0
        coll_dispatches = 0
        emulated_ici = 0.0
        for _ in range(reps):
            t0 = time.perf_counter()

            def io_worker():
                for t in storage_thunks:
                    t()

            th = None
            if storage_thunks:
                th = threading.Thread(target=io_worker)
                th.start()
            tokens = []
            for kind, t in thunks:                  # async device dispatch
                tok = t.launch()
                if tok is not None:                 # noop plans don't count
                    tokens.append(tok)
                    if kind == "ici":
                        coll_dispatches += 1
                        emulated_ici += t.amount    # quantized, see atoms
            dispatches += len(tokens)
            if tokens:
                jax.block_until_ready(tokens)       # one sync per sample
            if th is not None:
                th.join()
            per_sample.append(time.perf_counter() - t0)
            if verify:
                consumed = consumed.add(rr)
        return consumed, dispatches, coll_dispatches, emulated_ici

    def replay(self, sched: CompiledSchedule, *, command: str = "",
               planned: Optional[ResourceVector] = None,
               flops_scale: float = 1.0, storage_scale: float = 1.0,
               mem_scale: float = 1.0, verify: bool = True
               ) -> EmulationReport:
        """Execute an already-compiled schedule (fused path).

        This is the whole fused replay loop, factored out of ``emulate`` so
        a schedule compiled in one process can be shipped (see
        ``CompiledSchedule.detach``) and replayed by a fleet worker's own
        emulator with identical consumption accounting: segments run as one
        dispatch each — mesh-bound segments execute their wire rows inside
        that same dispatch on this emulator's mesh — and barrier steps
        replay per-sample through this emulator's atoms, including
        collective legs when this emulator owns a mesh.
        """
        if sched.mesh_bound:
            if self.collective is None or self.collective.mesh is None:
                raise RuntimeError(
                    "schedule carries mesh-bound collective segments but "
                    "this emulator owns no mesh; recompile it with "
                    "keep_collectives=True (barrier fallback) or build the "
                    "emulator with a mesh")
            mine = self.collective.quant()
            want = sched.collective_quant
            if want is None:
                raise RuntimeError(
                    "mesh-bound schedule carries no collective_quant — "
                    "its tables cannot be validated against this mesh; "
                    "recompile it (compile_schedule records the quant "
                    "whenever it fuses wire runs)")
            if want != mine:
                raise RuntimeError(
                    f"schedule was quantized for {want} but this "
                    f"emulator's mesh gives {mine}; replaying would emulate "
                    "skewed wire amounts — recompile for this mesh")
        consumed = ResourceVector()
        per_sample: List[float] = []
        dispatches = 0
        coll_dispatches = 0
        emulated_ici = 0.0
        quant = sched.collective_quant
        t_start = time.perf_counter()
        for step in sched.steps:
            if isinstance(step, FusedSegment):
                t0 = time.perf_counter()
                dispatched = self._segments.run(step)  # ONE dispatch+sync
                dt = time.perf_counter() - t0
                dispatches += int(dispatched)
                if step.mesh_bound:
                    # one executed wire leg per collective-bearing row —
                    # the same granularity the barrier fallback counts at
                    coll_dispatches += int((step.table[:, 2] > 0).sum())
                    emulated_ici += quant.emulated_bytes(
                        step.collective_iters)
                # apportion the segment's wall time across its rows so
                # per_sample_s keeps one entry per executed sample
                per_sample.extend([dt / step.n_rows] * step.n_rows)
                if verify:
                    for rr in step.rows:
                        consumed = consumed.add(rr)
            else:
                consumed, d, c, e = self._run_per_sample(
                    step.resources, step.count, flops_scale,
                    storage_scale, mem_scale, consumed, per_sample,
                    verify)
                dispatches += d
                coll_dispatches += c
                emulated_ici += e
        ttc = time.perf_counter() - t_start
        return EmulationReport(command=command, ttc_s=ttc,
                               n_samples=len(per_sample), consumed=consumed,
                               per_sample_s=per_sample, planned=planned,
                               mode="fused", n_dispatches=dispatches,
                               n_collective_dispatches=coll_dispatches,
                               emulated_ici_bytes=emulated_ici)

    def emulate(self, profile: SynapseProfile, *, flops_scale: float = 1.0,
                storage_scale: float = 1.0, mem_scale: float = 1.0,
                verify: bool = True, fused: bool = True) -> EmulationReport:
        runs = _collapse(profile.samples)
        use_fused = fused and self._fusable
        t_start = time.perf_counter()
        if use_fused:
            sched = compile_schedule(runs, compute=self.compute,
                                     memory=self.memory,
                                     collective=self.collective,
                                     flops_scale=flops_scale,
                                     mem_scale=mem_scale, speed=self.speed)
            rep = self.replay(sched, command=profile.command,
                              planned=profile.totals,
                              flops_scale=flops_scale,
                              storage_scale=storage_scale,
                              mem_scale=mem_scale, verify=verify)
            rep.ttc_s = time.perf_counter() - t_start   # include compile
            return rep
        consumed = ResourceVector()
        per_sample: List[float] = []
        dispatches = 0
        coll_dispatches = 0
        emulated_ici = 0.0
        for r, count in runs:
            consumed, d, c, e = self._run_per_sample(
                r, count, flops_scale, storage_scale, mem_scale,
                consumed, per_sample, verify)
            dispatches += d
            coll_dispatches += c
            emulated_ici += e
        ttc = time.perf_counter() - t_start
        return EmulationReport(command=profile.command, ttc_s=ttc,
                               n_samples=len(per_sample), consumed=consumed,
                               per_sample_s=per_sample,
                               planned=profile.totals,
                               mode="per_sample",
                               n_dispatches=dispatches,
                               n_collective_dispatches=coll_dispatches,
                               emulated_ici_bytes=emulated_ici)

    def emulate_many(self, profiles: Iterable[SynapseProfile], *,
                     flops_scale: float = 1.0, storage_scale: float = 1.0,
                     mem_scale: float = 1.0, verify: bool = True,
                     fused: bool = True, config=None,
                     collect: str = "reports",
                     # legacy fleet kwargs: fold into a FleetConfig with a
                     # DeprecationWarning — pass config= instead
                     executor=UNSET, max_workers=UNSET, mesh_spec=UNSET,
                     hosts=UNSET, listen=UNSET, agents=UNSET,
                     timeout=UNSET) -> FleetReport:
        """Fleet mode: replay many profiles concurrently.

        ``profiles`` is any iterable — a list, or a lazy source like
        ``ProfileStore.stream(...)``.  Every executor consumes it as a
        stream: profiles are pulled (and, on process/remote, compiled to
        bundles) at most ``config.window`` ahead of replay, so the source
        is backpressured by worker throughput and coordinator memory stays
        bounded by the window even when the stream is a production day
        long.  ``collect="totals"`` additionally drops per-profile reports
        after folding them into ``FleetReport.totals``, the bounded-memory
        mode for unbounded streams.

        ``config`` (a ``repro.fleet.FleetConfig``) is the one knob surface:
        ``FleetConfig.thread()`` runs profiles on worker threads inside
        this process, sharing this emulator's atoms through a keyed plan
        cache — identical (atom, amount) plans are built, and their XLA
        programs traced, once for the whole fleet instead of once per
        profile.  ``FleetConfig.process(...)`` compiles each profile to a
        ``CompiledSchedule`` here, detaches it to a picklable bundle, and
        ships it to a spawn-based worker-process pool
        (``repro.fleet.ProcessFleet``) where each worker owns its own
        emulator, jitted programs, and — with ``mesh=MeshSpec(...)`` — its
        own device mesh, so collective legs *execute* in fleet mode.
        ``FleetConfig.remote(...)`` ships the same bundles over framed TCP
        to host agents on other machines (``repro.fleet.RemoteFleet``).
        Process and remote pools can be elastic (``autoscale=True``):
        capacity is spawned/invited while queued bundles outnumber free
        slots and retired back to ``min_workers`` when the stream drains,
        with the scale record in ``FleetReport.scaling``.  See
        ``repro.fleet`` for the full decision matrix and the legacy-kwarg
        migration example.

        ``config.timeout`` bounds each fleet run.  Process and remote
        executors enforce it strictly (the scheduler deadline); the thread
        executor stops *starting* profiles at the deadline and raises, but
        profiles already replaying run to completion — threads can't be
        preempted.

        The robustness knobs (``max_attempts``, ``liveness_timeout``,
        ``speculate``, ``on_failure``, ``chaos``, ``max_respawns``) thread
        straight through to the fleet scheduler; fault accounting comes
        back in ``FleetReport.recovery``.  With ``on_failure="skip"`` the
        run completes degraded instead of raising on a poison profile —
        ``totals`` then cover only the replayed profiles, with the holes
        listed in ``recovery["skipped"]``.

        Each profile replays on exactly one worker, so the per-profile
        sample-ordering contract is intact; ordering *across* profiles is
        deliberately unconstrained (a fleet has no inter-profile
        dependencies) — but aggregate ``totals`` are folded in profile
        order, so they are bit-identical however the fleet is shaped.  A
        sized ``profiles`` caps the pool at ``len(profiles)`` so tiny
        fleets don't spawn idle workers.

        ``profiles`` may also be a ``repro.scenarios.WorkloadDag``
        (anything exposing ``parents_map``): the fleet then honors the
        dependency edges — a node dispatches only after every parent's
        result lands — and the report's ``dag`` dict carries
        critical-path accounting.  DAGs need the process/remote
        executors (the frontier scheduler lives in ``FleetBase.stream``)
        and ``collect="reports"``; both are validated loudly here.
        """
        from repro.fleet.config import FleetConfig
        cfg = FleetConfig.fold(
            config,
            dict(executor=executor, max_workers=max_workers,
                 mesh_spec=mesh_spec, hosts=hosts, listen=listen,
                 agents=agents, timeout=timeout),
            caller="Emulator.emulate_many")
        if collect not in ("reports", "totals"):
            raise ValueError("collect must be 'reports' (keep per-profile "
                             "reports) or 'totals' (fold aggregates only)")
        is_dag = hasattr(profiles, "parents_map")
        if (is_dag or cfg.dag) and cfg.executor == "thread":
            raise ValueError(
                "dependency-structured workloads (WorkloadDag, or "
                "FleetConfig(dag=True)) need executor='process' or "
                "'remote': the frontier scheduler lives in the fleet "
                "executors — the in-process thread pool has no dispatch "
                "gating.  Use FleetConfig.process(...) or .remote(...)")
        cfg.check_collect(collect, dag=is_dag)
        if cfg.executor in ("process", "remote"):
            if not (fused and self._fusable):
                raise ValueError(f"executor={cfg.executor!r} ships compiled "
                                 "schedules and requires the fused jnp "
                                 "replay path (fused=True, backend='jnp')")
            if cfg.executor == "remote":
                from repro.fleet.transport.remote import run_remote_fleet
                return run_remote_fleet(self, profiles, hosts=cfg.hosts,
                                        listen=cfg.listen, agents=cfg.agents,
                                        mesh_spec=cfg.mesh_spec,
                                        flops_scale=flops_scale,
                                        storage_scale=storage_scale,
                                        mem_scale=mem_scale, verify=verify,
                                        timeout=cfg.timeout,
                                        window=cfg.window,
                                        autoscale=cfg.autoscale,
                                        min_workers=cfg.min_workers,
                                        max_attempts=cfg.max_attempts,
                                        liveness_timeout=cfg.liveness_timeout,
                                        speculate=cfg.speculate,
                                        on_failure=cfg.on_failure,
                                        chaos=cfg.chaos,
                                        collect=collect)
            from repro.fleet.executor import run_process_fleet
            return run_process_fleet(self, profiles,
                                     max_workers=cfg.max_workers,
                                     mesh_spec=cfg.mesh_spec,
                                     flops_scale=flops_scale,
                                     storage_scale=storage_scale,
                                     mem_scale=mem_scale, verify=verify,
                                     timeout=cfg.timeout, window=cfg.window,
                                     autoscale=cfg.autoscale,
                                     min_workers=cfg.min_workers,
                                     max_attempts=cfg.max_attempts,
                                     liveness_timeout=cfg.liveness_timeout,
                                     speculate=cfg.speculate,
                                     on_failure=cfg.on_failure,
                                     chaos=cfg.chaos,
                                     max_respawns=cfg.max_respawns,
                                     collect=collect)
        workers = cfg.max_workers
        if hasattr(profiles, "__len__"):
            workers = max(1, min(workers, len(profiles)))
        win = cfg.window if cfg.window is not None else max(2 * workers, 2)
        # One fleet at a time per emulator: the atoms, ephemeral cache
        # attach/detach and scratch-file cleanup are instance state.
        with self._fleet_lock:
            cache = self.plan_cache
            ephemeral = cache is None
            if ephemeral:
                # Scope the auto-created cache to this call: retained plans
                # pin their operand arrays, so a long-lived emulator must
                # not keep accumulating them as a side effect of one fleet
                # replay.
                cache = PlanCache()
                self.set_plan_cache(cache)
            before = cache.stats()
            fold = ReportFold(keep_reports=collect != "totals")
            skipped: List[int] = []
            try:
                t0 = time.perf_counter()
                deadline = time.monotonic() + cfg.timeout
                source = iter(profiles)
                exhausted = False
                next_idx = 0
                n_samples = 0                    # true profile samples
                inflight: Dict = {}              # future -> profile index
                with ThreadPoolExecutor(max_workers=workers) as pool:
                    try:
                        while True:
                            # admission: at most `win` profiles submitted
                            # but unfinished — a lazy source is pulled (and
                            # anything it generates materialized) only as
                            # the pool drains
                            while not exhausted and len(inflight) < win:
                                try:
                                    p = next(source)
                                except StopIteration:
                                    exhausted = True
                                    break
                                n_samples += len(p.samples)
                                f = pool.submit(self.emulate, p,
                                                flops_scale=flops_scale,
                                                storage_scale=storage_scale,
                                                mem_scale=mem_scale,
                                                verify=verify, fused=fused)
                                inflight[f] = next_idx
                                next_idx += 1
                            if not inflight:
                                break
                            left = deadline - time.monotonic()
                            done = futures_wait(
                                list(inflight), timeout=max(0.0, left),
                                return_when=FIRST_COMPLETED).done
                            if not done:
                                raise TimeoutError(
                                    f"fleet run exceeded {cfg.timeout}s "
                                    f"with {len(inflight)} profile(s) "
                                    "unfinished (in-flight thread replays "
                                    "drain before this raises)")
                            for f in done:
                                idx = inflight.pop(f)
                                try:
                                    rep = f.result()
                                except Exception:
                                    # threads share this process, so there
                                    # is no worker to reap or retry against:
                                    # a profile that raises is degraded-mode
                                    # skippable, nothing else
                                    if cfg.on_failure != "skip":
                                        raise
                                    skipped.append(idx)
                                    fold.skip(idx)
                                    continue
                                fold.add(idx, rep)
                    except BaseException:
                        for f in inflight:
                            f.cancel()           # queued ones never start
                        raise
                wall = time.perf_counter() - t0
            finally:
                if ephemeral:
                    self.set_plan_cache(None)
                self.storage.cleanup()   # pool threads churn -> fresh
                                         # scratch files per run
            # report this call's activity, not cache-lifetime totals
            after = cache.stats()
            stats = {k: after[k] - before[k] for k in ("plans_built", "hits")}
            stats["size"] = after["size"]
        recovery = {"skipped": sorted(skipped)} if skipped else {}
        return FleetReport(reports=fold.reports, wall_s=wall,
                           serial_s=fold.serial_s, max_workers=workers,
                           cache_stats=stats, totals=fold.totals,
                           n_samples=n_samples, n_replayed=fold.n_done,
                           recovery=recovery)


def _collapse(samples: List[Sample]):
    """Group consecutive samples with identical resource vectors."""
    runs = []
    for s in samples:
        if runs and _same(runs[-1][0], s.resources):
            runs[-1][1] += 1
        else:
            runs.append([s.resources, 1])
    return [(r, c) for r, c in runs]


def _same(a: ResourceVector, b: ResourceVector) -> bool:
    return (a.flops == b.flops and a.hbm_bytes == b.hbm_bytes and
            a.ici_bytes == b.ici_bytes and
            a.storage_read_bytes == b.storage_read_bytes and
            a.storage_write_bytes == b.storage_write_bytes)
