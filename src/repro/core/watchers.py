"""Runtime watcher plugins — the paper's profiling architecture, verbatim.

Each watcher runs in its own thread, sampling at a global rate (paper: max
10/s; we allow faster since /proc is cheap), with the paper's plugin
protocol: ``_pre_process`` / ``_sample`` / ``_post_process`` / ``_finalize``
(where a plugin may read other watchers' results to avoid duplicating
measurements, e.g. runtime).  Timestamps are per-watcher and unsynchronized,
exactly as the paper chose (IV-A): skew is preferred over sync overhead.

All stamps route through ``repro.obs.clock``: sample timestamps are the
anchored wall projection of the monotonic clock, and every duration
(watcher wall_s, profiled-callable wall) is a monotonic difference — an
NTP step mid-profile can no longer produce a negative or inflated
duration.

These watchers profile *this* process (the JAX host process executing
jitted steps) — on a real TPU VM the same code observes the host side while
the static watcher (hlo_analysis) covers the device side.
"""
from __future__ import annotations

import os
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.core.metrics import ResourceVector, Sample, SynapseProfile
from repro.obs import clock as obs_clock

DEFAULT_SAMPLE_RATE = float(os.environ.get("SYNAPSE_SAMPLE_RATE", "10"))


class WatcherBase:
    """Paper §IV-A plugin structure."""

    name = "base"

    def __init__(self, pid: Optional[int] = None):
        self.pid = pid or os.getpid()
        self.samples: List[Dict[str, Any]] = []
        self.result: Dict[str, Any] = {}
        self._terminate = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._sample_interval = 1.0 / DEFAULT_SAMPLE_RATE

    # -- plugin protocol ------------------------------------------------------
    def _pre_process(self, config: Dict):
        pass

    def _sample(self, now: float):
        raise NotImplementedError

    def _post_process(self):
        pass

    def _finalize(self, all_watchers: Dict[str, "WatcherBase"]):
        """May read other watchers' raw results (paper: avoids duplicate
        measurements such as overall runtime)."""

    # -- threaded run loop (paper listing) ------------------------------------
    def run(self, config: Dict):
        self._pre_process(config)
        self._sample_interval = 1.0 / config.get("sample_rate",
                                                 DEFAULT_SAMPLE_RATE)
        while not self._terminate.is_set():
            now = obs_clock.wall()        # anchored: step-free wall stamps
            try:
                self._sample(now)
            except Exception:  # noqa: BLE001 — a failing sampler must not
                pass           # kill the profiled run (paper P.2)
            self._terminate.wait(self._sample_interval)
        self._post_process()

    def start(self, config: Dict):
        self._thread = threading.Thread(target=self.run, args=(config,),
                                        daemon=True, name=f"watcher-{self.name}")
        self._thread.start()

    def stop(self):
        self._terminate.set()
        if self._thread:
            self._thread.join(timeout=5.0)


def _read_proc(path: str) -> str:
    with open(path) as f:
        return f.read()


class CPUWatcher(WatcherBase):
    """utime/stime from /proc/<pid>/stat (perf-stat stand-in: cycle counters
    need perf permissions; CPU-seconds × calibrated flop/s gives the same
    consumption estimate at our fidelity target)."""

    name = "cpu"

    def _pre_process(self, config):
        self._hz = os.sysconf("SC_CLK_TCK")
        self._t0 = obs_clock.now()

    def _sample(self, now: float):
        parts = _read_proc(f"/proc/{self.pid}/stat").rsplit(")", 1)[1].split()
        utime, stime = int(parts[11]), int(parts[12])
        self.samples.append({"t": now, "cpu_s": (utime + stime) / self._hz})

    def _post_process(self):
        self.result["wall_s"] = obs_clock.now() - self._t0
        if self.samples:
            self.result["cpu_s"] = self.samples[-1]["cpu_s"]
            self.result["cpu_series"] = self.samples


class MemWatcher(WatcherBase):
    """VmRSS / VmHWM from /proc/<pid>/status."""

    name = "mem"

    def _sample(self, now: float):
        rss = peak = 0
        for line in _read_proc(f"/proc/{self.pid}/status").splitlines():
            if line.startswith("VmRSS:"):
                rss = int(line.split()[1]) * 1024
            elif line.startswith("VmHWM:"):
                peak = int(line.split()[1]) * 1024
        # Some kernels/containers omit VmHWM; the max sampled RSS is the
        # best observable peak there.
        self.samples.append({"t": now, "rss": rss, "peak": peak or rss})

    def _post_process(self):
        if self.samples:
            self.result["peak_rss"] = max(s["peak"] for s in self.samples)
            self.result["mem_series"] = self.samples


class IOWatcher(WatcherBase):
    """read_bytes / write_bytes from /proc/<pid>/io."""

    name = "io"

    def _sample(self, now: float):
        rb = wb = 0
        try:
            for line in _read_proc(f"/proc/{self.pid}/io").splitlines():
                if line.startswith("read_bytes:"):
                    rb = int(line.split()[1])
                elif line.startswith("write_bytes:"):
                    wb = int(line.split()[1])
        except PermissionError:
            return
        self.samples.append({"t": now, "read": rb, "write": wb})

    def _post_process(self):
        if self.samples:
            self.result["read_bytes"] = self.samples[-1]["read"] - \
                self.samples[0]["read"]
            self.result["write_bytes"] = self.samples[-1]["write"] - \
                self.samples[0]["write"]
            self.result["io_series"] = self.samples


class RuntimeProfiler:
    """Drives a set of watchers around a callable (the paper's profile())."""

    def __init__(self, sample_rate: float = DEFAULT_SAMPLE_RATE,
                 watchers=None):
        self.sample_rate = sample_rate
        self.watcher_classes = watchers or [CPUWatcher, MemWatcher, IOWatcher]

    def profile_callable(self, fn, *, command: str, tags=None,
                         flops_per_cpu_s: Optional[float] = None,
                         sysinfo=None) -> SynapseProfile:
        ws = {c.name: c() for c in self.watcher_classes}
        cfg = {"sample_rate": self.sample_rate}
        for w in ws.values():
            w.start(cfg)
        t0 = obs_clock.now()
        fn()
        wall = obs_clock.now() - t0
        for w in ws.values():
            w.stop()
        for w in ws.values():
            w._finalize(ws)
        return self._assemble(ws, wall, command, tags or {},
                              flops_per_cpu_s, sysinfo)

    def _assemble(self, ws, wall, command, tags, flops_per_cpu_s, sysinfo):
        """Combine unsynchronized per-watcher time series into uniform
        wall-clock samples (paper: postprocessing merges series)."""
        cpu = ws.get("cpu").samples if "cpu" in ws else []
        mem = ws.get("mem").samples if "mem" in ws else []
        io = ws.get("io").samples if "io" in ws else []
        n = max(len(cpu), len(mem), len(io), 1)
        t_start = min([s["t"] for s in (cpu + mem + io)] or [0.0])
        dt = wall / n
        samples = []
        prev_cpu = prev_r = prev_w = 0.0
        for i in range(n):
            r = ResourceVector()
            if i < len(cpu):
                d_cpu = cpu[i]["cpu_s"] - prev_cpu
                prev_cpu = cpu[i]["cpu_s"]
                if flops_per_cpu_s:
                    r.flops = max(d_cpu, 0.0) * flops_per_cpu_s
            if i < len(mem):
                r.host_mem_bytes = mem[i]["rss"]
                r.peak_mem_bytes = mem[i]["peak"]
            if i < len(io):
                r.storage_read_bytes = max(io[i]["read"] - prev_r, 0.0)
                r.storage_write_bytes = max(io[i]["write"] - prev_w, 0.0)
                prev_r, prev_w = io[i]["read"], io[i]["write"]
            samples.append(Sample(index=i, resources=r, duration_s=dt,
                                  label=f"t{i}"))
        prof = SynapseProfile(command=command, tags=tags, samples=samples,
                              sysinfo=sysinfo or host_sysinfo())
        prof.meta["wall_s"] = wall
        prof.meta["watcher_results"] = {
            k: {kk: vv for kk, vv in w.result.items()
                if not kk.endswith("_series")}
            for k, w in ws.items()}
        return prof


def host_sysinfo() -> Dict[str, Any]:
    info = {"cores": os.cpu_count()}
    try:
        with open("/proc/meminfo") as f:
            for line in f:
                if line.startswith("MemTotal:"):
                    info["mem_total"] = int(line.split()[1]) * 1024
                    break
    except OSError:
        pass
    try:
        with open("/proc/cpuinfo") as f:
            for line in f:
                if line.startswith("model name"):
                    info["cpu"] = line.split(":", 1)[1].strip()
                    break
    except OSError:
        pass
    return info
