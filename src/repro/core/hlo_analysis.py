"""Trip-count-aware cost model over optimized (post-SPMD) HLO text.

This is the *static watcher* of the Synapse adaptation: it treats the
compiled executable as a black box and derives per-chip resource consumption
from its HLO — FLOPs, HBM bytes and collective (ICI) wire bytes by kind.

Why not ``compiled.cost_analysis()``?  XLA's HloCostAnalysis visits a
``while`` body ONCE, so anything under ``lax.scan`` (our layer stacks, flash
KV loops, loss chunking) is undercounted by the trip count (verified
empirically; see EXPERIMENTS.md §Dry-run).  This walker parses the module
into computations, recurses through fusions/whiles/conditionals, multiplies
while bodies by their parsed trip counts, and accounts:

  * flops       — dot (2·M·N·K via operand-shape lookup), elementwise,
                  reductions, transcendentals
  * hbm_bytes   — operand + result bytes of top-level (unfused) instructions;
                  fusions count only their boundary operands/results
  * collectives — wire bytes per chip per kind, ring-model:
        all-reduce       2·size·(n-1)/n
        all-gather       size_out·(n-1)/n
        reduce-scatter   size_out·(n-1)          (input = out·n)
        all-to-all       size·(n-1)/n
        collective-permute  size
    attributed to a mesh axis by replica-group stride.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^=]*?\)|\S+)\s+([\w\-]+)\(")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "and", "or", "xor", "not", "negate", "abs", "sign", "compare", "select",
    "clamp", "remainder", "atan2",
}
TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "logistic",
                  "sine", "cosine", "expm1", "log1p", "cbrt", "erf"}
ZERO_COST = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "reshape", "copy", "transpose", "broadcast", "iota", "convert", "slice",
    "dynamic-slice", "dynamic-update-slice", "concatenate", "pad", "reverse",
    "gather", "scatter", "reduce", "reduce-window", "rng", "rng-bit-generator",
    "map", "sort", "after-all", "custom-call", "copy-start", "copy-done",
    "partition-id", "replica-id", "optimization-barrier", "domain",
    "get-dimension-size", "send", "recv", "send-done", "recv-done", "infeed",
    "outfeed", "dot", "convolution", "fusion", "while", "conditional", "call",
    "cholesky", "triangular-solve",
}  # ops handled specially or counted as data movement only


def shape_bytes(shape_str: str) -> float:
    """'f32[512,1024]{1,0}' or '(f32[2], s32[])' -> bytes."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += DTYPE_BYTES[dt] * n
    return total


def shape_numel(shape_str: str) -> float:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0.0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return float(n)


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclass
class CollectiveOp:
    kind: str
    wire_bytes: float            # per chip, per execution
    group_size: int
    stride: int                  # replica-id stride within a group
    count: float = 1.0           # executions (after trip-count multiply)
    shape: str = ""

    @property
    def total_bytes(self) -> float:
        return self.wire_bytes * self.count


@dataclass
class HloCost:
    flops: float = 0.0
    transcendentals: float = 0.0
    hbm_bytes: float = 0.0       # upper bound: all unfused op boundaries
    dot_bytes: float = 0.0       # operand+result bytes of dot/conv only
    collectives: List[CollectiveOp] = field(default_factory=list)
    op_flops: Dict[str, float] = field(default_factory=dict)   # by metadata op

    def scaled(self, k: float) -> "HloCost":
        return HloCost(
            flops=self.flops * k, transcendentals=self.transcendentals * k,
            hbm_bytes=self.hbm_bytes * k, dot_bytes=self.dot_bytes * k,
            collectives=[CollectiveOp(c.kind, c.wire_bytes, c.group_size,
                                      c.stride, c.count * k, c.shape)
                         for c in self.collectives],
            op_flops={n: v * k for n, v in self.op_flops.items()})

    def add(self, other: "HloCost") -> "HloCost":
        of = dict(self.op_flops)
        for n, v in other.op_flops.items():
            of[n] = of.get(n, 0.0) + v
        return HloCost(
            flops=self.flops + other.flops,
            transcendentals=self.transcendentals + other.transcendentals,
            hbm_bytes=self.hbm_bytes + other.hbm_bytes,
            dot_bytes=self.dot_bytes + other.dot_bytes,
            collectives=self.collectives + other.collectives,
            op_flops=of)

    def collective_bytes(self) -> Dict[str, float]:
        out: Dict[str, float] = defaultdict(float)
        for c in self.collectives:
            out[c.kind] += c.total_bytes
        return dict(out)

    @property
    def collective_total(self) -> float:
        return sum(c.total_bytes for c in self.collectives)


# ---------------------------------------------------------------------------
# Module parsing
# ---------------------------------------------------------------------------

@dataclass
class Instruction:
    name: str
    shape: str
    opcode: str
    line: str
    operands: List[str]


@dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    by_name: Dict[str, Instruction]


_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*)?\{\s*$")


def parse_module(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur_name, cur_instrs = None, []
    for raw in text.splitlines():
        line = re.sub(r"/\*.*?\*/", "", raw.rstrip())
        if cur_name is None:
            clean = line.strip()
            m = _COMP_START.match(clean)
            if m and clean.endswith("{") and " -> " in clean and \
                    " = " not in clean:
                cur_name = m.group(1)
                cur_instrs = []
                if clean.startswith("ENTRY"):
                    entry = cur_name
            continue
        if line.strip() == "}":
            comps[cur_name] = _finish(cur_name, cur_instrs)
            cur_name = None
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            name, shape, opcode = mi.groups()
            # operand names: between the opcode '(' and the next '),' boundary
            tail = line[mi.end():]
            depth = 1
            args = []
            buf = ""
            for ch in tail:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        args.append(buf)
                        break
                if depth >= 1:
                    buf += ch
            ops = _OPERANDS_RE.findall(args[0]) if args else []
            cur_instrs.append(Instruction(name, shape, opcode, line, ops))
    return comps, entry


def _finish(name, instrs):
    return Computation(name, instrs, {i.name: i for i in instrs})


# ---------------------------------------------------------------------------
# Per-instruction costing
# ---------------------------------------------------------------------------

_ATTR_RE = {
    "calls": re.compile(r"calls=%?([\w.\-]+)"),
    "body": re.compile(r"body=%?([\w.\-]+)"),
    "condition": re.compile(r"condition=%?([\w.\-]+)"),
    "branches": re.compile(r"branch_computations=\{([^}]*)\}"),
    "true": re.compile(r"true_computation=%?([\w.\-]+)"),
    "false": re.compile(r"false_computation=%?([\w.\-]+)"),
    "groups_explicit": re.compile(r"replica_groups=\{\{([\d,]+)\}"),
    "groups_iota": re.compile(
        r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?"),
    "contracting": re.compile(r"lhs_contracting_dims=\{([\d,]*)\}"),
    "metadata_op": re.compile(r'op_name="([^"]*)"'),
}


def _dot_flops(instr: Instruction, comp: Computation) -> float:
    out_numel = shape_numel(instr.shape)
    k = 1.0
    mc = _ATTR_RE["contracting"].search(instr.line)
    if mc and instr.operands:
        lhs = comp.by_name.get(instr.operands[0])
        if lhs is not None:
            dims = _shape_dims(lhs.shape)
            for di in (mc.group(1).split(",") if mc.group(1) else []):
                i = int(di)
                if i < len(dims):
                    k *= dims[i]
    return 2.0 * out_numel * k


def _conv_flops(instr: Instruction, comp: Computation) -> float:
    # flops = 2 * out_numel * (kernel spatial * in_channels)
    out_numel = shape_numel(instr.shape)
    if len(instr.operands) >= 2:
        rhs = comp.by_name.get(instr.operands[1])
        if rhs is not None:
            dims = _shape_dims(rhs.shape)
            if dims:
                k = 1
                for d in dims[:-1]:       # all but output-feature dim (approx)
                    k *= d
                return 2.0 * out_numel * k
    return 2.0 * out_numel


_TRIP_RE = re.compile(r'"known_trip_count":\s*\{\s*"n":\s*"(\d+)"')


def _trip_count_from_line(line: str) -> Optional[float]:
    """XLA annotates `backend_config={"known_trip_count":{"n":"48"}}`."""
    m = _TRIP_RE.search(line)
    return float(m.group(1)) if m else None


def _trip_count(cond: Computation) -> float:
    """Fallback: parse the condition computation.  The compare may be fused
    (`ROOT %wrapped_compare = fusion(%gte, %constant.N)`), so resolve constant
    operands of the root instruction."""
    consts: Dict[str, float] = {}
    for i in cond.instructions:
        m = re.search(r"constant\((-?\d+)\)", i.line)
        if m and i.opcode == "constant":
            consts[i.name] = float(m.group(1))
    root = None
    for i in cond.instructions:
        if i.line.lstrip().startswith("ROOT"):
            root = i
    for i in ([root] if root else []) + list(reversed(cond.instructions)):
        if i is None or i.opcode not in ("compare", "fusion"):
            continue
        vals = [consts[op] for op in i.operands if op in consts]
        if vals:
            return max(max(vals), 1.0)
    return 1.0


def _collective_wire_bytes(kind: str, out_bytes: float, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * out_bytes * (n - 1) / n
    if kind == "all-gather":
        return out_bytes * (n - 1) / n
    if kind == "reduce-scatter":
        return out_bytes * (n - 1)
    if kind == "all-to-all":
        return out_bytes * (n - 1) / n
    if kind == "collective-permute":
        return out_bytes
    return out_bytes


def _parse_groups(line: str) -> Tuple[int, int]:
    """-> (group_size, stride). stride 1 == innermost mesh axis."""
    m = _ATTR_RE["groups_explicit"].search(line)
    if m:
        ids = [int(x) for x in m.group(1).split(",")]
        size = len(ids)
        stride = (ids[1] - ids[0]) if size > 1 else 1
        return size, stride
    m = _ATTR_RE["groups_iota"].search(line)
    if m:
        n_groups, size = int(m.group(1)), int(m.group(2))
        reshape = [int(x) for x in m.group(3).split(",")]
        perm = [int(x) for x in m.group(4).split(",")] if m.group(4) else \
            list(range(len(reshape)))
        # stride of the last (fastest-varying) permuted axis:
        # device ids laid out in `reshape` row-major; groups take the
        # transposed-last dim.  stride = product of reshape dims after the
        # permuted last axis.
        last_axis = perm[-1]
        stride = 1
        for d in reshape[last_axis + 1:]:
            stride *= d
        return size, stride
    return 1, 1


COLLECTIVE_BASES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                    "collective-permute")


def _collective_kind(opcode: str) -> Optional[str]:
    for base in COLLECTIVE_BASES:
        if opcode == base or opcode == base + "-start":
            return base
    return None


class ModuleCost:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self._memo: Dict[str, HloCost] = {}

    def cost(self, comp_name: Optional[str] = None) -> HloCost:
        name = comp_name or self.entry
        if name is None:
            return HloCost()
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        if comp is None:
            return HloCost()
        total = HloCost()
        for instr in comp.instructions:
            total = total.add(self._instr_cost(instr, comp))
        self._memo[name] = total
        return total

    # -- helpers ------------------------------------------------------------

    def _operand_bytes(self, instr: Instruction, comp: Computation) -> float:
        b = 0.0
        for op in instr.operands:
            src = comp.by_name.get(op)
            if src is not None:
                b += shape_bytes(src.shape)
        return b

    def _instr_cost(self, instr: Instruction, comp: Computation) -> HloCost:
        op = instr.opcode
        kind = _collective_kind(op)
        if kind is not None:
            out_b = shape_bytes(instr.shape)
            size, stride = _parse_groups(instr.line)
            wire = _collective_wire_bytes(kind, out_b, size)
            return HloCost(hbm_bytes=0.0, collectives=[
                CollectiveOp(kind, wire, size, stride, 1.0, instr.shape)])
        if op.endswith("-done") or op in ("after-all",):
            return HloCost()

        if op == "fusion":
            m = _ATTR_RE["calls"].search(instr.line)
            inner = self.cost(m.group(1)) if m else HloCost()
            io_bytes = shape_bytes(instr.shape) + self._operand_bytes(instr, comp)
            return HloCost(flops=inner.flops,
                           transcendentals=inner.transcendentals,
                           hbm_bytes=io_bytes,
                           dot_bytes=inner.dot_bytes,
                           collectives=inner.collectives,
                           op_flops=inner.op_flops)
        if op == "while":
            body = _ATTR_RE["body"].search(instr.line)
            cond = _ATTR_RE["condition"].search(instr.line)
            trips = _trip_count_from_line(instr.line)
            if trips is None:
                trips = _trip_count(self.comps[cond.group(1)]) if cond and \
                    cond.group(1) in self.comps else 1.0
            inner = self.cost(body.group(1)) if body else HloCost()
            return inner.scaled(trips)
        if op == "conditional":
            branches = []
            m = _ATTR_RE["branches"].search(instr.line)
            if m:
                branches = _OPERANDS_RE.findall(m.group(1))
            else:
                for key in ("true", "false"):
                    mm = _ATTR_RE[key].search(instr.line)
                    if mm:
                        branches.append(mm.group(1))
            if not branches:
                return HloCost()
            costs = [self.cost(b) for b in branches]
            return max(costs, key=lambda c: c.flops + c.hbm_bytes)
        if op == "call":
            m = re.search(r"to_apply=%?([\w.\-]+)", instr.line)
            return self.cost(m.group(1)) if m else HloCost()

        # leaf instructions ---------------------------------------------------
        cost = HloCost()
        out_numel = shape_numel(instr.shape)
        if op == "dot":
            cost.flops = _dot_flops(instr, comp)
            cost.dot_bytes = shape_bytes(instr.shape) + \
                self._operand_bytes(instr, comp)
        elif op == "convolution":
            cost.flops = _conv_flops(instr, comp)
            cost.dot_bytes = shape_bytes(instr.shape) + \
                self._operand_bytes(instr, comp)
        elif op in ELEMENTWISE:
            cost.flops = out_numel
        elif op in TRANSCENDENTAL:
            cost.flops = out_numel
            cost.transcendentals = out_numel
        elif op == "reduce" or op == "reduce-window":
            in_b = 0.0
            if instr.operands:
                src = comp.by_name.get(instr.operands[0])
                if src is not None:
                    in_b = shape_numel(src.shape)
            cost.flops = in_b
        elif op in ("exponential-minus-one",):
            cost.flops = out_numel
            cost.transcendentals = out_numel

        if op not in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast"):
            cost.hbm_bytes = shape_bytes(instr.shape) + \
                self._operand_bytes(instr, comp)
        if cost.flops:
            mm = _ATTR_RE["metadata_op"].search(instr.line)
            if mm:
                cost.op_flops = {_short_op(mm.group(1)): cost.flops}
        return cost


def _short_op(op_name: str) -> str:
    # "jit(train_step)/jvp(...)/transformer/attn/dot_general" -> trailing parts
    parts = op_name.split("/")
    return "/".join(parts[-2:]) if len(parts) > 1 else op_name


def analyze_hlo(text: str) -> HloCost:
    return ModuleCost(text).cost()


def sample_breakdown(text: str, max_samples: int = 4096):
    """Ordered (label, HloCost) samples from the entry computation.

    The execution order of the entry computation is the profiler's clock:
    straight-line segments accumulate into one sample; each ``while`` (a
    layer scan, flash KV loop, loss chunk loop) emits trip-count samples of
    its body cost.  This is the static analog of the paper's time-sampled
    profiling — granularity follows program structure instead of wall time.
    Consecutive identical whiles collapse into (label, cost, count) runs to
    bound sample counts for very long loops.
    """
    mc = ModuleCost(text)
    if mc.entry is None:
        return []
    comp = mc.comps[mc.entry]
    out = []          # list of (label, HloCost, count)
    cur = HloCost()

    def flush(label):
        nonlocal cur
        if cur.flops or cur.hbm_bytes or cur.collectives:
            out.append((label, cur, 1))
        cur = HloCost()

    for instr in comp.instructions:
        if instr.opcode == "while":
            flush("glue")
            body = _ATTR_RE["body"].search(instr.line)
            cond = _ATTR_RE["condition"].search(instr.line)
            trips = _trip_count_from_line(instr.line)
            if trips is None:
                trips = _trip_count(mc.comps[cond.group(1)]) if cond and \
                    cond.group(1) in mc.comps else 1.0
            inner = mc.cost(body.group(1)) if body else HloCost()
            n = int(max(trips, 1))
            if n > max_samples:
                inner = inner.scaled(n / max_samples)
                n = max_samples
            out.append((f"scan:{instr.name}", inner, n))
        else:
            cur = cur.add(mc._instr_cost(instr, comp))
    flush("glue")
    return out


def attribute_axes(cost: HloCost, mesh_shape: Dict[str, int]) -> Dict[str, float]:
    """Map collective wire bytes to mesh axes by replica-group stride.

    mesh axes are row-major: last axis has stride 1 in device ids.
    """
    axes = list(mesh_shape.items())                     # [(name, size), ...]
    strides = {}
    s = 1
    for name, size in reversed(axes):
        strides[name] = s
        s *= size
    out: Dict[str, float] = defaultdict(float)
    for c in cost.collectives:
        matched = None
        for name, size in axes:
            if c.stride == strides[name] and c.group_size <= size:
                matched = name
                break
        if matched is None:
            # groups spanning multiple axes (e.g. ('data','model')) — match by
            # total span
            for name, size in axes:
                if c.group_size == size:
                    matched = name
                    break
        out[matched or "unknown"] += c.total_bytes
    return dict(out)
