"""Live traffic emulation service: open-loop load against a warm fleet.

The batch layers below this one (``repro.fleet``) answer "how fast can
the pool drain these profiles?".  This package answers the serving
question instead: "with requests arriving on *their* schedule, what
latency distribution does the emulated system deliver — and what does a
mid-storm fault do to the tail?".

Four pieces, composable from Python or driven over HTTP:

* :mod:`repro.service.arrivals` — seeded deterministic open-loop arrival
  processes (Poisson, constant-rate, diurnal ramp, recorded trace);
  bit-reproducible via the repo's sha256-per-scope seeding discipline.
* :mod:`repro.service.standing` — :class:`StandingFleet`, a persistent
  serve loop over ``FleetBase.stream``'s open-loop admission mode: a
  warm process/remote pool that accepts bundles at arrival time and
  tracks per-request enqueue/dispatch/completion timing.
* :mod:`repro.service.slo` — streaming SLO accounting: bounded quantile
  sketch (p50/p99/p999 in a few hundred ints), goodput vs offered load,
  per-window violations, and chaos attribution (fault MTTR windows
  joined against the latency timeline).
* :mod:`repro.service.load` / :mod:`repro.service.http` — ``run_load``
  drives one run end to end; ``python -m repro.service`` serves it as
  ``/run?scenario=...`` HTTP endpoints returning SLO reports as JSON.

The one-liner::

    from repro.service import PoissonArrivals, SLO, run_load
    report = run_load(em, PoissonArrivals(rate_hz=50, n_requests=500,
                                          scenario="serving_traffic"),
                      config=FleetConfig.process(max_workers=4,
                                                 chaos=ChaosPolicy(
                                                     kill_every=100)),
                      slo=SLO(target_ms=200, percentile=0.99))
    print(report.slo["p999"], report.slo["windows"])
"""
from repro.service.arrivals import (ARRIVAL_KINDS, Arrival,  # noqa: F401
                                    ArrivalProcess, ConstantArrivals,
                                    DiurnalArrivals, PoissonArrivals,
                                    TraceArrivals, arrival_process)
from repro.service.load import LoadReport, run_load  # noqa: F401
from repro.service.slo import SLO, LatencySketch, SLOEngine  # noqa: F401
from repro.service.standing import (RequestRecord, ServeResult,  # noqa: F401
                                    StandingFleet)

__all__ = [
    "ARRIVAL_KINDS", "Arrival", "ArrivalProcess", "ConstantArrivals",
    "DiurnalArrivals", "PoissonArrivals", "TraceArrivals",
    "arrival_process", "LoadReport", "run_load", "SLO", "LatencySketch",
    "SLOEngine", "RequestRecord", "ServeResult", "StandingFleet",
]
