"""Seeded open-loop arrival processes for the live traffic service.

A closed-loop replayer dispatches as fast as the fleet drains — its
"load" is whatever the pool can absorb, and queueing delay is invisible
by construction.  Open-loop load is the opposite contract: requests
arrive on a schedule that does not care how busy the fleet is, so when
the pool falls behind the queue grows and *latency* (not throughput) is
what the run measures.  Everything here emits that schedule.

An :class:`ArrivalProcess` is a frozen, picklable description of a load
shape — Poisson, constant-rate, diurnal ramp, or a recorded trace — that
iterates deterministically into timestamped :class:`Arrival` requests.
Randomized processes draw from a ``random.Random`` seeded with the same
sha256-per-scope discipline as ``ChaosPolicy``
(:func:`repro.fleet.chaos.derive_seed`), and every candidate ordinal
draws the same number of variates whether or not it is accepted, so the
arrival timeline is bit-identical run-to-run and independent of the
fleet that serves it.  A chaos-under-load run is therefore reproducible
end to end from two integers: the arrival seed and the chaos seed.

Iterating a process never mutates it: ``list(p) == list(p)`` always.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from random import Random
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

from repro.fleet.chaos import derive_seed

#: params travel as a sorted ``(key, value)`` tuple so Arrival stays
#: hashable/comparable and two logically-equal requests compare equal
ParamItems = Tuple[Tuple[str, object], ...]


def _freeze_params(params) -> ParamItems:
    if params is None:
        return ()
    if isinstance(params, dict):
        return tuple(sorted(params.items()))
    return tuple((str(k), v) for k, v in params)


@dataclass(frozen=True)
class Arrival:
    """One scheduled request: fire ``scenario(**params)`` at ``t`` seconds
    after the run starts.  ``t`` is run-relative virtual time — the serve
    layer maps it onto the wall clock (optionally time-scaled)."""

    t: float
    scenario: str
    params: ParamItems = ()

    def __post_init__(self):
        object.__setattr__(self, "params", _freeze_params(self.params))
        if self.t < 0:
            raise ValueError(f"arrival time must be >= 0, got {self.t}")

    @property
    def kwargs(self) -> Dict[str, object]:
        """``params`` in the form ``repro.scenarios.generate`` takes."""
        return dict(self.params)


@dataclass(frozen=True)
class ArrivalProcess:
    """Base contract: a bounded, deterministic iterable of ``Arrival``s.

    Every process must be bounded by ``n_requests`` and/or ``duration_s``
    (an unbounded load run is a typo, not a workload).  Subclasses
    implement ``_times`` — a lazy nondecreasing time stream — and declare
    a ``kind`` tag that scopes their RNG stream, so two processes in one
    run (say a Poisson floor plus a diurnal ramp) never share variates
    even under the same seed.
    """

    scenario: str = "serving_traffic"
    params: ParamItems = ()
    seed: int = 0
    n_requests: Optional[int] = None
    duration_s: Optional[float] = None

    kind = "base"

    def __post_init__(self):
        object.__setattr__(self, "params", _freeze_params(self.params))
        if self.n_requests is None and self.duration_s is None:
            raise ValueError(
                f"{type(self).__name__} must be bounded: pass n_requests=N "
                "and/or duration_s=T (open-loop load with no bound never "
                "stops arriving)")
        if self.n_requests is not None and self.n_requests < 0:
            raise ValueError("n_requests must be >= 0")
        if self.duration_s is not None and self.duration_s < 0:
            raise ValueError("duration_s must be >= 0")

    # -- subclass surface ---------------------------------------------------

    def _times(self, rng: Random) -> Iterator[float]:
        raise NotImplementedError

    def _rng(self) -> Random:
        """Fresh per-iteration RNG: the stream is a pure function of
        ``(seed, kind, scenario)``, so iterating twice replays exactly."""
        return Random(derive_seed(self.seed,
                                  f"arrivals:{self.kind}:{self.scenario}"))

    # -- iteration ----------------------------------------------------------

    def __iter__(self) -> Iterator[Arrival]:
        n = 0
        for t in self._times(self._rng()):
            if self.n_requests is not None and n >= self.n_requests:
                return
            if self.duration_s is not None and t > self.duration_s:
                return
            yield Arrival(t=t, scenario=self.scenario, params=self.params)
            n += 1

    def trace(self) -> "TraceArrivals":
        """Materialize into a replayable trace (the recorded-log form)."""
        return TraceArrivals(log=tuple(self), n_requests=self.n_requests,
                             duration_s=self.duration_s)


@dataclass(frozen=True)
class ConstantArrivals(ArrivalProcess):
    """Metronome load: request ``i`` arrives at exactly ``i / rate_hz``.
    The sharpest tool for capacity knees — offered load is exact, so
    goodput shortfall is all queueing."""

    rate_hz: float = 10.0

    kind = "constant"

    def __post_init__(self):
        super().__post_init__()
        if self.rate_hz <= 0:
            raise ValueError("rate_hz must be > 0")

    def _times(self, rng: Random) -> Iterator[float]:
        i = 0
        while True:
            yield i / self.rate_hz   # i/rate, never t += gap: no fp drift
            i += 1


@dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Memoryless load at ``rate_hz``: i.i.d. exponential gaps.  The
    canonical open-loop model — bursts and lulls arrive for free, which
    is exactly what makes tail latency honest."""

    rate_hz: float = 10.0

    kind = "poisson"

    def __post_init__(self):
        super().__post_init__()
        if self.rate_hz <= 0:
            raise ValueError("rate_hz must be > 0")

    def _times(self, rng: Random) -> Iterator[float]:
        t = 0.0
        while True:
            t += -math.log(1.0 - rng.random()) / self.rate_hz
            yield t


@dataclass(frozen=True)
class DiurnalArrivals(ArrivalProcess):
    """Sinusoidal ramp between ``base_hz`` and ``peak_hz`` over
    ``period_s`` — the day/night shape that makes autoscalers earn their
    keep.  Implemented by thinning a ``peak_hz`` Poisson stream; each
    candidate always draws two variates (gap, accept) so the stream stays
    ordinal-aligned no matter which candidates survive — the same
    discipline ``ChaosPolicy`` uses for its fault streams."""

    base_hz: float = 5.0
    peak_hz: float = 20.0
    period_s: float = 60.0

    kind = "diurnal"

    def __post_init__(self):
        super().__post_init__()
        if not 0 < self.base_hz <= self.peak_hz:
            raise ValueError("need 0 < base_hz <= peak_hz")
        if self.period_s <= 0:
            raise ValueError("period_s must be > 0")

    def rate_at(self, t: float) -> float:
        """Instantaneous target rate: ``base`` at t=0, ``peak`` mid-period."""
        swing = 0.5 * (1.0 - math.cos(2.0 * math.pi * t / self.period_s))
        return self.base_hz + (self.peak_hz - self.base_hz) * swing

    def _times(self, rng: Random) -> Iterator[float]:
        t = 0.0
        while True:
            t += -math.log(1.0 - rng.random()) / self.peak_hz
            u = rng.random()                      # drawn even if rejected
            if u * self.peak_hz <= self.rate_at(t):
                yield t


@dataclass(frozen=True)
class TraceArrivals(ArrivalProcess):
    """Replay a recorded ``(t, scenario, params)`` arrival log verbatim —
    the bridge from a captured production trace (or a previous run's
    ``ArrivalProcess.trace()``) back into the load generator.  Bounds
    still apply, so a long trace can be replayed truncated."""

    log: Tuple[Arrival, ...] = ()

    kind = "trace"

    def __post_init__(self):
        # a trace is inherently bounded; exempt it from the bound check
        if self.n_requests is None and self.duration_s is None:
            object.__setattr__(self, "n_requests", len(self.log))
        super().__post_init__()
        object.__setattr__(self, "log", tuple(
            a if isinstance(a, Arrival) else Arrival(*a) for a in self.log))
        for prev, cur in zip(self.log, self.log[1:]):
            if cur.t < prev.t:
                raise ValueError(
                    f"trace times must be nondecreasing; got {cur.t} after "
                    f"{prev.t}")

    @classmethod
    def from_log(cls, rows: Iterable) -> "TraceArrivals":
        """Build from plain rows — ``(t, scenario, params_dict)`` triples
        (the JSON-friendly recorded form) or ``Arrival`` instances."""
        log = tuple(a if isinstance(a, Arrival)
                    else Arrival(t=a[0], scenario=a[1],
                                 params=a[2] if len(a) > 2 else ())
                    for a in rows)
        return cls(log=log)

    def to_log(self) -> List[Tuple[float, str, Dict]]:
        """The JSON-friendly recorded form (round-trips via ``from_log``)."""
        return [(a.t, a.scenario, a.kwargs) for a in self.log]

    def _times(self, rng: Random) -> Iterator[float]:  # pragma: no cover
        raise AssertionError("TraceArrivals overrides __iter__")

    def __iter__(self) -> Iterator[Arrival]:
        n = 0
        for a in self.log:
            if self.n_requests is not None and n >= self.n_requests:
                return
            if self.duration_s is not None and a.t > self.duration_s:
                return
            yield a
            n += 1


#: HTTP/CLI-facing registry: ``process=`` query parameter values
ARRIVAL_KINDS = {
    "constant": ConstantArrivals,
    "poisson": PoissonArrivals,
    "diurnal": DiurnalArrivals,
}


def arrival_process(kind: str, scenario: str, *, seed: int = 0,
                    n_requests: Optional[int] = None,
                    duration_s: Optional[float] = None,
                    params: Optional[Dict] = None,
                    **knobs) -> ArrivalProcess:
    """Factory keyed by ``kind`` — the string surface the HTTP endpoint
    and CLI use.  ``knobs`` are the process's own shape parameters
    (``rate_hz``, ``base_hz``/``peak_hz``/``period_s``)."""
    try:
        cls = ARRIVAL_KINDS[kind]
    except KeyError:
        raise ValueError(
            f"unknown arrival process {kind!r}; valid kinds: "
            + ", ".join(sorted(ARRIVAL_KINDS))) from None
    return cls(scenario=scenario, params=_freeze_params(params), seed=seed,
               n_requests=n_requests, duration_s=duration_s, **knobs)
