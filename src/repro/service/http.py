"""The service's HTTP surface: start/query/stop load runs over stdlib
``http.server``, SLO reports as JSON.

In the spirit of the synthetic-agents harness's ``/run?scenario=fanout&
fanout=100`` endpoints: every knob is a query parameter, so a perf PR
gets a traffic-shaped benchmark from a one-line ``curl`` instead of a
fixed profile list.

Routes (all GET, all JSON):

* ``/healthz`` — liveness probe.
* ``/scenarios`` — the registered scenario generators and their params.
* ``/run?scenario=serving_traffic&process=poisson&rate_hz=20&n=100`` —
  start a load run; returns ``{"id": ...}`` immediately, or the full
  report when ``wait=1``.  Scenario params are passed ``p_``-prefixed
  (``p_fanout=100``); chaos via ``kill_every=``/``chaos_seed=``; pool
  shape via ``workers=``/``autoscale=``/``min_workers=``; the objective
  via ``slo_ms=``/``slo_pct=``.
* ``/status?id=N`` — run state; includes the SLO report once finished.
* ``/stop?id=N`` — stop admitting arrivals; the queue still drains and
  the truncated run reports normally.
* ``/runs`` — all runs this service has seen (schema-tagged
  ``FleetReport.to_json`` payloads, not ad-hoc dicts).
* ``/trace?id=N`` — the finished run's merged flight-recorder timeline
  as Chrome trace-event JSON (load it at https://ui.perfetto.dev); a
  finished ``/run``/``/status`` response links here.
* ``/metrics`` — Prometheus text exposition (the one non-JSON route):
  service-level run/request counters plus the request-latency
  histogram folded from every finished run's SLO sketch.

Run it: ``python -m repro.service [--port 8787]`` (or
``python -m repro.scenarios serve``).
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional
from urllib.parse import parse_qsl, urlparse

from repro.core.emulator import Emulator
from repro.fleet.chaos import ChaosPolicy
from repro.fleet.config import FleetConfig
from repro.obs.metrics import MetricsRegistry
from repro.obs.recorder import Event
from repro.obs.trace import slo_windows_ms, to_chrome_trace
from repro.service.arrivals import ARRIVAL_KINDS, arrival_process
from repro.service.load import LoadReport, run_load
from repro.service.slo import SLO

#: one serve "session" (a run) may sit queued behind chaos recovery for
#: a while; the stream deadline is a backstop, not a feature here
_RUN_TIMEOUT_S = 24 * 3600.0


def _coerce(v: str):
    """Query-string value → int/float/bool/str, best effort."""
    low = v.lower()
    if low in ("true", "yes", "on"):
        return True
    if low in ("false", "no", "off"):
        return False
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            continue
    return v


class LoadRunHandle:
    """One load run's lifetime: a driver thread around ``run_load``."""

    def __init__(self, run_id: int, spec: Dict, thread: threading.Thread,
                 stop: threading.Event):
        self.run_id = run_id
        self.spec = spec
        self.thread = thread
        self.stop_event = stop
        self.report: Optional[LoadReport] = None
        self.error: Optional[str] = None

    @property
    def state(self) -> str:
        if self.thread.is_alive():
            return "stopping" if self.stop_event.is_set() else "running"
        return "failed" if self.error is not None else "done"

    def describe(self, full: bool = False) -> Dict:
        out = {"id": self.run_id, "state": self.state, "spec": self.spec}
        if self.error is not None:
            out["error"] = self.error
        if self.report is not None and (full or not self.thread.is_alive()):
            out["report"] = self.report.to_dict()
            out["trace"] = f"/trace?id={self.run_id}"
        return out


class LoadService:
    """Run registry + parameter parsing; the handler below is a thin
    shim over this so it is testable without sockets."""

    def __init__(self, emulator: Optional[Emulator] = None):
        self._em = emulator if emulator is not None else Emulator()
        self._runs: Dict[int, LoadRunHandle] = {}
        self._next_id = 1
        self._lock = threading.Lock()
        # the /metrics scrape body: service-level series here; per-run
        # fleet series live in each report's obs snapshot
        self.metrics = MetricsRegistry()
        self._m_runs = self.metrics.counter(
            "repro_service_runs_total", "load runs by terminal state")
        self._m_active = self.metrics.gauge(
            "repro_service_runs_active", "driver threads currently running")
        self._m_requests = self.metrics.counter(
            "repro_service_requests_total",
            "requests across finished runs, by outcome")
        self._m_latency = self.metrics.histogram(
            "repro_service_request_latency_seconds",
            "open-loop request latency across finished runs")

    # -- query parsing ------------------------------------------------------

    def _parse(self, q: Dict) -> Dict:
        scenario = str(q.get("scenario", "serving_traffic"))
        kind = str(q.get("process", "poisson"))
        if kind not in ARRIVAL_KINDS:
            raise ValueError(f"unknown arrival process {kind!r}; valid: "
                             + ", ".join(sorted(ARRIVAL_KINDS)))
        params = {k[2:]: v for k, v in q.items() if k.startswith("p_")}
        knobs = {}
        for name in ("rate_hz", "base_hz", "peak_hz", "period_s"):
            if name in q:
                knobs[name] = float(q[name])
        if "rate" in q:                      # ergonomic alias
            knobs.setdefault("rate_hz", float(q["rate"]))
        if kind != "diurnal":
            knobs.pop("base_hz", None)
            knobs.pop("peak_hz", None)
            knobs.pop("period_s", None)
        n_requests = q.get("n", q.get("n_requests"))
        duration_s = q.get("duration", q.get("duration_s"))
        if n_requests is None and duration_s is None:
            n_requests = 50
        chaos = None
        chaos_knobs = {k: int(q[k]) for k in ("kill_every", "hang_nth",
                                              "fail_nth", "delay_every",
                                              "max_faults") if k in q}
        if chaos_knobs:
            chaos = ChaosPolicy(seed=int(q.get("chaos_seed", 0)),
                                **chaos_knobs)
        workers = int(q.get("workers", 2))
        autoscale = bool(q.get("autoscale", False))
        liveness = q.get("liveness", q.get("liveness_timeout"))
        if liveness is None and chaos is not None:
            liveness = 5.0                   # chaos without liveness is deaf
        config = FleetConfig.process(
            max_workers=workers, autoscale=autoscale,
            min_workers=int(q["min_workers"]) if autoscale
            and "min_workers" in q else None,
            liveness_timeout=float(liveness) if liveness is not None
            else None,
            on_failure="skip",               # a poison request must not
            chaos=chaos,                     # take the service down
            max_respawns=int(q.get("max_respawns", max(8, workers * 4))),
            timeout=float(q.get("timeout", _RUN_TIMEOUT_S)))
        return {
            "scenario": scenario, "kind": kind, "params": params,
            "knobs": knobs, "seed": int(q.get("seed", 0)),
            "n_requests": int(n_requests) if n_requests is not None
            else None,
            "duration_s": float(duration_s) if duration_s is not None
            else None,
            "config": config,
            "slo": SLO(target_ms=float(q.get("slo_ms", 200.0)),
                       percentile=float(q.get("slo_pct", 0.99))),
            "window_s": float(q.get("window_s", 1.0)),
            "time_scale": float(q.get("time_scale", 1.0)),
        }

    # -- verbs --------------------------------------------------------------

    def start(self, q: Dict, wait: bool = False) -> Dict:
        spec = self._parse(q)
        arrivals = arrival_process(
            spec["kind"], spec["scenario"], seed=spec["seed"],
            n_requests=spec["n_requests"], duration_s=spec["duration_s"],
            params=spec["params"], **spec["knobs"])
        stop = threading.Event()
        with self._lock:
            run_id = self._next_id
            self._next_id += 1

        def drive():
            self._m_active.inc(1)
            try:
                report = run_load(
                    self._em, arrivals, config=spec["config"],
                    slo=spec["slo"], window_s=spec["window_s"],
                    time_scale=spec["time_scale"], stop=stop)
                handle.report = report
                self._m_runs.inc(state="done")
                self._m_requests.inc(report.serve.n_ok, outcome="ok")
                self._m_requests.inc(report.serve.n_skipped,
                                     outcome="skipped")
                if report.latency is not None and report.latency.count:
                    self._m_latency.absorb(report.latency)
            except BaseException as e:  # noqa: BLE001 — reported via /status
                handle.error = f"{type(e).__name__}: {e}"
                self._m_runs.inc(state="failed")
            finally:
                self._m_active.inc(-1)

        public = {k: (repr(v) if k in ("config", "slo") else v)
                  for k, v in spec.items()}
        thread = threading.Thread(target=drive, name=f"load-run-{run_id}",
                                  daemon=True)
        handle = LoadRunHandle(run_id, public, thread, stop)
        with self._lock:
            self._runs[run_id] = handle
        thread.start()
        if wait:
            thread.join()
        return handle.describe()

    def _handle(self, run_id) -> LoadRunHandle:
        try:
            return self._runs[int(run_id)]
        except (KeyError, TypeError, ValueError):
            raise KeyError(f"unknown run id {run_id!r}") from None

    def status(self, run_id) -> Dict:
        return self._handle(run_id).describe()

    def stop(self, run_id) -> Dict:
        h = self._handle(run_id)
        h.stop_event.set()
        return h.describe()

    def runs(self) -> Dict:
        with self._lock:
            return {"runs": [h.describe() for h in self._runs.values()]}

    def trace(self, run_id) -> Dict:
        """A finished run's merged event timeline as a Chrome trace-event
        object (Perfetto-loadable as-is), SLO windows as counter tracks."""
        h = self._handle(run_id)
        if h.report is None:
            raise ValueError(f"run {run_id} has no report yet "
                             f"(state {h.state!r})")
        obs = h.report.serve.obs or {}
        events = [Event.from_dict(d) for d in obs.get("events", ())]
        return to_chrome_trace(
            events, slo_windows=slo_windows_ms(h.report.slo),
            meta={"run_id": h.run_id, "spec": h.spec,
                  "dropped_events": obs.get("dropped_events", 0)})

    def shutdown(self, timeout: float = 30.0):
        """Stop every live run and wait for their driver threads."""
        with self._lock:
            handles = list(self._runs.values())
        for h in handles:
            h.stop_event.set()
        for h in handles:
            h.thread.join(timeout)

    # -- request routing (shared by the socket server and tests) ------------

    def route(self, path: str) -> Dict:
        """Dispatch one request path; returns the JSON-ready response.
        Raises KeyError (404) or ValueError (400) for bad requests."""
        parsed = urlparse(path)
        q = {k: _coerce(v) for k, v in parse_qsl(parsed.query)}
        route = parsed.path.rstrip("/") or "/"
        if route == "/healthz":
            return {"ok": True}
        if route == "/scenarios":
            from repro.scenarios import get_scenario, list_scenarios
            return {"scenarios": {
                name: {"description": get_scenario(name).description,
                       "params": get_scenario(name).defaults}
                for name in list_scenarios()},
                "processes": sorted(ARRIVAL_KINDS)}
        if route == "/run":
            return self.start(q, wait=bool(q.pop("wait", False)))
        if route == "/status":
            return self.status(q.get("id"))
        if route == "/stop":
            return self.stop(q.get("id"))
        if route == "/runs":
            return self.runs()
        if route == "/trace":
            return self.trace(q.get("id"))
        raise KeyError(f"no route {route!r}")


def make_server(host: str = "127.0.0.1", port: int = 8787,
                emulator: Optional[Emulator] = None,
                ) -> ThreadingHTTPServer:
    """Build (but don't start) the HTTP server; ``server.service`` is the
    underlying :class:`LoadService`.  Port 0 picks a free port —
    ``server.server_address`` has the real one."""
    service = LoadService(emulator)

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 — BaseHTTPRequestHandler contract
            if urlparse(self.path).path.rstrip("/") == "/metrics":
                # the one non-JSON route: Prometheus text exposition
                payload = service.metrics.render().encode()
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4; charset=utf-8")
                self.send_header("Content-Length", str(len(payload)))
                self.end_headers()
                self.wfile.write(payload)
                return
            try:
                body, code = service.route(self.path), 200
            except KeyError as e:
                body, code = {"error": str(e)}, 404
            except (ValueError, TypeError) as e:
                body, code = {"error": str(e)}, 400
            except Exception as e:  # noqa: BLE001
                body, code = {"error": f"{type(e).__name__}: {e}"}, 500
            payload = json.dumps(body, indent=1, default=str).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(payload)))
            self.end_headers()
            self.wfile.write(payload)

        def log_message(self, fmt, *args):  # quiet: we're a test target
            pass

    server = ThreadingHTTPServer((host, port), Handler)
    server.service = service
    return server


def serve(host: str = "127.0.0.1", port: int = 8787,
          emulator: Optional[Emulator] = None) -> None:
    """Blocking entrypoint: serve until interrupted."""
    server = make_server(host, port, emulator)
    h, p = server.server_address[:2]
    print(f"repro.service listening on http://{h}:{p}  "
          f"(try /healthz, /scenarios, /run?scenario=serving_traffic"
          f"&process=poisson&rate_hz=20&n=50&wait=1)", flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.service.shutdown()
        server.server_close()
