"""StandingFleet: a persistent serve loop over ``FleetBase.stream``.

``stream`` was built for iterator-of-bundles batch replay: pull, window,
dispatch, drain, done.  A live service needs the inverse admission
model — a warm pool that *waits* for work and accepts bundles at arrival
time.  ``StandingFleet`` bridges the two without a second scheduler: it
feeds ``stream`` a source backed by a thread-safe inbox that yields
``None`` while nothing has arrived (the executor's open-loop admission
contract), so the entire hardened machinery — chaos, liveness reaping,
backoff respawn, autoscale, speculation, skip-mode — serves live traffic
unchanged.

Lifecycle::

    fleet = StandingFleet(em, FleetConfig.process(max_workers=2, ...))
    fleet.warmup()                  # optional: pay spawn cost up front
    idx = fleet.submit(profile)     # at arrival time, any thread
    ...
    result = fleet.drain()          # finish everything submitted
    idx = fleet.submit(profile)     # pool still warm: next serve session
    fleet.close()                   # tear the pool down

Every request gets a :class:`RequestRecord` carrying the executor's
:class:`~repro.fleet.executor.BundleTiming` (separate enqueue/dispatch/
done stamps, queue-vs-replay split honest under chaos requeues) plus the
submit/complete wall stamps the serve layer adds.  Totals fold in index
order through ``ReportFold`` so an elastic, fault-injected serve session
reports aggregate totals bit-identical to a clean batch run over the
same profiles.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.emulator import EmulationReport, FleetReport, ReportFold
from repro.fleet.bundle import ScheduleBundle, bundle_profile
from repro.fleet.config import FleetConfig
from repro.fleet.executor import BundleTiming
from repro.obs import clock as obs_clock

_CLOSE = object()          # inbox sentinel: end the current serve session


@dataclass
class RequestRecord:
    """One submitted request's lifecycle, as the serve layer saw it.
    ``submitted``/``done`` are ``repro.obs.clock`` stamps; ``timing`` is
    the executor's per-bundle view (None until the bundle finishes —
    and permanently None for requests consumed by a raised stream)."""

    idx: int
    command: str
    submitted: float
    meta: Optional[dict] = None
    timing: Optional[BundleTiming] = None
    done: Optional[float] = None
    ok: Optional[bool] = None


@dataclass
class ServeResult:
    """One drained serve session: per-request records (submit order),
    index-order-folded totals, and the fleet's scaling/recovery
    accounting for the session's stream."""

    records: List[RequestRecord]
    totals: object
    serial_s: float
    n_ok: int
    n_skipped: int
    wall_s: float
    scaling: Dict = field(default_factory=dict)
    recovery: Dict = field(default_factory=dict)
    #: observability snapshot (``FleetBase.obs_snapshot``): the merged
    #: flight-recorder timeline, drop accounting, and a metrics snapshot
    obs: Dict = field(default_factory=dict)

    def fleet_report(self) -> FleetReport:
        """This serve session reshaped as the executor's
        :class:`FleetReport` — the one versioned serialization
        (``to_json``, schema-tagged) the service layer ships.  The serve
        layer does not retain per-request ``EmulationReport``s, so
        ``reports`` is empty; totals, scaling, recovery and the obs
        snapshot carry the session."""
        return FleetReport(
            reports=[], wall_s=self.wall_s, serial_s=self.serial_s,
            max_workers=int(self.scaling.get("peak_workers", 0) or 0),
            totals=self.totals, n_replayed=self.n_ok,
            scaling=dict(self.scaling), recovery=dict(self.recovery),
            obs=dict(self.obs))


class StandingFleet:
    """A warm process/remote pool serving requests at arrival time.

    ``config`` must describe a pool that exists between requests —
    ``executor='process'`` or ``'remote'`` (the thread path replays
    in-process and has nothing to keep warm).  ``timeout_s`` bounds one
    serve *session* (start → drain), defaulting to ``config.timeout``;
    a long-lived service should pass the session length it means.

    ``fleet=`` injects a pre-built pool (tests use an in-process loopback
    fleet); the injected pool's lifecycle stays with the caller.
    """

    def __init__(self, emulator, config: FleetConfig, *,
                 fleet=None, timeout_s: Optional[float] = None):
        if fleet is None:
            # build() validates the executor choice and owns the spawn
            fleet = config.build(config.worker_spec(emulator.spec()))
            self._owns_fleet = True
        else:
            self._owns_fleet = False
        self._em = emulator
        self._cfg = config
        self._fleet = fleet
        self._timeout = timeout_s if timeout_s is not None \
            else config.timeout
        self._inbox: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()
        self._records: Dict[int, RequestRecord] = {}
        self._fold = ReportFold(keep_reports=False)
        self._on_complete: List[Callable] = []
        self._pump: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._next_idx = 0
        self._session_t0 = 0.0
        self._closed = False

    # -- introspection ------------------------------------------------------

    @property
    def fleet(self):
        """The underlying pool (scaling/recovery counters live there)."""
        return self._fleet

    @property
    def active(self) -> bool:
        return self._pump is not None and self._pump.is_alive()

    @property
    def pending(self) -> int:
        """Requests submitted but not yet completed this session."""
        with self._lock:
            return sum(1 for r in self._records.values() if r.done is None)

    def on_complete(self, cb: Callable[[RequestRecord,
                                        Optional[EmulationReport]], None]):
        """Register a completion hook (runs on the pump thread, in
        completion order).  The SLO engine attaches here.  Returns an
        unsubscribe callable, so a load run on a shared standing pool can
        detach its hook when it finishes."""
        self._on_complete.append(cb)

        def unsubscribe():
            try:
                self._on_complete.remove(cb)
            except ValueError:
                pass
        return unsubscribe

    # -- lifecycle ----------------------------------------------------------

    def warmup(self, timeout: float = 120.0):
        """Block until the pool's workers report ready — pays the
        spawn/jax-import bill before the first arrival instead of under
        it."""
        return self._fleet.warmup(timeout)

    def submit(self, profile=None, *, bundle: Optional[ScheduleBundle] = None,
               meta: Optional[dict] = None) -> int:
        """Accept one request *now*; returns its session-local index.

        Pass a ``SynapseProfile`` (compiled here against the config's
        mesh) or a pre-built ``ScheduleBundle``.  Thread-safe; the first
        submit after construction or a drain starts a serve session on
        the warm pool.
        """
        if self._closed:
            raise RuntimeError("StandingFleet is closed")
        if (profile is None) == (bundle is None):
            raise ValueError("pass exactly one of profile= or bundle=")
        if bundle is None:
            bundle = bundle_profile(self._em, profile,
                                    mesh_spec=self._cfg.mesh_spec)
        with self._lock:
            self._raise_pump_error()
            if not self.active:
                self._start_session()
            idx = self._next_idx
            self._next_idx += 1
            self._records[idx] = RequestRecord(
                idx=idx, command=bundle.command,
                submitted=obs_clock.now(), meta=meta)
        self._inbox.put(bundle)
        return idx

    def drain(self, timeout: Optional[float] = None) -> ServeResult:
        """Finish every submitted request, end the session, keep the pool
        warm.  Returns the session's :class:`ServeResult`; re-raises the
        stream's error if the serve loop died."""
        if not self.active:
            self._raise_pump_error()
            raise RuntimeError("no active serve session to drain")
        self._inbox.put(_CLOSE)
        self._pump.join(timeout)
        if self._pump.is_alive():
            raise TimeoutError(f"serve session did not drain in {timeout}s")
        self._pump = None
        self._raise_pump_error()
        return self._session_result()

    def close(self, timeout: Optional[float] = None):
        """Drain (if a session is live) and tear down an owned pool."""
        if self._closed:
            return
        try:
            if self.active:
                self.drain(timeout)
        finally:
            self._closed = True
            if self._owns_fleet:
                self._fleet.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        # on an exception path don't mask it with a drain error
        if exc[0] is not None and self.active:
            self._inbox.put(_CLOSE)
        self.close()
        return False

    # -- serve loop ---------------------------------------------------------

    def _raise_pump_error(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _start_session(self):
        # called under self._lock
        self._records = {}
        self._fold = ReportFold(keep_reports=False)
        self._next_idx = 0
        self._error = None
        self._session_t0 = obs_clock.now()
        self._pump = threading.Thread(target=self._run, name="standing-pump",
                                      args=(self._records, self._fold),
                                      daemon=True)
        self._pump.start()

    def _source(self):
        """The executor-facing request source: inbox → bundles, ``None``
        while idle (open-loop admission), ``StopIteration`` on drain."""
        while True:
            try:
                item = self._inbox.get_nowait()
            except queue.Empty:
                yield None
                continue
            if item is _CLOSE:
                return
            yield item

    def _note_timing(self, records):
        def note(idx: int, timing: BundleTiming):
            rec = records.get(idx)
            if rec is not None:
                rec.timing = timing
        return note

    def _run(self, records, fold):
        try:
            results = self._fleet.stream(
                self._source(), timeout=self._timeout,
                window=self._cfg.window,
                max_attempts=self._cfg.max_attempts,
                liveness_timeout=self._cfg.liveness_timeout,
                speculate=self._cfg.speculate,
                on_failure=self._cfg.on_failure,
                record_timing=self._note_timing(records))
            for idx, rep in results:
                rec = records[idx]
                rec.done = obs_clock.now()
                rec.ok = rep is not None
                if rep is None:
                    fold.skip(idx)
                else:
                    fold.add(idx, rep)
                for cb in self._on_complete:
                    cb(rec, rep)
        except BaseException as e:  # noqa: BLE001 — surfaced on drain/submit
            self._error = e

    def _session_result(self) -> ServeResult:
        with self._lock:
            records = [self._records[i] for i in sorted(self._records)]
        return ServeResult(
            records=records, totals=self._fold.totals,
            serial_s=self._fold.serial_s, n_ok=self._fold.n_done,
            n_skipped=self._fold.n_skipped,
            wall_s=obs_clock.now() - self._session_t0,
            scaling=dict(self._fleet.last_scaling),
            recovery=dict(self._fleet.last_recovery),
            # injected test fleets may predate the recorder: obs is then
            # honestly empty rather than a fabricated snapshot
            obs=(self._fleet.obs_snapshot()
                 if hasattr(self._fleet, "obs_snapshot") else {}))
