"""``python -m repro.service``: start the live traffic emulation service.

Flags mirror the ``repro.scenarios serve`` subcommand; the HTTP routes
are documented in :mod:`repro.service.http`.
"""
import argparse

from repro.service.http import serve


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.service",
        description="HTTP load-run service over the emulation fleet")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8787,
                    help="0 picks a free port (printed at startup)")
    args = ap.parse_args(argv)
    serve(args.host, args.port)


if __name__ == "__main__":
    main()
