"""Streaming SLO accounting: bounded quantile sketch, windows, chaos join.

The service's product is the latency distribution under offered load —
p50/p99/p999, goodput vs offered, violations against a declared
:class:`SLO` — computed *streaming*: a load run may push millions of
requests, so nothing here stores per-request latencies.

:class:`LatencySketch` is a log-bucketed histogram: bucket boundaries
grow geometrically by ``growth`` (default 1.05), so any quantile read
back is within ``growth - 1`` relative error of the exact sample
quantile while memory stays a few hundred ints regardless of stream
length.  Merging two sketches adds bucket counts — exactly associative,
so per-window sketches roll up to run totals without re-observing
anything — and a sketch pickles, so remote agents could ship theirs
home.

:class:`SLOEngine` keys sketches by fixed time window and joins
``FleetReport.recovery["fault_events"]`` (``(opened, repaired)`` stamps
from the executor's MTTR bookkeeping) against that timeline: the windows
a fault overlaps are marked, so a kill-mid-storm visibly lands in the
marked windows' p999 rather than dissolving into the run average.  The
attribution interval extends one window past repair — the request a
death interrupted completes only *after* the replacement warms, so its
latency lands just after the repair stamp.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

__all__ = ["SLO", "LatencySketch", "SLOEngine"]


@dataclass(frozen=True)
class SLO:
    """A declared objective: ``percentile`` of latencies must come in at
    or under ``target_ms``.  ``SLO(200, 0.99)`` reads "p99 under 200ms"."""

    target_ms: float
    percentile: float = 0.99

    def __post_init__(self):
        if self.target_ms <= 0:
            raise ValueError("target_ms must be > 0")
        if not 0.0 < self.percentile < 1.0:
            raise ValueError("percentile must be in (0, 1), got "
                             f"{self.percentile}")

    def met(self, latency_s: float) -> bool:
        return latency_s * 1e3 <= self.target_ms

    def to_dict(self) -> Dict:
        return {"target_ms": self.target_ms, "percentile": self.percentile}


class LatencySketch:
    """Bounded-memory streaming quantiles over positive durations.

    Geometric buckets: value ``v`` lands in bucket
    ``floor(log(v / lo) / log(growth))``, and a quantile query returns
    the geometric midpoint of the bucket holding that rank — within
    ``growth - 1`` relative error of the exact sample quantile (the
    midpoint is at most ``sqrt(growth)`` off either edge).  Exact
    ``min``/``max``/``count``/``sum`` ride along, and queries clamp to
    the observed ``[min, max]`` so small samples never report a value
    outside what was seen.

    ``merge`` adds bucket counts elementwise: associative and
    commutative by construction (integer adds), which the tests assert
    literally.  Plain attributes only, so a sketch pickles.
    """

    def __init__(self, lo: float = 1e-6, hi: float = 3600.0,
                 growth: float = 1.05):
        if not 0 < lo < hi:
            raise ValueError("need 0 < lo < hi")
        if growth <= 1.0:
            raise ValueError("growth must be > 1.0")
        self.lo = lo
        self.hi = hi
        self.growth = growth
        self._log_g = math.log(growth)
        # bucket i covers [lo * g**i, lo * g**(i+1)); +2 for under/overflow
        self.n_buckets = int(math.ceil(math.log(hi / lo) / self._log_g)) + 2
        self.counts = [0] * self.n_buckets
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    # -- ingest -------------------------------------------------------------

    def _bucket(self, v: float) -> int:
        if v < self.lo:
            return 0                                   # underflow
        if v >= self.hi:
            return self.n_buckets - 1                  # overflow
        return 1 + int(math.log(v / self.lo) / self._log_g)

    def add(self, latency_s: float) -> None:
        if latency_s < 0:
            raise ValueError(f"latency must be >= 0, got {latency_s}")
        self.counts[self._bucket(latency_s)] += 1
        self.count += 1
        self.total += latency_s
        self.min = latency_s if self.min is None else min(self.min,
                                                          latency_s)
        self.max = latency_s if self.max is None else max(self.max,
                                                          latency_s)

    # -- query --------------------------------------------------------------

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """The sketched ``q``-quantile (0 < q <= 1) of everything added."""
        if not 0.0 < q <= 1.0:
            raise ValueError(f"q must be in (0, 1], got {q}")
        if self.count == 0:
            return 0.0
        rank = max(1, math.ceil(q * self.count))
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                if i == 0:                       # underflow: below lo
                    est = self.lo
                elif i == self.n_buckets - 1:    # overflow: clamp to max
                    est = self.max
                else:
                    edge = self.lo * self.growth ** (i - 1)
                    est = edge * math.sqrt(self.growth)  # geometric mid
                return min(max(est, self.min), self.max)
        return self.max                          # pragma: no cover

    # -- combine ------------------------------------------------------------

    def merge(self, other: "LatencySketch") -> "LatencySketch":
        """A new sketch holding both streams (inputs untouched)."""
        if (self.lo, self.hi, self.growth) != (other.lo, other.hi,
                                               other.growth):
            raise ValueError("cannot merge sketches with different "
                             "bucket geometry")
        out = LatencySketch(self.lo, self.hi, self.growth)
        out.counts = [a + b for a, b in zip(self.counts, other.counts)]
        out.count = self.count + other.count
        out.total = self.total + other.total
        mins = [m for m in (self.min, other.min) if m is not None]
        maxs = [m for m in (self.max, other.max) if m is not None]
        out.min = min(mins) if mins else None
        out.max = max(maxs) if maxs else None
        return out

    def __repr__(self):
        return (f"LatencySketch(n={self.count}, p50={self.quantile(0.5):.6f}"
                f", p99={self.quantile(0.99):.6f})")


class SLOEngine:
    """Joins three streams on one run-relative timeline: offered arrivals,
    completed latencies, and fault windows.

    All times are seconds since the run started (the serve layer
    subtracts its ``t0``).  ``observe`` takes the *completion* time and
    the open-loop latency measured from the scheduled arrival — so
    coordinated omission is structurally impossible: a request that sat
    out a worker outage is charged the whole wait, and its latency lands
    in the window where it completed, which the fault join then marks.
    """

    def __init__(self, slo: SLO, *, window_s: float = 1.0,
                 lo: float = 1e-6, hi: float = 3600.0, growth: float = 1.05):
        if window_s <= 0:
            raise ValueError("window_s must be > 0")
        self.slo = slo
        self.window_s = window_s
        self._mk = lambda: LatencySketch(lo, hi, growth)
        self.overall = self._mk()
        self._windows: Dict[int, Dict] = {}
        self.n_offered = 0
        self.n_completed = 0
        self.n_failed = 0
        self.n_violations = 0
        self._faults: List[Tuple[float, float]] = []
        self._t_hi = 0.0

    def _window(self, t: float) -> Dict:
        w = int(t // self.window_s)
        self._t_hi = max(self._t_hi, t)
        win = self._windows.get(w)
        if win is None:
            win = self._windows[w] = {
                "sketch": self._mk(), "offered": 0, "completed": 0,
                "failed": 0, "violations": 0,
            }
        return win

    # -- ingest -------------------------------------------------------------

    def offered(self, t: float) -> None:
        """An arrival was *scheduled* at run-relative ``t``."""
        self.n_offered += 1
        self._window(t)["offered"] += 1

    def observe(self, t_done: float, latency_s: float,
                ok: bool = True) -> None:
        """A request completed at ``t_done`` after ``latency_s`` measured
        from its scheduled arrival (open-loop)."""
        win = self._window(t_done)
        self.n_completed += 1
        win["completed"] += 1
        self.overall.add(latency_s)
        win["sketch"].add(latency_s)
        violated = (not ok) or not self.slo.met(latency_s)
        if not ok:
            self.n_failed += 1
            win["failed"] += 1
        if violated:
            self.n_violations += 1
            win["violations"] += 1

    def fault(self, opened: float, repaired: float) -> None:
        """A fault's MTTR window in run-relative seconds (from
        ``FleetReport.recovery["fault_events"]``, rebased by the serve
        layer's t0)."""
        self._faults.append((opened, repaired))

    # -- report -------------------------------------------------------------

    def _fault_count(self, w: int) -> int:
        """Faults overlapping window ``w``, with the attribution interval
        stretched one window past repair: the interrupted request lands
        just after the repair stamp."""
        t0, t1 = w * self.window_s, (w + 1) * self.window_s
        return sum(1 for o, r in self._faults
                   if o < t1 and (r + self.window_s) >= t0)

    def report(self) -> Dict:
        """The run's SLO accounting as one JSON-ready dict."""
        duration = max(self._t_hi,
                       (max(self._windows) + 1) * self.window_s
                       if self._windows else 0.0)
        n_good = self.n_completed - self.n_violations
        windows = []
        for w in sorted(self._windows):
            win = self._windows[w]
            sk = win["sketch"]
            windows.append({
                "t0": w * self.window_s,
                "offered": win["offered"],
                "completed": win["completed"],
                "failed": win["failed"],
                "violations": win["violations"],
                "faults": self._fault_count(w),
                "p50": sk.quantile(0.50),
                "p99": sk.quantile(0.99),
                "p999": sk.quantile(0.999),
                "max": sk.max or 0.0,
            })
        return {
            "slo": self.slo.to_dict(),
            "window_s": self.window_s,
            "duration_s": duration,
            "n_offered": self.n_offered,
            "n_completed": self.n_completed,
            "n_failed": self.n_failed,
            "n_violations": self.n_violations,
            "offered_hz": self.n_offered / duration if duration else 0.0,
            # goodput: completions that met the SLO, per second offered
            "goodput_hz": n_good / duration if duration else 0.0,
            "p50": self.overall.quantile(0.50),
            "p99": self.overall.quantile(0.99),
            "p999": self.overall.quantile(0.999),
            "mean": self.overall.mean,
            "max": self.overall.max or 0.0,
            "slo_met": (self.overall.quantile(self.slo.percentile) * 1e3
                        <= self.slo.target_ms) if self.n_completed else True,
            "faults": [{"opened": o, "repaired": r, "mttr_s": r - o}
                       for o, r in sorted(self._faults)],
            "windows": windows,
        }
