"""One open-loop load run: arrival process → standing fleet → SLO report.

``run_load`` is the service's core verb, shared by the HTTP surface, the
benchmarks, and the tests: pace an :class:`ArrivalProcess` onto the wall
clock, generate each arrival's scenario profile at fire time, submit it
to a :class:`StandingFleet`, and account every completion into an
:class:`SLOEngine`.  Latency is measured from the request's *scheduled*
arrival, not from submission — a request that waited out a worker outage
is charged the whole wait (no coordinated omission) — and the fleet's
``fault_events`` are rebased onto the run timeline so the SLO report's
windows show exactly where a chaos kill landed.

``time_scale`` compresses virtual arrival time onto the wall clock
(``time_scale=10`` plays a 60s diurnal period in 6s of wall time);
latencies are always reported in wall seconds.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.fleet.config import FleetConfig
from repro.obs import clock as obs_clock
from repro.service.arrivals import ArrivalProcess
from repro.service.slo import SLO, SLOEngine
from repro.service.standing import ServeResult, StandingFleet

DEFAULT_SLO = SLO(target_ms=200.0, percentile=0.99)


@dataclass
class LoadReport:
    """Everything one load run produced: the SLO accounting (the
    product), the serve session's per-request records and fold, and the
    run's shape for provenance."""

    slo: Dict                      # SLOEngine.report()
    serve: ServeResult             # records + totals + scaling/recovery
    n_arrivals: int                # requests actually fired
    time_scale: float
    wall_s: float
    stopped: bool = False          # True: cut short by a stop event
    meta: Dict = field(default_factory=dict)
    #: the run's overall LatencySketch (``SLOEngine.overall``) — folds
    #: into the service's /metrics histogram without re-observing
    latency: object = None

    #: schema version of ``to_dict``; bump on any breaking field change
    SCHEMA = 1

    def to_dict(self) -> Dict:
        """JSON-ready summary with a stable schema tag.

        Per-request records are elided; the fleet side goes through the
        one versioned serialization (``ServeResult.fleet_report()`` →
        ``FleetReport.to_json``) instead of a hand-built dict.  The obs
        event timeline is bulky and served by ``/trace`` — here it is
        reduced to its counts."""
        fleet = self.serve.fleet_report().to_json(reports=False)
        obs = dict(fleet.get("obs") or {})
        if "events" in obs:
            obs["n_events"] = len(obs.pop("events"))
        fleet["obs"] = obs
        return {
            "schema": self.SCHEMA,
            "n_arrivals": self.n_arrivals,
            "time_scale": self.time_scale,
            "wall_s": self.wall_s,
            "stopped": self.stopped,
            "n_ok": self.serve.n_ok,
            "n_skipped": self.serve.n_skipped,
            "fleet": fleet,
            "slo": self.slo,
            "meta": self.meta,
        }


def run_load(emulator, arrivals: ArrivalProcess, *,
             config: Optional[FleetConfig] = None,
             standing: Optional[StandingFleet] = None,
             slo: SLO = DEFAULT_SLO, window_s: float = 1.0,
             time_scale: float = 1.0,
             stop: Optional[threading.Event] = None,
             warmup: bool = True) -> LoadReport:
    """Drive one open-loop load run to completion and report.

    Pass ``config`` to build (and tear down) a pool for this run, or
    ``standing`` to reuse a warm one (it stays warm afterwards — the
    offered-load sweep benchmark amortizes one spawn across every rate).
    ``stop`` cuts the arrival loop short; everything already submitted
    still drains and is accounted.
    """
    if time_scale <= 0:
        raise ValueError("time_scale must be > 0")
    if (standing is None) == (config is None):
        raise ValueError("pass exactly one of config= or standing=")
    from repro.scenarios import generate

    owns = standing is None
    if owns:
        standing = StandingFleet(emulator, config)
        if warmup:
            standing.warmup()
    engine = SLOEngine(slo, window_s=window_s)
    t0_box = {}

    def _complete(rec, rep):
        t0 = t0_box["t0"]
        sched = t0 + rec.meta["t"] / time_scale
        engine.observe(t_done=rec.done - t0,
                       latency_s=max(0.0, rec.done - sched),
                       ok=bool(rec.ok))

    unsubscribe = standing.on_complete(_complete)
    stopped = False
    n = 0
    try:
        t0 = t0_box["t0"] = obs_clock.now()
        for a in arrivals:
            due = t0 + a.t / time_scale
            while True:
                lag = due - obs_clock.now()
                if lag <= 0:
                    break
                if stop is not None and stop.wait(min(lag, 0.1)):
                    break
                if stop is None:
                    time.sleep(min(lag, 0.25))
            if stop is not None and stop.is_set():
                stopped = True
                break
            # offered is charged at the *scheduled* instant: offered load
            # is the experiment's independent variable, not a measurement
            engine.offered(a.t / time_scale)
            profile = generate(a.scenario, **a.kwargs)
            standing.submit(profile, meta={"t": a.t, "arrival": a})
            n += 1
        serve = standing.drain() if standing.active else ServeResult(
            records=[], totals=None, serial_s=0.0, n_ok=0, n_skipped=0,
            wall_s=0.0)
        for opened, repaired in serve.recovery.get("fault_events", ()):
            engine.fault(opened - t0, repaired - t0)
        return LoadReport(slo=engine.report(), serve=serve, n_arrivals=n,
                          time_scale=time_scale,
                          wall_s=obs_clock.now() - t0, stopped=stopped,
                          latency=engine.overall)
    finally:
        unsubscribe()
        if owns:
            standing.close()
