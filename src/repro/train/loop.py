"""End-to-end training loop: data -> supervised step -> checkpoints, with the
Synapse runtime watchers around it (profile-as-you-train) and the predictor
feeding the straggler deadline.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs.base import ModelConfig
from repro.configs.run import RunConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.model_zoo import Model, build_model
from repro.optim.adamw import OptConfig
from repro.optim.compression import Int8ErrorFeedback
from repro.runtime.supervisor import (FailurePlan, Supervisor,
                                      SupervisorConfig)
from repro.train.step import init_train_state, make_train_step


@dataclass
class TrainJob:
    model: Model
    data: SyntheticLM
    step_fn: Any
    ckpt: CheckpointManager
    supervisor: Supervisor


def make_job(cfg: ModelConfig, run: RunConfig, *, opt: OptConfig = OptConfig(),
             data_cfg: Optional[DataConfig] = None, ckpt_dir: str = "/tmp/ckpt",
             mesh=None, sup_cfg: Optional[SupervisorConfig] = None,
             compress: bool = False) -> TrainJob:
    model = build_model(cfg, run)
    data = SyntheticLM(data_cfg or DataConfig(
        vocab_size=cfg.vocab_size, seq_len=512, global_batch=8))
    compressor = Int8ErrorFeedback() if compress else None
    step = jax.jit(make_train_step(model, opt, mesh, compress=compressor),
                   donate_argnums=0)
    ckpt = CheckpointManager(ckpt_dir, keep=(sup_cfg or SupervisorConfig()).keep)
    sup = Supervisor(ckpt, sup_cfg or SupervisorConfig())
    return TrainJob(model=model, data=data, step_fn=step, ckpt=ckpt,
                    supervisor=sup)


def train(job: TrainJob, num_steps: int, *, rng_seed: int = 0,
          resume: bool = True, failure_plan: Optional[FailurePlan] = None,
          compress: bool = False) -> Dict:
    start = 0
    compressor = Int8ErrorFeedback() if compress else None
    if resume and job.ckpt.latest_step() is not None:
        state, extra = job.ckpt.restore()
        start = extra.get("step", job.ckpt.latest_step())
    else:
        state = init_train_state(job.model, jax.random.key(rng_seed),
                                 compress=compressor)

    losses = []

    def step_fn(state, batch):
        state, metrics = job.step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        return state, metrics

    state, metrics = job.supervisor.run(
        state=state, step_fn=step_fn,
        batch_fn=lambda s: job.data.batch_at(s),
        num_steps=num_steps, start_step=start, failure_plan=failure_plan,
        extra_fn=lambda s: {"data": job.data.state(s)})
    return {"state": state, "losses": losses,
            "final_metrics": {k: float(v) for k, v in metrics.items()},
            "report": job.supervisor.report}
