"""Train step factory: forward + chunked CE + AdamW, with microbatched
gradient accumulation and mesh-aware sharding entered at trace time.

The returned step is a pure function  (state, batch) -> (state, metrics)
suitable for ``jax.jit`` with explicit in/out shardings from
``train_state_specs``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model_zoo import Model
from repro.optim.adamw import (OptConfig, adamw_update, init_opt_state,
                               zero1_specs)
from repro.parallel.sharding import (TRAIN_RULES, Rules, make_rules, shard,
                                     use_sharding)
from repro.train.loss import cross_entropy

AUX_LOSS_KEYS = ("moe_load_balance", "moe_router_z")


def make_loss_fn(model: Model):
    from repro.models.params import cast_tree

    def loss_fn(params, batch):
        # Mixed precision: f32 master params cast to bf16 ONCE, before the
        # layer scan — FSDP all-gathers then move bf16 (half the wire bytes)
        # and no f32 weight copies are ever materialized.  Grads flow back
        # through the cast and accumulate into f32 master state.
        params_c = cast_tree(params, model.run.cdtype)
        hidden, _, aux = model.forward(params_c, batch)
        targets = batch["targets"]
        ce, metrics = cross_entropy(
            lambda h: model.logits(params_c, h), hidden, targets,
            model.run.loss_chunk)
        loss = ce
        for k in AUX_LOSS_KEYS:
            if k in aux:
                loss = loss + aux[k]
        metrics.update(aux)
        metrics["ce_loss"] = ce
        return loss, metrics
    return loss_fn


def _split_microbatches(batch, m: int):
    def resh(x):
        # batch dim may be axis 0 ([B,...]) or axis 1 ([3,B,S] M-RoPE positions)
        if x.ndim >= 2 and x.shape[0] == 3 and x.shape[1] % m == 0:
            return jnp.moveaxis(
                x.reshape(x.shape[0], m, x.shape[1] // m, *x.shape[2:]), 1, 0)
        assert x.shape[0] % m == 0, (x.shape, m)
        return x.reshape(m, x.shape[0] // m, *x.shape[1:])
    return jax.tree.map(resh, batch)


def make_train_step(model: Model, opt_cfg: OptConfig, mesh=None,
                    rules_table=TRAIN_RULES, compress=None):
    """``compress``: optional gradient compressor (repro.optim.compression)."""
    loss_fn = make_loss_fn(model)
    m = model.run.microbatches

    def train_step(state, batch):
        with use_sharding(mesh, rules_table):
            params = state["params"]
            if m <= 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            else:
                mb = _split_microbatches(batch, m)

                def acc_body(carry, mbatch):
                    gsum, lsum = carry
                    (l, met), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, mbatch)
                    gsum = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), gsum, g)
                    return (gsum, lsum + l), met

                g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params)
                (grads, loss), mets = jax.lax.scan(
                    acc_body, (g0, jnp.zeros(())), mb)
                grads = jax.tree.map(lambda g: g / m, grads)
                loss = loss / m
                metrics = jax.tree.map(lambda x: jnp.mean(x, 0), mets)

            if compress is not None:
                grads, state, cmetrics = compress.apply(grads, state)
                metrics.update(cmetrics)

            new_params, new_opt, opt_metrics = adamw_update(
                grads, state["opt"], params, opt_cfg)
            metrics.update(opt_metrics)
            metrics["loss"] = loss
            new_state = dict(state)
            new_state["params"] = new_params
            new_state["opt"] = new_opt
            return new_state, metrics

    return train_step


def init_train_state(model: Model, rng, compress=None) -> Dict[str, Any]:
    params = model.init(rng)
    state = {"params": params, "opt": init_opt_state(params)}
    if compress is not None:
        state["ef_error"] = compress.init_error(params)
    return state


def train_state_specs(model: Model, mesh, rules: Rules, compress=None):
    """PartitionSpec tree for the train state (params TP, opt ZeRO-1)."""
    from jax.sharding import PartitionSpec as P
    pspecs = model.param_specs(rules)
    abstract = model.abstract()
    if model.run.zero1:
        ospecs = zero1_specs(pspecs, abstract, mesh, rules)
    else:
        ospecs = pspecs
    state = {"params": pspecs,
             "opt": {"mu": ospecs, "nu": ospecs, "step": P()}}
    if compress is not None:
        state["ef_error"] = ospecs
    return state


def abstract_train_state(model: Model, mesh=None, rules=None, compress=None):
    """ShapeDtypeStruct tree with shardings — dry-run input, no allocation."""
    from jax.sharding import NamedSharding

    abstract = model.abstract()
    if mesh is None:
        from jax.sharding import PartitionSpec as P
        specs = {"params": jax.tree.map(lambda _: P(), abstract),
                 "opt": {"mu": jax.tree.map(lambda _: P(), abstract),
                         "nu": jax.tree.map(lambda _: P(), abstract),
                         "step": P()}}
        if compress is not None:
            specs["ef_error"] = jax.tree.map(lambda _: P(), abstract)
    else:
        specs = train_state_specs(model, mesh, rules, compress)

    def mk(aval, spec, dtype=None):
        dt = dtype or aval.dtype
        if mesh is None:
            return jax.ShapeDtypeStruct(aval.shape, dt)
        return jax.ShapeDtypeStruct(aval.shape, dt,
                                    sharding=NamedSharding(mesh, spec))

    params = jax.tree.map(mk, abstract, specs["params"])
    f32 = functools.partial(mk, dtype=jnp.float32)
    mu = jax.tree.map(f32, abstract, specs["opt"]["mu"])
    nu = jax.tree.map(f32, abstract, specs["opt"]["nu"])
    step = jax.ShapeDtypeStruct((), jnp.int32) if mesh is None else \
        jax.ShapeDtypeStruct((), jnp.int32,
                             sharding=NamedSharding(
                                 mesh, specs["opt"]["step"]))
    state = {"params": params, "opt": {"mu": mu, "nu": nu, "step": step}}
    if compress is not None:
        state["ef_error"] = jax.tree.map(f32, abstract, specs["ef_error"])
    return state
