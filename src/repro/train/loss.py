"""Cross-entropy loss, optionally chunked over the sequence so the full
[B, S, V] logits tensor is never materialized (vocab up to 256k here — at
train_4k/llama4 that tensor would be 400 GB global).  The chunk loop is a
``lax.scan`` whose body recomputes under the remat policy in backward.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def _ce_block(logits, targets):
    """logits [.., V] f32; targets [..] int32 -> (sum loss, sum correct)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    loss = jnp.sum(lse - tgt)
    correct = jnp.sum((jnp.argmax(logits, -1) == targets).astype(jnp.float32))
    return loss, correct


def cross_entropy(logits_fn, hidden, targets,
                  chunk: int = 0) -> Tuple[jnp.ndarray, Dict]:
    """logits_fn(hidden_chunk) -> logits_chunk.  Returns (mean loss, metrics)."""
    B, S = targets.shape
    n_tok = B * S
    if chunk <= 0 or S <= chunk or S % chunk != 0:
        loss, correct = _ce_block(logits_fn(hidden), targets)
    else:
        nc = S // chunk
        h = jnp.moveaxis(hidden.reshape(B, nc, chunk, -1), 1, 0)
        t = jnp.moveaxis(targets.reshape(B, nc, chunk), 1, 0)

        def body(carry, xs):
            hl, tl = xs
            l, c = _ce_block(logits_fn(hl), tl)
            return (carry[0] + l, carry[1] + c), None

        (loss, correct), _ = jax.lax.scan(
            jax.checkpoint(body), (jnp.zeros(()), jnp.zeros(())), (h, t))
    return loss / n_tok, {"accuracy": correct / n_tok,
                          "tokens": jnp.asarray(n_tok, jnp.float32)}
