"""Error-feedback int8 gradient compression.

For cross-pod data parallelism the gradient all-reduce is the dominant
DCI/ICI payload; quantizing to int8 with per-tensor scale cuts it 4× vs f32
(2× vs bf16).  Plain quantization biases training; error feedback (EF-SGD /
1-bit-Adam style) keeps the quantization residual in optimizer state and
adds it back next step, making compression unbiased in the long run —
``tests/test_train_loop.py`` shows convergence parity on the synthetic LM.

``apply`` operates on the *already-reduced* gradient tree in the pjit path
(the compression itself is what a bandwidth-limited deployment would move
into a shard_map collective; ``wire_bytes_saved`` reports the would-be
saving and the dry-run's compressed variant measures it for §Perf).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


@dataclass(frozen=True)
class Int8ErrorFeedback:
    """Gradient compressor with persistent error state under key 'ef_error'."""

    def init_error(self, params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def apply(self, grads, state) -> Tuple[Any, Any, Dict]:
        err = state["ef_error"]

        def one(g, e):
            g32 = g.astype(jnp.float32) + e
            q, scale = quantize_int8(g32)
            deq = dequantize_int8(q, scale)
            return deq, g32 - deq

        flat = jax.tree.map(one, grads, err)
        new_grads = jax.tree.map(lambda t: t[0], flat,
                                 is_leaf=lambda t: isinstance(t, tuple))
        new_err = jax.tree.map(lambda t: t[1], flat,
                               is_leaf=lambda t: isinstance(t, tuple))
        new_state = dict(state)
        new_state["ef_error"] = new_err
        err_norm = jnp.sqrt(sum(jnp.sum(jnp.square(e))
                                for e in jax.tree.leaves(new_err)))
        return new_grads, new_state, {"ef_error_norm": err_norm}

    @staticmethod
    def wire_bytes_saved(params) -> float:
        """f32 all-reduce payload minus int8+scale payload, per step."""
        total = sum(x.size for x in jax.tree.leaves(params))
        n = len(jax.tree.leaves(params))
        return 4.0 * total - (1.0 * total + 4.0 * n)
