"""AdamW with cosine schedule, global-norm clipping, and ZeRO-1 sharding.

The optimizer state (mu, nu) can be sharded over the 'data' mesh axis in
addition to the parameter's own TP sharding (``zero1_specs``): GSPMD then
materializes the classic ZeRO-1 reduce-scatter(grads) -> local update ->
all-gather(params) schedule.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: OptConfig, step):
    """Linear warmup then cosine decay (f32 scalar)."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = cfg.lr * jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    t = jnp.clip((step - cfg.warmup_steps) /
                 max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(np.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda t: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(grads, opt_state, params, cfg: OptConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"]
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    lr = lr_at(cfg, step)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      opt_state["mu"], grads)
    nu = jax.tree.map(lambda n, g: cfg.b2 * n + (1 - cfg.b2) * jnp.square(g),
                      opt_state["nu"], grads)
    c1 = 1 - cfg.b1 ** (step.astype(jnp.float32) + 1)
    c2 = 1 - cfg.b2 ** (step.astype(jnp.float32) + 1)

    def upd(p, m, n):
        mhat = m / c1
        nhat = n / c2
        step_ = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if cfg.weight_decay:
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    new_state = {"mu": mu, "nu": nu, "step": step + 1}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# ZeRO-1 sharding of optimizer state
# ---------------------------------------------------------------------------

def zero1_specs(param_specs, abstract_params, mesh, rules):
    """Add the 'opt_shard' ('data') axis to the first divisible unsharded dim.

    ``param_specs``: PartitionSpec tree; ``abstract_params``: matching tree of
    ShapeDtypeStructs.  Leaves where no dim divides keep the param spec
    (ZeRO-1 falls back gracefully for small tensors).
    """
    from jax.sharding import PartitionSpec as P

    data_axes = rules.resolve("opt_shard")
    if data_axes is None or mesh is None:
        return param_specs
    if isinstance(data_axes, str):
        data_axes = (data_axes,)
    dsize = 1
    for a in data_axes:
        dsize *= mesh.shape[a]

    def one(spec, aval):
        shape = aval.shape
        parts = list(tuple(spec)) + [None] * (len(shape) - len(tuple(spec)))
        flat = set()
        for e in parts:
            flat.update((e,) if isinstance(e, str) else (e or ()))
        if flat & set(data_axes):
            return spec              # FSDP already shards this leaf over data
        for i, (s, ax) in enumerate(zip(shape, parts)):
            if ax is None and s % dsize == 0 and s >= dsize:
                parts[i] = data_axes if len(data_axes) > 1 else data_axes[0]
                return P(*parts)
        return spec

    return jax.tree.map(one, param_specs, abstract_params)
