"""Shared transformer layers: norms, rotary embeddings, attention, MLP.

Attention has three interchangeable implementations:
  * ``full``    — one einsum; O(S²) memory; fine for short sequences.
  * ``blocked`` — lax.scan over KV blocks with online softmax (flash-style in
                  pure XLA); O(S·block) memory; default above a threshold.
  * ``pallas``  — the Pallas TPU kernel in ``repro.kernels.flash_attention``.

All softmax math is f32 regardless of activation dtype.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttnConfig, ModelConfig
from repro.models.params import PDef
from repro.parallel.sharding import shard

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def def_rmsnorm(d: int) -> Dict[str, PDef]:
    return {"scale": PDef((d,), ("embed",), init="zeros")}  # (1 + scale) form


def rmsnorm(p, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def _rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    exp = jnp.arange(0, head_dim // 2, dtype=jnp.float32) / (head_dim // 2)
    return 1.0 / (theta ** exp)                      # [hd/2]


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               mrope_sections: Optional[Tuple[int, int, int]] = None):
    """x: [B,S,H,hd]; positions: [B,S] or [3,B,S] for M-RoPE."""
    hd = x.shape[-1]
    freqs = _rope_freqs(hd, theta)                   # [hd/2]
    if mrope_sections is None:
        angles = positions.astype(jnp.float32)[..., None] * freqs  # [B,S,hd/2]
    else:
        assert positions.ndim == 3, "M-RoPE needs [3,B,S] positions (t,h,w)"
        a = positions.astype(jnp.float32)[..., None] * freqs       # [3,B,S,hd/2]
        sec = mrope_sections
        assert sum(sec) == hd // 2, (sec, hd)
        parts = []
        start = 0
        for i, s in enumerate(sec):
            parts.append(a[i, ..., start:start + s])
            start += s
        angles = jnp.concatenate(parts, axis=-1)     # [B,S,hd/2]
    cos = jnp.cos(angles)[:, :, None, :]             # [B,S,1,hd/2]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention core
# ---------------------------------------------------------------------------

def _softcap(logits, cap: Optional[float]):
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


def _mask_bias(q_pos, k_pos, *, causal: bool, window: Optional[int],
               local_flag=None, kv_valid_len=None):
    """Additive f32 mask bias of shape broadcastable to [.., Sq, Sk].

    ``local_flag``: traced 0-d bool; when given, the window constraint only
    applies where the flag is True (scan-over-heterogeneous-layers support).
    """
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    ok = (kp <= qp) if causal else jnp.broadcast_to(
        jnp.bool_(True), jnp.broadcast_shapes(qp.shape, kp.shape))
    if window is not None:
        win_ok = qp - kp < window
        if local_flag is not None:
            win_ok = jnp.logical_or(win_ok, jnp.logical_not(local_flag))
        ok = jnp.logical_and(ok, win_ok)
    if kv_valid_len is not None:
        ok = jnp.logical_and(ok, kp < kv_valid_len)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attend_full(q, k, v, *, q_pos, k_pos, causal, window, softcap,
                local_flag=None, kv_valid_len=None):
    """q:[B,Sq,Hk,G,hd] grouped query; k,v:[B,Sk,Hk,hd]."""
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = _softcap(logits, softcap)
    bias = _mask_bias(q_pos, k_pos, causal=causal, window=window,
                      local_flag=local_flag,
                      kv_valid_len=kv_valid_len)             # [Sq,Sk] or [B,Sq,Sk]
    if bias.ndim == 2:
        bias = bias[None, None, None]
    else:
        bias = bias[:, None, None]
    logits = logits + bias
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)


_HUGE_WINDOW = 1.0e9


def _win_arr(window, local_flag):
    """Fold (static window, traced local_flag) into one traced f32 scalar."""
    if window is None:
        return jnp.float32(_HUGE_WINDOW)
    w = jnp.float32(window)
    if local_flag is None:
        return w
    return jnp.where(local_flag, w, jnp.float32(_HUGE_WINDOW))


def _block_bias(qp, kp, win_arr, causal: bool):
    """Additive f32 mask [bq, bkv] from position vectors + traced window."""
    d = qp[:, None].astype(jnp.float32) - kp[None, :].astype(jnp.float32)
    ok = (d >= 0) if causal else jnp.ones_like(d, bool)
    ok = jnp.logical_and(ok, d < win_arr)
    return jnp.where(ok, 0.0, NEG_INF)


@functools.lru_cache(maxsize=64)
def _flash_fn(causal: bool, softcap, block_q: int, block_kv: int,
              nq: int, nk: int):
    """FlashAttention-2 in pure XLA with a custom VJP.

    Forward: outer scan over q blocks, inner online-softmax scan over kv
    blocks; saves (q, k, v, out, L=m+log l) — O(S·hd), never O(S²).
    Backward: recomputes p per (kv, q) block pair; dk/dv accumulate per kv
    block (emitted as scan ys), dq accumulates as an f32 carry.  Without
    this, jax.linearize of the online-softmax scan saves the f32 ``acc``
    carry every inner step: O(nk · S · hd) f32 per layer (≈7 GB/layer on
    train_4k) — the dominant †temp in the v0 dry-run (§Perf iteration 2).
    """

    def fwd_blocks(q, k, v, win_arr):
        B, Sq, Hk, G, hd = q.shape
        Sk = k.shape[1]
        scale = hd ** -0.5
        qr = jnp.moveaxis(q.reshape(B, nq, block_q, Hk, G, hd), 1, 0)
        kr = jnp.moveaxis(k.reshape(B, nk, block_kv, Hk, hd), 1, 0)
        vr = jnp.moveaxis(v.reshape(B, nk, block_kv, Hk, hd), 1, 0)
        qp = jnp.arange(Sq).reshape(nq, block_q)
        kp = jnp.arange(Sk).reshape(nk, block_kv)

        def kv_step(carry, inp):
            m, l, acc, qb, qpb = carry
            kb, vb, kpb = inp
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            s = _softcap(s, softcap)
            s = s + _block_bias(qpb, kpb, win_arr, causal)[None, None, None]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb
            ).astype(jnp.float32)
            return (m_new, l, acc, qb, qpb), None

        def q_step(_, inp):
            qb, qpb = inp
            m0 = jnp.full((B, Hk, G, block_q), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, Hk, G, block_q), jnp.float32)
            a0 = jnp.zeros((B, Hk, G, block_q, hd), jnp.float32)
            (m, l, acc, _, _), _ = jax.lax.scan(kv_step, (m0, l0, a0, qb, qpb),
                                                (kr, vr, kp))
            out = acc / jnp.maximum(l, 1e-30)[..., None]
            L = m + jnp.log(jnp.maximum(l, 1e-30))          # [B,Hk,G,bq]
            return None, (jnp.einsum("bhgqd->bqhgd", out), L)

        _, (outs, Ls) = jax.lax.scan(q_step, None, (qr, qp))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Hk, G, hd)
        L = jnp.moveaxis(Ls, 0, 3).reshape(B, Hk, G, Sq)
        return out.astype(v.dtype), L                        # L: [B,Hk,G,Sq]

    def f(q, k, v, win_arr):
        return fwd_blocks(q, k, v, win_arr)[0]

    def f_fwd(q, k, v, win_arr):
        out, L = fwd_blocks(q, k, v, win_arr)
        return out, (q, k, v, out, L, win_arr)

    def f_bwd(res, do):
        q, k, v, out, L, win_arr = res
        B, Sq, Hk, G, hd = q.shape
        Sk = k.shape[1]
        scale = hd ** -0.5
        f32 = jnp.float32
        delta = jnp.einsum("bqhgd,bqhgd->bhgq", do.astype(f32),
                           out.astype(f32))                  # [B,Hk,G,Sq]

        qr = jnp.moveaxis(q.reshape(B, nq, block_q, Hk, G, hd), 1, 0)
        dor = jnp.moveaxis(do.reshape(B, nq, block_q, Hk, G, hd), 1, 0)
        Lr = jnp.moveaxis(L.reshape(B, Hk, G, nq, block_q), 3, 0)
        dr = jnp.moveaxis(delta.reshape(B, Hk, G, nq, block_q), 3, 0)
        kr = jnp.moveaxis(k.reshape(B, nk, block_kv, Hk, hd), 1, 0)
        vr = jnp.moveaxis(v.reshape(B, nk, block_kv, Hk, hd), 1, 0)
        qp = jnp.arange(Sq).reshape(nq, block_q)
        kp = jnp.arange(Sk).reshape(nk, block_kv)

        def kv_step(dq_acc, inp):
            kb, vb, kpb = inp

            def q_step(carry, qinp):
                dkj, dvj = carry
                qb, dob, Lb, db, qpb = qinp
                s_raw = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                                   preferred_element_type=f32) * scale
                if softcap is not None:
                    t = jnp.tanh(s_raw / softcap)
                    s = softcap * t
                else:
                    s = s_raw
                s = s + _block_bias(qpb, kpb, win_arr, causal)[None, None, None]
                p = jnp.exp(s - Lb[..., None])               # [B,Hk,G,bq,bkv]
                dvj = dvj + jnp.einsum("bhgqk,bqhgd->bkhd",
                                       p, dob.astype(f32))
                dp = jnp.einsum("bqhgd,bkhd->bhgqk", dob.astype(f32),
                                vb.astype(f32))
                ds = p * (dp - db[..., None])                # wrt softcapped s
                if softcap is not None:
                    ds = ds * (1.0 - t * t)
                dqb = jnp.einsum("bhgqk,bkhd->bqhgd", ds,
                                 kb.astype(f32)) * scale
                dkj = dkj + jnp.einsum("bhgqk,bqhgd->bkhd", ds,
                                       qb.astype(f32)) * scale
                return (dkj, dvj), dqb

            z_kv = jnp.zeros((B, block_kv, Hk, hd), f32)
            (dkj, dvj), dqs = jax.lax.scan(
                q_step, (z_kv, z_kv), (qr, dor, Lr, dr, qp))
            dq_acc = dq_acc + jnp.moveaxis(dqs, 0, 1).reshape(
                B, Sq, Hk, G, hd)
            return dq_acc, (dkj, dvj)

        dq0 = jnp.zeros((B, Sq, Hk, G, hd), f32)
        dq, (dks, dvs) = jax.lax.scan(kv_step, dq0, (kr, vr, kp))
        dk = jnp.moveaxis(dks, 0, 1).reshape(B, Sk, Hk, hd)
        dv = jnp.moveaxis(dvs, 0, 1).reshape(B, Sk, Hk, hd)
        return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
                jnp.zeros((), jnp.float32))

    flash = jax.custom_vjp(f)
    flash.defvjp(f_fwd, f_bwd)
    return flash




@functools.lru_cache(maxsize=64)
def _banded_fn(window: int, softcap, block_q: int, band: int, nq: int):
    """Banded causal attention for static sliding windows (custom VJP).

    Each query block of ``block_q`` rows attends only its ``band``-wide KV
    slice (band >= window + block_q - 1, clamped into range), cutting both
    FLOPs and HBM traffic from O(S²) to O(S·band) — 32k prefill with a 2048
    window does ~12.8× less attention work than the full flash path
    (EXPERIMENTS.md §Perf, hymba-1.5b/prefill_32k).
    """

    def _mask(i, kstart, bq, bd):
        qp = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (bq, bd), 0)
        kp = kstart + jax.lax.broadcasted_iota(jnp.int32, (bq, bd), 1)
        ok = jnp.logical_and(kp <= qp, qp - kp < window)
        return jnp.where(ok, 0.0, NEG_INF)

    def fwd_blocks(q, k, v):
        B, Sq, Hk, G, hd = q.shape
        Sk = k.shape[1]
        scale = hd ** -0.5
        qr = jnp.moveaxis(q.reshape(B, nq, block_q, Hk, G, hd), 1, 0)

        def q_step(_, inp):
            qb, i = inp
            kstart = jnp.clip((i + 1) * block_q - band, 0, Sk - band)
            kb = jax.lax.dynamic_slice_in_dim(k, kstart, band, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, kstart, band, axis=1)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            s = _softcap(s, softcap)
            s = s + _mask(i, kstart, block_q, band)[None, None, None]
            m = jnp.max(s, axis=-1)
            p = jnp.exp(s - m[..., None])
            l = jnp.sum(p, axis=-1)
            o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(vb.dtype), vb)
            o = o / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
            L = m + jnp.log(jnp.maximum(l, 1e-30))
            return None, (o.astype(v.dtype), L)

        _, (outs, Ls) = jax.lax.scan(q_step, None,
                                     (qr, jnp.arange(nq)))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Hk, G, hd)
        L = jnp.moveaxis(Ls, 0, 3).reshape(B, Hk, G, Sq)
        return out, L

    def f(q, k, v):
        return fwd_blocks(q, k, v)[0]

    def f_fwd(q, k, v):
        out, L = fwd_blocks(q, k, v)
        return out, (q, k, v, out, L)

    def f_bwd(res, do):
        q, k, v, out, L = res
        B, Sq, Hk, G, hd = q.shape
        Sk = k.shape[1]
        scale = hd ** -0.5
        f32 = jnp.float32
        delta = jnp.einsum("bqhgd,bqhgd->bhgq", do.astype(f32),
                           out.astype(f32))
        qr = jnp.moveaxis(q.reshape(B, nq, block_q, Hk, G, hd), 1, 0)
        dor = jnp.moveaxis(do.reshape(B, nq, block_q, Hk, G, hd), 1, 0)
        Lr = jnp.moveaxis(L.reshape(B, Hk, G, nq, block_q), 3, 0)
        dr = jnp.moveaxis(delta.reshape(B, Hk, G, nq, block_q), 3, 0)

        def q_step(carry, inp):
            dk_acc, dv_acc = carry
            qb, dob, Lb, db, i = inp
            kstart = jnp.clip((i + 1) * block_q - band, 0, Sk - band)
            kb = jax.lax.dynamic_slice_in_dim(k, kstart, band, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, kstart, band, axis=1)
            s_raw = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                               preferred_element_type=f32) * scale
            if softcap is not None:
                t = jnp.tanh(s_raw / softcap)
                s = softcap * t
            else:
                s = s_raw
            s = s + _mask(i, kstart, block_q, band)[None, None, None]
            p = jnp.exp(s - Lb[..., None])
            dvb = jnp.einsum("bhgqk,bqhgd->bkhd", p, dob.astype(f32))
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", dob.astype(f32),
                            vb.astype(f32))
            ds = p * (dp - db[..., None])
            if softcap is not None:
                ds = ds * (1.0 - t * t)
            dqb = jnp.einsum("bhgqk,bkhd->bqhgd", ds,
                             kb.astype(f32)) * scale
            dkb = jnp.einsum("bhgqk,bqhgd->bkhd", ds,
                             qb.astype(f32)) * scale
            dk_acc = jax.lax.dynamic_update_slice_in_dim(
                dk_acc, jax.lax.dynamic_slice_in_dim(
                    dk_acc, kstart, band, 1) + dkb, kstart, axis=1)
            dv_acc = jax.lax.dynamic_update_slice_in_dim(
                dv_acc, jax.lax.dynamic_slice_in_dim(
                    dv_acc, kstart, band, 1) + dvb, kstart, axis=1)
            return (dk_acc, dv_acc), dqb

        z = jnp.zeros((B, Sk, Hk, hd), f32)
        (dk, dv), dqs = jax.lax.scan(
            q_step, (z, z), (qr, dor, Lr, dr, jnp.arange(nq)))
        dq = jnp.moveaxis(dqs, 0, 1).reshape(B, Sq, Hk, G, hd)
        return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))

    banded = jax.custom_vjp(f)
    banded.defvjp(f_fwd, f_bwd)
    return banded

def attend_blocked(q, k, v, *, q_pos=None, k_pos=None, causal, window, softcap,
                   block_q: int = 512, block_kv: int = 1024,
                   local_flag=None, kv_valid_len=None, remat_body: bool = True,
                   skip_blocks: bool = False):
    """Flash-style attention in pure XLA (custom VJP, O(S·hd) memory).

    ``q_pos``/``k_pos`` are accepted for API compatibility but positions are
    token order (arange) by construction in every caller.
    ``kv_valid_len`` falls back to dense attention (unused in current paths).
    """
    del q_pos, k_pos, remat_body, skip_blocks
    B, Sq, Hk, G, hd = q.shape
    Sk = k.shape[1]
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Sk)
    assert Sq % block_q == 0 and Sk % block_kv == 0, (Sq, Sk, block_q, block_kv)
    if kv_valid_len is not None:
        pos = jnp.arange(Sq)
        return attend_full(q, k, v, q_pos=pos, k_pos=jnp.arange(Sk),
                           causal=causal, window=window, softcap=softcap,
                           local_flag=local_flag, kv_valid_len=kv_valid_len)
    if causal and window is not None and local_flag is None and Sq == Sk:
        # static sliding window: banded path, O(S·band) instead of O(S²)
        nb = -(-(window + block_q - 1) // block_kv)
        band = nb * block_kv
        if band < Sk:
            banded = _banded_fn(int(window), softcap, block_q, band,
                                Sq // block_q)
            return banded(q, k, v)
    flash = _flash_fn(bool(causal), softcap, block_q, block_kv,
                      Sq // block_q, Sk // block_kv)
    return flash(q, k, v, _win_arr(window, local_flag))


def attend_decode(q, k_cache, v_cache, *, cur_pos, window, softcap,
                  local_flag=None):
    """Single-token decode: q:[B,1,Hk,G,hd]; caches [B,T,Hk,hd]; cur_pos [B]."""
    scale = q.shape[-1] ** -0.5
    T = k_cache.shape[1]
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q, k_cache,
                        preferred_element_type=jnp.float32) * scale
    logits = _softcap(logits, softcap)
    kp = jnp.arange(T)[None, :]                       # [1,T]
    cp = cur_pos[:, None]                             # [B,1]
    ok = kp <= cp
    if window is not None:
        win_ok = cp - kp < window
        if local_flag is not None:
            win_ok = jnp.logical_or(win_ok, jnp.logical_not(local_flag))
        ok = jnp.logical_and(ok, win_ok)
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
    logits = logits + bias[:, None, None, None, :]
    probs = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v_cache)


# ---------------------------------------------------------------------------
# Attention module (projections + cache plumbing)
# ---------------------------------------------------------------------------

def def_attention(cfg: ModelConfig) -> Dict[str, Any]:
    d, hq, hk, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p: Dict[str, Any] = {
        "wq": PDef((d, hq, hd), ("embed", "heads", "head_dim"), init="scaled"),
        "wk": PDef((d, hk, hd), ("embed", "kv_heads", "head_dim"), init="scaled"),
        "wv": PDef((d, hk, hd), ("embed", "kv_heads", "head_dim"), init="scaled"),
        "wo": PDef((hq, hd, d), ("heads", "head_dim", "embed"), init="scaled"),
    }
    if cfg.attn.qkv_bias:
        p["bq"] = PDef((hq, hd), ("heads", "head_dim"), init="zeros")
        p["bk"] = PDef((hk, hd), ("kv_heads", "head_dim"), init="zeros")
        p["bv"] = PDef((hk, hd), ("kv_heads", "head_dim"), init="zeros")
    return p


class AttnRun(NamedTuple):
    impl: str = "auto"          # auto | full | blocked | pallas
    block_q: int = 512
    block_kv: int = 1024
    blocked_threshold: int = 2048
    skip_blocks: bool = False


def attention(p, x, *, cfg: ModelConfig, positions, is_local=False,
              run: AttnRun = AttnRun(),
              cache: Optional[Dict[str, jnp.ndarray]] = None,
              decode: bool = False, causal: bool = True):
    """Returns (out [B,S,D], updated cache or None).

    * train/prefill: causal self-attention over x; fills cache when given.
    * decode: x is [B,1,D]; attends over cache; ``cache["pos"]`` is [B].
    """
    B, S, D = x.shape
    hq, hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = hq // hk
    a = cfg.attn
    if isinstance(is_local, bool):                 # static layer pattern
        window, local_flag = (a.sliding_window if is_local else None), None
    else:                                          # traced flag (scan xs)
        window, local_flag = a.sliding_window, is_local

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if a.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)

    rope_pos = positions
    if a.mrope_sections is not None and positions.ndim == 2:
        rope_pos = jnp.broadcast_to(positions[None], (3,) + positions.shape)
    q = apply_rope(q, rope_pos, a.rope_theta, a.mrope_sections)
    k = apply_rope(k, rope_pos, a.rope_theta, a.mrope_sections)

    q = shard(q, "batch", "seq", "act_heads", "head_dim")
    k = shard(k, "batch", "seq", "act_kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "act_kv_heads", "head_dim")
    qg = q.reshape(B, S, hk, G, hd)

    if decode:
        assert cache is not None and S == 1
        pos = cache["pos"]                                     # [B]
        k_cache = _cache_write(cache["k"], k, pos)
        v_cache = _cache_write(cache["v"], v, pos)
        out = attend_decode(qg, k_cache, v_cache, cur_pos=pos,
                            window=window, local_flag=local_flag,
                            softcap=a.logit_softcap)
        new_cache = {"k": k_cache, "v": v_cache, "pos": pos + 1}
    else:
        impl = run.impl
        if impl == "auto":
            impl = "blocked" if S > run.blocked_threshold else "full"
        # Masks follow token order (RoPE positions may repeat, e.g. M-RoPE).
        q_pos = jnp.arange(S)
        if impl == "full":
            out = attend_full(qg, k, v, q_pos=q_pos, k_pos=q_pos,
                              causal=causal, window=window,
                              local_flag=local_flag,
                              softcap=a.logit_softcap)
        elif impl == "pallas":
            from repro.kernels import flash_attention as fa
            out = fa.ops.flash_attention_grouped(
                qg, k, v, causal=True, window=window,
                softcap=a.logit_softcap,
                block_q=run.block_q, block_kv=run.block_kv)
        else:
            out = attend_blocked(qg, k, v, q_pos=q_pos, k_pos=q_pos,
                                 causal=causal, window=window,
                                 local_flag=local_flag,
                                 softcap=a.logit_softcap,
                                 block_q=run.block_q, block_kv=run.block_kv,
                                 skip_blocks=run.skip_blocks)
        new_cache = None
        if cache is not None:  # prefill fills the cache
            T = cache["k"].shape[1]
            kpad = _pad_to(k, T).astype(cache["k"].dtype)
            vpad = _pad_to(v, T).astype(cache["v"].dtype)
            new_cache = {"k": shard(kpad, "batch", "cache_seq", None, "head_dim"),
                         "v": shard(vpad, "batch", "cache_seq", None, "head_dim"),
                         "pos": jnp.full((B,), S, jnp.int32)}

    out = out.reshape(B, S, hq, hd)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, new_cache


def _cache_write(cache_arr, new_kv, pos):
    """Write [B,1,H,hd] into [B,T,H,hd] at per-batch position ``pos``.

    Scatter (not one-hot multiply): XLA updates the donated cache buffer in
    place instead of materializing two cache-sized temporaries (§Perf log:
    34 GB/chip saved on qwen2-72b decode_32k)."""
    B = cache_arr.shape[0]
    upd = new_kv.astype(cache_arr.dtype)[:, 0]                    # [B,H,hd]
    return cache_arr.at[jnp.arange(B), pos].set(upd, mode="drop")


def _pad_to(x, T):
    S = x.shape[1]
    if S == T:
        return x
    assert S < T
    pad = [(0, 0)] * x.ndim
    pad[1] = (0, T - S)
    return jnp.pad(x, pad)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def def_mlp(d: int, f: int) -> Dict[str, PDef]:
    return {
        "wi_gate": PDef((d, f), ("embed", "ff"), init="scaled"),
        "wi_up": PDef((d, f), ("embed", "ff"), init="scaled"),
        "wo": PDef((f, d), ("ff", "embed"), init="scaled"),
    }


def mlp(p, x):
    h = jax.nn.silu(x @ p["wi_gate"].astype(x.dtype)) * (x @ p["wi_up"].astype(x.dtype))
    h = shard(h, "batch", "seq", "act_ff")
    return h @ p["wo"].astype(x.dtype)
