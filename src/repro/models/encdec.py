"""Encoder-decoder backbone (SeamlessM4T-medium).

The audio frontend is a stub per the assignment: ``input_specs()`` feeds
precomputed frame embeddings [B, S_src, D].  Shapes: an assigned LM cell of
``seq_len`` tokens maps to src = tgt = seq_len/2 so total tokens match the
decoder-only cells (DESIGN.md §4).

Decode carries two caches per decoder layer: the causal self-attention cache
and the (write-once at prefill) cross-attention K/V over the encoder output.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.run import RunConfig
from repro.models.layers import (attend_blocked, attend_decode, attend_full,
                                 attention, def_attention, def_mlp,
                                 def_rmsnorm, mlp, rmsnorm)
from repro.models.params import PDef, stack_pdefs
from repro.models.transformer import _attn_run, _remat_wrap, _stack_layers, \
    init_attn_cache
from repro.parallel.sharding import shard


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def def_encoder_block(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    return {"ln_attn": def_rmsnorm(d), "attn": def_attention(cfg),
            "ln_mlp": def_rmsnorm(d), "mlp": def_mlp(d, cfg.d_ff)}


def def_decoder_block(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    return {"ln_self": def_rmsnorm(d), "self_attn": def_attention(cfg),
            "ln_cross": def_rmsnorm(d), "cross_attn": def_attention(cfg),
            "ln_mlp": def_rmsnorm(d), "mlp": def_mlp(d, cfg.d_ff)}


def def_encdec(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "embed": PDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed")),
        "enc_layers": stack_pdefs(def_encoder_block(cfg),
                                  cfg.num_encoder_layers),
        "enc_ln_final": def_rmsnorm(cfg.d_model),
        "dec_layers": stack_pdefs(def_decoder_block(cfg), cfg.num_layers),
        "ln_final": def_rmsnorm(cfg.d_model),
        "lm_head": PDef((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                        init="scaled"),
    }


# ---------------------------------------------------------------------------
# Cross attention
# ---------------------------------------------------------------------------

def _proj_kv(p, enc_out, cfg):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(enc_out.dtype))
    return k, v


def cross_attention(p, x, *, cfg: ModelConfig, run: RunConfig,
                    enc_out=None, kv=None):
    """q from x [B,St,D]; k/v from enc_out or precomputed ``kv`` (decode)."""
    B, S, D = x.shape
    hq, hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = hq // hk
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if kv is None:
        k, v = _proj_kv(p, enc_out, cfg)
    else:
        k, v = kv
    qg = q.reshape(B, S, hk, G, hd)
    q_pos = jnp.arange(S)
    k_pos = jnp.arange(k.shape[1])
    if S == 1:
        # decode: full (non-causal) attention over the whole cross cache
        pos = jnp.full((B,), k.shape[1] - 1, jnp.int32)
        out = attend_decode(qg, k, v, cur_pos=pos, window=None, softcap=None)
    elif S > run.blocked_threshold:
        out = attend_blocked(qg, k, v, q_pos=q_pos, k_pos=k_pos, causal=False,
                             window=None, softcap=None,
                             block_q=run.block_q, block_kv=run.block_kv)
    else:
        out = attend_full(qg, k, v, q_pos=q_pos, k_pos=k_pos, causal=False,
                          window=None, softcap=None)
    out = out.reshape(B, S, hq, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def encode(params, src_embeds, *, cfg: ModelConfig, run: RunConfig):
    x = src_embeds.astype(run.cdtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), run.cdtype)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = shard(x, "batch", "seq_shard", "embed")

    def body(xx, pl):
        h = rmsnorm(pl["ln_attn"], xx, cfg.norm_eps)
        out, _ = attention(pl["attn"], h, cfg=cfg, positions=positions,
                           run=_attn_run(run), causal=False)
        xx = xx + out
        xx = shard(xx, "batch", "seq_shard", "embed")
        h = rmsnorm(pl["ln_mlp"], xx, cfg.norm_eps)
        xx = xx + mlp(pl["mlp"], h)
        return shard(xx, "batch", "seq_shard", "embed"), None

    x, _ = jax.lax.scan(lambda c, pl: _remat_wrap(body, run)(c, pl),
                        x, params["enc_layers"])
    return rmsnorm(params["enc_ln_final"], x, cfg.norm_eps)


def forward_encdec(params, batch, *, cfg: ModelConfig, run: RunConfig,
                   cache=None, decode=False):
    """Returns (decoder hidden, new_cache|None, aux).

    train/prefill: batch = {src_embeds [B,Ss,D], tgt_tokens [B,St]}
    decode:        batch = {tokens [B,1]}, cache from prefill
    """
    if decode:
        assert cache is not None
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(run.cdtype)
        if cfg.embed_scale:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), run.cdtype)
        positions = cache["self"]["pos"][0][:, None]
        enc_out = None
    else:
        enc_out = encode(params, batch["src_embeds"], cfg=cfg, run=run)
        x = jnp.take(params["embed"], batch["tgt_tokens"], axis=0).astype(run.cdtype)
        if cfg.embed_scale:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), run.cdtype)
        B, St, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(St)[None], (B, St))
        x = shard(x, "batch", "seq_shard", "embed")

    def body(xx, pl, self_cl, cross_kv):
        h = rmsnorm(pl["ln_self"], xx, cfg.norm_eps)
        out, self_nc = attention(pl["self_attn"], h, cfg=cfg,
                                 positions=positions, run=_attn_run(run),
                                 cache=self_cl, decode=decode)
        xx = xx + out
        h = rmsnorm(pl["ln_cross"], xx, cfg.norm_eps)
        if decode:
            cross_out = cross_attention(pl["cross_attn"], h, cfg=cfg, run=run,
                                        kv=cross_kv)
            new_kv = cross_kv
        else:
            cross_out = cross_attention(pl["cross_attn"], h, cfg=cfg, run=run,
                                        enc_out=enc_out)
            new_kv = _proj_kv(pl["cross_attn"], enc_out, cfg) \
                if self_cl is not None else None
        xx = xx + cross_out
        h = rmsnorm(pl["ln_mlp"], xx, cfg.norm_eps)
        xx = xx + mlp(pl["mlp"], h)
        if not decode:
            xx = shard(xx, "batch", "seq_shard", "embed")
        return xx, self_nc, new_kv

    if cache is None:
        def scan_fn(carry, pl):
            y, _, _ = _remat_wrap(
                lambda c, p_: body(c, p_, None, None), run)(carry, pl)
            return y, None
        x, _ = jax.lax.scan(scan_fn, x, params["dec_layers"])
        new_cache = None
    else:
        def scan_fn(carry, xs):
            pl, self_cl, ck, cv = xs
            y, self_nc, new_kv = _remat_wrap(body, run)(
                carry, pl, self_cl, (ck, cv))
            return y, (self_nc, new_kv[0], new_kv[1])
        x, (self_cache, ck, cv) = jax.lax.scan(
            scan_fn, x,
            (params["dec_layers"], cache["self"],
             cache["cross_k"], cache["cross_v"]))
        new_cache = {"self": self_cache, "cross_k": ck, "cross_v": cv}

    x = rmsnorm(params["ln_final"], x, cfg.norm_eps)
    return x, new_cache, {}


def init_encdec_cache(cfg: ModelConfig, run: RunConfig, batch: int,
                      tgt_len: int, src_len: int):
    hk, hd = cfg.num_kv_heads, cfg.head_dim
    self_cache = _stack_layers(
        init_attn_cache(cfg, batch, tgt_len, run.kvdtype), cfg.num_layers)
    zeros_kv = jnp.zeros((cfg.num_layers, batch, src_len, hk, hd), run.kvdtype)
    return {"self": self_cache,
            "cross_k": shard_5d(zeros_kv), "cross_v": shard_5d(zeros_kv)}


def shard_5d(x):
    return shard(x, None, "batch", "cache_seq", None, "head_dim")
