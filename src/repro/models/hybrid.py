"""Attention-free (mamba2 LM) and hybrid (hymba) blocks.

Hymba (arXiv:2411.13676): each layer runs attention heads and mamba heads in
*parallel* on the same normed input; the two outputs are RMS-normalized and
averaged with learned per-channel scales, then a SwiGLU MLP follows.  Three
layers (first / middle / last) use full global attention, the rest sliding
window — this arrives as the traced ``local_flag`` from the scan driver.
Meta-tokens from the paper are out of scope (noted in DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.run import RunConfig
from repro.models import ssm as ssm_lib
from repro.models.layers import (attention, def_attention, def_mlp,
                                 def_rmsnorm, mlp, rmsnorm)
from repro.models.params import PDef, stack_pdefs
from repro.parallel.sharding import shard
from repro.models.transformer import _attn_run


# ---------------------------------------------------------------------------
# Mamba2 LM (attention-free)
# ---------------------------------------------------------------------------

def def_ssm_block(cfg: ModelConfig) -> Dict[str, Any]:
    return {"ln": def_rmsnorm(cfg.d_model), "mamba": ssm_lib.def_mamba2(cfg)}


def def_ssm_lm(cfg: ModelConfig) -> Dict[str, Any]:
    p = {
        "embed": PDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed")),
        "layers": stack_pdefs(def_ssm_block(cfg), cfg.num_layers),
        "ln_final": def_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = PDef((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                            init="scaled")
    return p


def make_ssm_block(cfg: ModelConfig, run: RunConfig):
    def block(pl, x, *, positions, local_flag, cache_layer, decode):
        del positions, local_flag
        h = rmsnorm(pl["ln"], x, cfg.norm_eps)
        cl = cache_layer["ssm"] if cache_layer is not None else None
        out, nc = ssm_lib.mamba2_block(pl["mamba"], h, cfg=cfg, cache=cl,
                                       decode=decode)
        x = x + out
        x = shard(x, "batch", "seq_shard" if not decode else "seq", "embed")
        return x, ({"ssm": nc} if nc is not None else None), {}
    return block


def init_ssm_cache(cfg: ModelConfig, run: RunConfig, batch: int):
    per_layer = {"ssm": ssm_lib.init_mamba_cache(cfg, batch, run.cdtype)}
    from repro.models.transformer import _stack_layers
    return _stack_layers(per_layer, cfg.num_layers)


# ---------------------------------------------------------------------------
# Hymba hybrid block
# ---------------------------------------------------------------------------

def def_hybrid_block(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    return {
        "ln_in": def_rmsnorm(d),
        "attn": def_attention(cfg),
        "mamba": ssm_lib.def_mamba2(cfg),
        "norm_attn_out": def_rmsnorm(d),
        "norm_ssm_out": def_rmsnorm(d),
        "ln_mlp": def_rmsnorm(d),
        "mlp": def_mlp(d, cfg.d_ff),
    }


def def_hybrid_lm(cfg: ModelConfig) -> Dict[str, Any]:
    p = {
        "embed": PDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed")),
        "layers": stack_pdefs(def_hybrid_block(cfg), cfg.num_layers),
        "ln_final": def_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = PDef((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                            init="scaled")
    return p


def make_hybrid_block(cfg: ModelConfig, run: RunConfig):
    def block(pl, x, *, positions, local_flag, cache_layer, decode):
        h = rmsnorm(pl["ln_in"], x, cfg.norm_eps)
        acl = cache_layer["attn"] if cache_layer is not None else None
        scl = cache_layer["ssm"] if cache_layer is not None else None
        attn_out, a_nc = attention(pl["attn"], h, cfg=cfg, positions=positions,
                                   is_local=local_flag, run=_attn_run(run),
                                   cache=acl, decode=decode)
        ssm_out, s_nc = ssm_lib.mamba2_block(pl["mamba"], h, cfg=cfg,
                                             cache=scl, decode=decode)
        fused = 0.5 * (rmsnorm(pl["norm_attn_out"], attn_out, cfg.norm_eps) +
                       rmsnorm(pl["norm_ssm_out"], ssm_out, cfg.norm_eps))
        x = x + fused
        x = shard(x, "batch", "seq_shard" if not decode else "seq", "embed")
        h2 = rmsnorm(pl["ln_mlp"], x, cfg.norm_eps)
        x = x + mlp(pl["mlp"], h2)
        x = shard(x, "batch", "seq_shard" if not decode else "seq", "embed")
        nc = None
        if a_nc is not None or s_nc is not None:
            nc = {"attn": a_nc, "ssm": s_nc}
        return x, nc, {}
    return block


def init_hybrid_cache(cfg: ModelConfig, run: RunConfig, batch: int,
                      max_len: int):
    from repro.models.transformer import _stack_layers, init_attn_cache
    per_layer = {
        "attn": init_attn_cache(cfg, batch, max_len, run.kvdtype),
        "ssm": ssm_lib.init_mamba_cache(cfg, batch, run.cdtype),
    }
    return _stack_layers(per_layer, cfg.num_layers)
