"""Mixture-of-Experts: token-choice top-k routing with per-group capacity.

Dispatch is gather/scatter based (O(tokens) memory) rather than the GShard
one-hot-einsum form (O(tokens·E·C)): each batch row is a routing group; a
[B, E, C] token-index table is built by scatter, tokens are gathered into
[B, E, C, D], expert FFNs run as einsums with the expert dim sharded over the
'model' mesh axis (expert parallelism), and outputs are combined by a gather
back to token order weighted by router gates.  Over-capacity tokens drop
(capacity_factor controls head-room), the standard TPU MoE contract.

Aux losses: switch load-balance loss and router z-loss.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import PDef
from repro.parallel.sharding import shard


def def_moe(cfg: ModelConfig) -> Dict[str, Any]:
    d, m = cfg.d_model, cfg.moe
    e, f = m.num_experts, m.d_ff_expert
    p: Dict[str, Any] = {
        "router": PDef((d, e), ("embed", "experts"), init="scaled", scale=0.1),
        "wi_gate": PDef((e, d, f), ("experts", "embed", "ff"), init="scaled"),
        "wi_up": PDef((e, d, f), ("experts", "embed", "ff"), init="scaled"),
        "wo": PDef((e, f, d), ("experts", "ff", "embed"), init="scaled"),
    }
    if m.shared_expert:
        from repro.models.layers import def_mlp
        p["shared"] = def_mlp(d, cfg.d_ff)
    return p


def _capacity(tokens_per_group: int, top_k: int, num_experts: int,
              factor: float) -> int:
    c = int(tokens_per_group * top_k * factor / num_experts)
    return max(c, 1)


def moe_block(p, x, *, cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: [B, S, D] -> (out [B, S, D], aux losses)."""
    m = cfg.moe
    B, S, D = x.shape
    E, K = m.num_experts, m.top_k
    C = _capacity(S, K, E, m.capacity_factor)

    xf = x.astype(jnp.float32)
    logits = xf @ p["router"].astype(jnp.float32)            # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)          # [B,S,K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)    # renormalize top-k

    # --- position within each expert's capacity buffer (per group) ---------
    # one-hot over experts for each slot k, cumulated over the token axis.
    oh = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)      # [B,S,K,E]
    ohf = oh.reshape(B, S * K, E)                            # slot-major order
    pos_in_e = jnp.cumsum(ohf, axis=1) - ohf                 # [B,S*K,E]
    pos = jnp.sum(pos_in_e.reshape(B, S, K, E) * oh, axis=-1)  # [B,S,K]
    keep = pos < C                                           # over-capacity drop
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # --- dispatch: scatter token index s into [B, E, C] ---------------------
    # vmap over the batch (group) dim so GSPMD sees batched scatter/gather and
    # keeps B sharded over data; explicit batch index arrays made the
    # partitioner all-gather the whole activation (§Perf log, llama4 prefill).
    s_ix = jnp.broadcast_to(jnp.arange(S)[None, :, None], (B, S, K))
    safe_pos = jnp.where(keep, pos, C)                       # C == drop slot
    table0 = jnp.full((E, C + 1), S, jnp.int32)              # S == empty sentinel

    def scat(e_b, p_b, s_b):
        return table0.at[e_b, p_b].set(s_b, mode="drop")

    table = jax.vmap(scat)(expert_idx, safe_pos, s_ix)[:, :, :C]   # [B,E,C]

    xs = jnp.concatenate([x, jnp.zeros((B, 1, D), x.dtype)], axis=1)  # pad row S
    gathered = jnp.take_along_axis(
        xs, table.reshape(B, E * C)[..., None], axis=1).reshape(B, E, C, D)
    gathered = shard(gathered, "batch", "act_experts", "expert_cap", None)

    # --- expert FFN (swiglu), expert dim sharded over 'model' ---------------
    wg = p["wi_gate"].astype(x.dtype)
    wu = p["wi_up"].astype(x.dtype)
    wo = p["wo"].astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", gathered, wg)) * \
        jnp.einsum("becd,edf->becf", gathered, wu)
    h = shard(h, "batch", "act_experts", "expert_cap", "act_ff")
    y = jnp.einsum("becf,efd->becd", h, wo)                  # [B,E,C,D]

    # --- combine: gather each token's K expert outputs ----------------------
    flat = y.reshape(B, E * C, D)
    slot = expert_idx * C + jnp.minimum(safe_pos, C - 1)     # [B,S,K]
    tok_out = jnp.take_along_axis(
        flat[:, :, :], slot.reshape(B, S * K)[..., None], axis=1
    ).reshape(B, S, K, D)
    out = jnp.sum(tok_out * gate_vals[..., None].astype(x.dtype), axis=2)

    if m.shared_expert:
        from repro.models.layers import mlp
        out = out + mlp(p["shared"], x)

    # --- aux losses ----------------------------------------------------------
    # Switch load-balance: E * sum_e f_e * p_e  (f: token fraction, p: prob mass)
    density = jnp.mean(jnp.sum(oh[:, :, :, :].astype(jnp.float32), axis=2),
                       axis=(0, 1))                          # [E] token fraction*K
    prob_mass = jnp.mean(probs, axis=(0, 1))                 # [E]
    lb = E * jnp.sum((density / K) * prob_mass)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {
        "moe_load_balance": m.router_aux_weight * lb,
        "moe_router_z": m.router_z_weight * z,
        "moe_drop_fraction": dropped,
    }
    return out, aux
