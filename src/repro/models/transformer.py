"""Decoder-only transformer LM (llama4 / moonshot / qwen2 / gemma2 / qwen2-vl).

Layers are stacked and scanned (``jax.lax.scan``) so HLO size is independent
of depth; per-layer heterogeneity (gemma2 local/global alternation, hymba's
three global layers) rides along as a traced flag vector in the scan xs.
Activation remat policy wraps the scan body.  KV caches are stacked with a
leading layer dim and scanned together with the parameters.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.run import RunConfig
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (AttnRun, attention, def_attention, def_mlp,
                                 def_rmsnorm, mlp, rmsnorm)
from repro.models.params import PDef, stack_pdefs
from repro.parallel.sharding import shard


# ---------------------------------------------------------------------------
# Layer patterns
# ---------------------------------------------------------------------------

def layer_flags(cfg: ModelConfig) -> np.ndarray:
    """is_local flag per layer."""
    L = cfg.num_layers
    pat = cfg.attn.layer_pattern
    if pat == "global" or cfg.attn.sliding_window is None:
        return np.zeros(L, bool)
    if pat == "local_global":               # gemma2: even layers local
        return np.array([i % 2 == 0 for i in range(L)])
    if pat == "hymba":                      # full attn at first/middle/last
        glob = {0, L // 2, L - 1}
        return np.array([i not in glob for i in range(L)])
    raise ValueError(pat)


def uses_uniform_global(cfg: ModelConfig) -> bool:
    return not layer_flags(cfg).any()


# ---------------------------------------------------------------------------
# Parameter tree
# ---------------------------------------------------------------------------

def def_block(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    p: Dict[str, Any] = {"ln_attn": def_rmsnorm(d), "ln_mlp": def_rmsnorm(d)}
    p["attn"] = def_attention(cfg)
    if cfg.sandwich_norms:
        p["ln_attn_post"] = def_rmsnorm(d)
        p["ln_mlp_post"] = def_rmsnorm(d)
    if cfg.moe is not None:
        p["moe"] = moe_lib.def_moe(cfg)
    else:
        p["mlp"] = def_mlp(d, cfg.d_ff)
    return p


def def_lm(cfg: ModelConfig) -> Dict[str, Any]:
    p: Dict[str, Any] = {
        "embed": PDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                      init="normal"),
        "layers": stack_pdefs(def_block(cfg), cfg.num_layers),
        "ln_final": def_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = PDef((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                            init="scaled")
    return p


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------

def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    hk, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": shard(jnp.zeros((batch, max_len, hk, hd), dtype),
                   "batch", "cache_seq", None, "head_dim"),
        "v": shard(jnp.zeros((batch, max_len, hk, hd), dtype),
                   "batch", "cache_seq", None, "head_dim"),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def _stack_layers(per_layer, L: int):
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (L,) + x.shape), per_layer)


def init_cache(cfg: ModelConfig, run: RunConfig, batch: int, max_len: int):
    """Stacked (leading layer dim) cache pytree: {"attn": {k,v,pos}}."""
    per_layer = init_attn_cache(cfg, batch, max_len, run.kvdtype)
    return {"attn": _stack_layers(per_layer, cfg.num_layers)}


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _attn_run(run: RunConfig) -> AttnRun:
    return AttnRun(impl=run.attn_impl, block_q=run.block_q,
                   block_kv=run.block_kv,
                   blocked_threshold=run.blocked_threshold,
                   skip_blocks=run.skip_attn_blocks)


def block_apply(pl, x, *, cfg: ModelConfig, run: RunConfig, positions,
                local_flag, cache_layer=None, decode=False):
    seq_ax = "seq_shard" if not decode else "seq"
    h = rmsnorm(pl["ln_attn"], x, cfg.norm_eps)
    # pin the norm output to the seq-sharded bf16 layout so the Megatron-SP
    # all-gather happens on bf16 h at the qkv einsum, not on f32 internals
    h = shard(h, "batch", seq_ax, "embed")
    attn_out, new_cache = attention(
        pl["attn"], h, cfg=cfg, positions=positions, is_local=local_flag,
        run=_attn_run(run), cache=cache_layer, decode=decode)
    if cfg.sandwich_norms:
        attn_out = rmsnorm(pl["ln_attn_post"], attn_out, cfg.norm_eps)
    x = x + attn_out
    x = shard(x, "batch", "seq_shard" if not decode else "seq", "embed")

    h = rmsnorm(pl["ln_mlp"], x, cfg.norm_eps)
    h = shard(h, "batch", seq_ax, "embed")
    if cfg.moe is not None:
        mlp_out, aux = moe_lib.moe_block(pl["moe"], h, cfg=cfg)
    else:
        mlp_out, aux = mlp(pl["mlp"], h), {}
    if cfg.sandwich_norms:
        mlp_out = rmsnorm(pl["ln_mlp_post"], mlp_out, cfg.norm_eps)
    x = x + mlp_out
    x = shard(x, "batch", "seq_shard" if not decode else "seq", "embed")
    return x, new_cache, aux


def _remat_wrap(fn, run: RunConfig):
    if run.remat == "none":
        return fn
    if run.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)            # "full": save nothing


def embed_tokens(params, batch, cfg: ModelConfig, run: RunConfig):
    if "embeds" in batch:                # vlm / audio frontend stubs
        x = batch["embeds"].astype(run.cdtype)
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(run.cdtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), run.cdtype)
    return x


def layer_plan(cfg: ModelConfig):
    """Static execution plan over the stacked layers.

    Heterogeneous patterns are split into *uniform* groups so the locality
    flag is a compile-time constant inside each group — static sliding
    windows then take the banded attention path (O(S·band) instead of
    O(S²); §Perf, hymba-1.5b/prefill_32k).  Groups:

      ("scan",  start, count, flag)   — lax.scan over a contiguous slice
      ("single", idx, flag)           — one unrolled layer
      ("pair_scan", count)            — alternating local/global (gemma2):
                                        scan over (even, odd) layer pairs
    """
    flags = layer_flags(cfg)
    L = cfg.num_layers
    if not flags.any():
        return [("scan", 0, L, False)]
    if cfg.attn.layer_pattern == "local_global" and L % 2 == 0:
        return [("pair_scan", L // 2)]
    plan = []
    i = 0
    while i < L:
        j = i
        while j < L and flags[j] == flags[i]:
            j += 1
        if j - i == 1:
            plan.append(("single", i, bool(flags[i])))
        else:
            plan.append(("scan", i, j - i, bool(flags[i])))
        i = j
    return plan


def forward_stack(params, batch, *, cfg: ModelConfig, run: RunConfig,
                  block_fn, cache=None, decode=False):
    """Generic grouped-scan driver shared by all decoder-only families.

    ``block_fn(pl, x, positions, local_flag, cache_layer, decode)``
        -> (x, new_cache_layer, aux)

    The KV/SSM cache rides in the CARRY with per-layer dynamic slice/update,
    not as scan xs/ys: emitting updated caches as ys allocates a second full
    stacked cache (double-buffer), +5.4 GB/chip on qwen2-72b decode_32k
    (§Perf log).  In-carry updates alias the donated buffer.
    """
    x = embed_tokens(params, batch, cfg, run)
    B, S, D = x.shape
    positions = batch.get("positions")
    if positions is None:
        if decode and cache is not None and "attn" in cache:
            positions = cache["attn"]["pos"][0][:, None]       # [B,1]
        elif decode:
            positions = jnp.zeros((B, 1), jnp.int32)           # ssm: unused
        else:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = shard(x, "batch", "seq_shard" if not decode else "seq", "embed")

    layers = params["layers"]
    aux_acc: Dict[str, Any] = {}

    def add_aux(aux):
        for k, v in aux.items():
            v = jnp.sum(v)
            aux_acc[k] = aux_acc.get(k, 0.0) + v

    def body(xx, pl, flag, cl):
        return block_fn(pl, xx, positions=positions, local_flag=flag,
                        cache_layer=cl, decode=decode)

    def slice_layers(start, count, stride=1):
        if stride == 1:
            return jax.tree.map(lambda p: p[start:start + count], layers)
        return jax.tree.map(lambda p: p[start::stride][:count], layers)

    def cache_at(full_cache, idx):
        return jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, idx, 0,
                                                   keepdims=False),
            full_cache)

    def cache_set(full_cache, nc, idx):
        return jax.tree.map(
            lambda c, n: jax.lax.dynamic_update_index_in_dim(
                c, n.astype(c.dtype), idx, 0), full_cache, nc)

    new_cache = cache

    def run_single(x, new_cache, li, flag):
        pl = jax.tree.map(lambda p: p[li], layers)
        cl = cache_at(new_cache, li) if new_cache is not None else None
        y, nc, aux = _remat_wrap(
            lambda c, p_, cl_: body(c, p_, flag, cl_), run)(x, pl, cl)
        add_aux(aux)
        if new_cache is not None and nc is not None:
            new_cache = cache_set(new_cache, nc, li)
        return y, new_cache

    def run_scan(x, new_cache, start, count, flag, pair=False):
        if pair:
            xs = (slice_layers(0, count, stride=2),
                  slice_layers(1, count, stride=2))
        else:
            xs = (slice_layers(start, count),)

        if new_cache is None:
            def scan_fn(carry, pls):
                y = carry
                if pair:
                    y, aux1 = _remat_wrap(
                        lambda c, p_: _drop_cache(body(c, p_, True, None)),
                        run)(y, pls[0])
                    y, aux2 = _remat_wrap(
                        lambda c, p_: _drop_cache(body(c, p_, False, None)),
                        run)(y, pls[1])
                    return y, {**aux1, **{k + "_g": v
                                          for k, v in aux2.items()}}
                y, aux = _remat_wrap(
                    lambda c, p_: _drop_cache(body(c, p_, flag, None)),
                    run)(y, pls[0])
                return y, aux
            x, auxs = jax.lax.scan(scan_fn, x, xs)
            add_aux(auxs)
            return x, None
        else:
            def scan_fn(carry, pls):
                y, fc, idx = carry
                if pair:
                    for sub, (p_, fl) in enumerate(
                            zip(pls, (True, False))):
                        li = idx * 2 + sub
                        cl = cache_at(fc, li)
                        y, nc, aux = _remat_wrap(
                            lambda c, pp, cc, f=fl: body(c, pp, f, cc),
                            run)(y, p_, cl)
                        fc = cache_set(fc, nc, li)
                    return (y, fc, idx + 1), aux
                li = start + idx
                cl = cache_at(fc, li)
                y, nc, aux = _remat_wrap(
                    lambda c, pp, cc: body(c, pp, flag, cc), run)(y, pls[0],
                                                                  cl)
                fc = cache_set(fc, nc, li)
                return (y, fc, idx + 1), aux
            (x, fc, _), auxs = jax.lax.scan(
                scan_fn, (x, new_cache, jnp.int32(0)), xs)
            add_aux(auxs)
            return x, fc

    for group in layer_plan(cfg):
        if group[0] == "single":
            _, li, flag = group
            x, new_cache = run_single(x, new_cache, li, flag)
        elif group[0] == "pair_scan":
            _, count = group
            x, new_cache = run_scan(x, new_cache, 0, count, None, pair=True)
        else:
            _, start, count, flag = group
            x, new_cache = run_scan(x, new_cache, start, count, flag)

    aux = dict(aux_acc)
    x = rmsnorm(params["ln_final"], x, cfg.norm_eps)
    return x, new_cache, aux


def _drop_cache(t3):
    y, _, aux = t3
    return y, aux


def make_dense_block(cfg: ModelConfig, run: RunConfig):
    def block(pl, x, *, positions, local_flag, cache_layer, decode):
        cl = cache_layer["attn"] if cache_layer is not None else None
        y, nc, aux = block_apply(pl, x, cfg=cfg, run=run, positions=positions,
                                 local_flag=local_flag, cache_layer=cl,
                                 decode=decode)
        return y, ({"attn": nc} if nc is not None else None), aux
    return block


def forward_lm(params, batch, *, cfg: ModelConfig, run: RunConfig,
               cache=None, decode=False):
    """Dense/MoE/VLM decoder-only forward: (hidden, new_cache, aux)."""
    return forward_stack(params, batch, cfg=cfg, run=run,
                         block_fn=make_dense_block(cfg, run),
                         cache=cache, decode=decode)


def lm_logits(params, hidden, cfg: ModelConfig, run: RunConfig):
    """[.., D] -> [.., V] with optional final softcap (gemma2)."""
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = hidden @ w.astype(hidden.dtype)
    if cfg.attn.final_softcap is not None:
        c = cfg.attn.final_softcap
        logits = c * jnp.tanh(logits.astype(jnp.float32) / c)
    return logits
