"""Parameter definition trees.

A model is described once as a nested dict of ``PDef`` leaves (shape, logical
axes, initializer).  From that single source we derive:

  * materialized parameters           (``init_params``)
  * PartitionSpecs for pjit           (``spec_tree``)
  * ShapeDtypeStructs for the dry-run (``abstract_params`` — no allocation)

Logical axis names are resolved to mesh axes by ``repro.parallel.sharding``
rules, so the same model code runs on 1 CPU device and on a 512-chip mesh.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class PDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"            # normal | zeros | ones | scaled | small
    scale: float = 1.0              # multiplier on the initializer
    dtype: Optional[Any] = None     # override the tree-wide param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_pdef(x) -> bool:
    return isinstance(x, PDef)


def _tree_map(tree, fn, path=()):
    if is_pdef(tree):
        return fn(path, tree)
    if isinstance(tree, dict):
        return {k: _tree_map(v, fn, path + (k,)) for k, v in tree.items()}
    raise TypeError(f"bad pdef tree node at {path}: {type(tree)}")


def _leaf_seed(path: Tuple[str, ...]) -> int:
    # Deterministic per-leaf seed independent of dict iteration order.
    h = 0
    for p in path:
        for ch in str(p):
            h = (h * 1000003 + ord(ch)) % (2**31 - 1)
    return h


def _materialize(rng, pd: PDef, path, dtype):
    dt = pd.dtype or dtype
    key = jax.random.fold_in(rng, _leaf_seed(path))
    if pd.init == "zeros":
        return jnp.zeros(pd.shape, dt)
    if pd.init == "ones":
        return jnp.ones(pd.shape, dt)
    if pd.init == "normal":
        return (pd.scale * 0.02) * jax.random.normal(key, pd.shape, dt)
    if pd.init == "scaled":  # fan-in scaled (truncated-normal-ish)
        fan_in = pd.shape[0] if len(pd.shape) >= 2 else max(pd.shape[0], 1)
        std = pd.scale / np.sqrt(fan_in)
        return std * jax.random.normal(key, pd.shape, dt)
    if pd.init == "small":
        return (pd.scale * 1e-3) * jax.random.normal(key, pd.shape, dt)
    raise ValueError(pd.init)


def init_params(tree, rng, dtype=jnp.float32):
    return _tree_map(tree, lambda path, pd: _materialize(rng, pd, path, dtype))


def spec_tree(tree, rules):
    """PDef tree -> PartitionSpec tree via logical-axis rules.

    Divisibility-checked with row-parallel TP fallback (see
    Rules.pspec_checked): head counts that don't divide the model axis fall
    back to sharding d_model.
    """
    return _tree_map(
        tree,
        lambda path, pd: rules.pspec_checked(pd.shape, pd.axes,
                                             tp_fallback=True))


def abstract_params(tree, dtype, mesh=None, rules=None):
    """PDef tree -> ShapeDtypeStruct tree (optionally sharded) — dry-run input."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    def mk(path, pd):
        dt = pd.dtype or dtype
        if mesh is None:
            return jax.ShapeDtypeStruct(pd.shape, dt)
        spec = rules.pspec_checked(pd.shape, pd.axes, tp_fallback=True)
        return jax.ShapeDtypeStruct(pd.shape, dt, sharding=NamedSharding(mesh, spec))

    return _tree_map(tree, mk)


def stack_pdefs(tree, n: int, axis_name: Optional[str] = "layers"):
    """Prepend a stacking dim (for scan-over-layers) to every leaf."""
    return _tree_map(
        tree,
        lambda path, pd: PDef((n,) + pd.shape, (axis_name,) + pd.axes,
                              pd.init, pd.scale, pd.dtype),
    )


def count_params(tree) -> int:
    total = 0

    def add(path, pd):
        nonlocal total
        n = 1
        for s in pd.shape:
            n *= s
        total += n
        return pd

    _tree_map(tree, add)
    return total


def cast_tree(params, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        params)
