"""Mamba-2 (SSD — state-space duality) block.

Training/prefill uses the chunked matmul ("SSD") form from arXiv:2405.21060:
within a chunk the recurrence is expanded into attention-like matmuls (MXU
friendly); across chunks a small [H, P, N] state is carried by a scan.  Decode
is the O(1) recurrence step on a persistent (conv window, SSM state) cache.

A pure recurrent oracle (``ssd_reference``) is kept for tests: the chunked
form must match it to fp tolerance for every shape swept in tests/.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import PDef
from repro.parallel.sharding import shard


# ---------------------------------------------------------------------------
# Parameter defs
# ---------------------------------------------------------------------------

def def_mamba2(cfg: ModelConfig) -> Dict[str, Any]:
    s = cfg.ssm
    d = cfg.d_model
    di = cfg.d_inner
    nh = cfg.ssm_heads
    G, N = s.ngroups, s.state_dim
    conv_ch = di + 2 * G * N
    return {
        # in_proj -> [z (di), x (di), B (G*N), C (G*N), dt (nh)]
        "in_proj": PDef((d, 2 * di + 2 * G * N + nh), ("embed", "ssm_inner"),
                        init="scaled"),
        "conv_w": PDef((s.conv_dim, conv_ch), (None, "ssm_inner"), init="scaled"),
        "conv_b": PDef((conv_ch,), ("ssm_inner",), init="zeros"),
        "A_log": PDef((nh,), ("ssm_heads",), init="ones"),
        "dt_bias": PDef((nh,), ("ssm_heads",), init="zeros"),
        "D": PDef((nh,), ("ssm_heads",), init="ones"),
        "norm": PDef((di,), ("ssm_inner",), init="zeros"),
        "out_proj": PDef((di, d), ("ssm_inner", "embed"), init="scaled"),
    }


# ---------------------------------------------------------------------------
# Core SSD math
# ---------------------------------------------------------------------------

def _split_proj(cfg: ModelConfig, proj):
    s = cfg.ssm
    di, nh = cfg.d_inner, cfg.ssm_heads
    G, N = s.ngroups, s.state_dim
    z, xc, Bc, Cc, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1)
    return z, xc, Bc, Cc, dt


def _causal_conv(xBC, w, b, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv, width K.  xBC: [B,L,ch]; w: [K,ch].

    ``state``: [B, K-1, ch] trailing context (decode); returns (out, new_state).
    """
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = state.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)                # [B, L+K-1, ch]
    out = sum(xp[:, i:i + xBC.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    out = out + b[None, None, :]
    new_state = xp[:, -(K - 1):, :] if K > 1 else pad[:, :0]
    return jax.nn.silu(out), new_state


def ssd_chunked(x, dt, A, Bm, Cm, *, chunk: int,
                initial_state: Optional[jnp.ndarray] = None,
                return_state: bool = False):
    """Chunked SSD scan.

    x  : [B, L, H, P]   (inputs per head)
    dt : [B, L, H]      (positive step sizes, softplus+bias already applied)
    A  : [H]            (negative decay rates)
    Bm : [B, L, G, N]   Cm: [B, L, G, N]
    Returns y: [B, L, H, P] (+ final state [B,H,P,N] if requested).
    """
    B, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    if L % chunk != 0:
        # zero-pad to a chunk multiple: dt=0 rows are state-neutral
        # (decay = exp(0·A) = 1, contribution = dt·B⊗x = 0).
        pad = chunk - L % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        out = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk,
                          initial_state=initial_state,
                          return_state=return_state)
        if return_state:
            return out[0][:, :L], out[1]
        return out[:, :L]
    nc = L // chunk
    rep = H // G

    f32 = jnp.float32
    xc = x.reshape(B, nc, chunk, H, P)
    dtc = dt.reshape(B, nc, chunk, H).astype(f32)
    Bc = Bm.reshape(B, nc, chunk, G, N)
    Cc = Cm.reshape(B, nc, chunk, G, N)
    BcH = jnp.repeat(Bc, rep, axis=3)                        # [B,nc,Q,H,N]
    CcH = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A.astype(f32)[None, None, None, :]            # [B,nc,Q,H] (<=0)
    cum = jnp.cumsum(dA, axis=2)                             # within-chunk cumsum
    seg_total = cum[:, :, -1, :]                             # [B,nc,H]

    # --- intra-chunk (quadratic in chunk, matmul form) ----------------------
    # L_mat[i,j] = exp(cum_i - cum_j) for i>=j else 0
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # [B,nc,Q,Q,H]
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    Lmat = jnp.where(causal, jnp.exp(diff), 0.0)             # f32
    CB = jnp.einsum("bcihn,bcjhn->bcijh", CcH.astype(f32), BcH.astype(f32))
    W = CB * Lmat * dtc[:, :, None, :, :]                    # [B,nc,Q,Q,H]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", W, xc.astype(f32))

    # --- chunk states -------------------------------------------------------
    decay_to_end = jnp.exp(seg_total[:, :, None, :] - cum)   # [B,nc,Q,H]
    states = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn",
                        (decay_to_end * dtc), BcH.astype(f32), xc.astype(f32))

    # --- inter-chunk recurrence over nc -------------------------------------
    if initial_state is None:
        s0 = jnp.zeros((B, H, P, N), f32)
    else:
        s0 = initial_state.astype(f32)

    def step(s, inp):
        st, seg = inp                                        # [B,H,P,N], [B,H]
        s_out = s                                            # state entering chunk
        s = s * jnp.exp(seg)[:, :, None, None] + st
        return s, s_out

    sT, s_in = jax.lax.scan(step, s0,
                            (jnp.moveaxis(states, 1, 0),
                             jnp.moveaxis(seg_total, 1, 0)))
    s_in = jnp.moveaxis(s_in, 0, 1)                          # [B,nc,H,P,N]

    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp",
                         CcH.astype(f32) * jnp.exp(cum)[..., None], s_in)
    y = (y_intra + y_inter).reshape(B, L, H, P).astype(x.dtype)
    if return_state:
        return y, sT
    return y


def ssd_reference(x, dt, A, Bm, Cm, initial_state=None):
    """O(L) recurrent oracle (slow; tests only)."""
    B, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    f32 = jnp.float32
    s = jnp.zeros((B, H, P, N), f32) if initial_state is None else initial_state.astype(f32)
    BmH = jnp.repeat(Bm, rep, axis=2).astype(f32)
    CmH = jnp.repeat(Cm, rep, axis=2).astype(f32)

    def step(s, inp):
        xt, dtt, Bt, Ct = inp                                # [B,H,P],[B,H],[B,H,N]
        decay = jnp.exp(dtt * A[None, :])                    # [B,H]
        s = s * decay[:, :, None, None] + \
            jnp.einsum("bh,bhn,bhp->bhpn", dtt, Bt, xt)
        y = jnp.einsum("bhn,bhpn->bhp", Ct, s)
        return s, y

    sT, ys = jax.lax.scan(step, s,
                          (jnp.moveaxis(x, 1, 0).astype(f32),
                           jnp.moveaxis(dt, 1, 0).astype(f32),
                           jnp.moveaxis(BmH, 1, 0),
                           jnp.moveaxis(CmH, 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), sT


# ---------------------------------------------------------------------------
# Full block
# ---------------------------------------------------------------------------

def _gated_norm(p, y, z, eps=1e-6):
    y = y * jax.nn.silu(z)
    dt = y.dtype
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return ((yf * jax.lax.rsqrt(var + eps)) *
            (1.0 + p["norm"].astype(jnp.float32))).astype(dt)


def mamba2_block(p, x, *, cfg: ModelConfig,
                 cache: Optional[Dict[str, jnp.ndarray]] = None,
                 decode: bool = False):
    """x: [B,S,D] -> (out [B,S,D], new cache or None).

    cache = {"conv": [B, K-1, ch], "ssm": [B, H, P, N]}
    """
    s = cfg.ssm
    B, S, D = x.shape
    di, nh = cfg.d_inner, cfg.ssm_heads
    G, N, P_ = s.ngroups, s.state_dim, s.head_dim

    proj = x @ p["in_proj"].astype(x.dtype)
    z, xi, Bc, Cc, dt_raw = _split_proj(cfg, proj)
    xBC = jnp.concatenate([xi, Bc, Cc], axis=-1)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))   # [B,S,nh]

    if decode:
        assert cache is not None and S == 1
        xBC, conv_state = _causal_conv(xBC, p["conv_w"].astype(x.dtype),
                                       p["conv_b"].astype(x.dtype),
                                       state=cache["conv"])
        xi, Bc, Cc = jnp.split(xBC, [di, di + G * N], axis=-1)
        xh = xi.reshape(B, nh, P_)
        Bh = jnp.repeat(Bc.reshape(B, G, N), nh // G, axis=1)
        Ch = jnp.repeat(Cc.reshape(B, G, N), nh // G, axis=1)
        dt1 = dt[:, 0, :]                                    # [B,nh]
        decay = jnp.exp(dt1 * A[None, :])
        ssm = cache["ssm"].astype(jnp.float32)
        ssm = ssm * decay[:, :, None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dt1, Bh.astype(jnp.float32),
            xh.astype(jnp.float32))
        y = jnp.einsum("bhn,bhpn->bhp", Ch.astype(jnp.float32), ssm)
        y = y + p["D"].astype(jnp.float32)[None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(B, 1, di).astype(x.dtype)
        new_cache = {"conv": conv_state, "ssm": ssm}
    else:
        xBC, conv_tail = _causal_conv(xBC, p["conv_w"].astype(x.dtype),
                                      p["conv_b"].astype(x.dtype))
        xi, Bc, Cc = jnp.split(xBC, [di, di + G * N], axis=-1)
        xh = xi.reshape(B, S, nh, P_)
        xh = shard(xh, "batch", "seq", "act_ssm_heads", None)
        Bh = Bc.reshape(B, S, G, N)
        Ch = Cc.reshape(B, S, G, N)
        want_state = cache is not None
        out = ssd_chunked(xh, dt, A, Bh, Ch, chunk=min(s.chunk_size, S),
                          return_state=want_state)
        if want_state:
            y4, ssm_state = out
        else:
            y4 = out
        y4 = y4 + p["D"].astype(y4.dtype)[None, None, :, None] * xh
        y = y4.reshape(B, S, di)
        new_cache = None
        if want_state:
            new_cache = {"conv": conv_tail, "ssm": ssm_state}

    y = _gated_norm(p, y, z)
    return y @ p["out_proj"].astype(x.dtype), new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    ch = cfg.d_inner + 2 * s.ngroups * s.state_dim
    return {
        "conv": shard(jnp.zeros((batch, s.conv_dim - 1, ch), dtype),
                      "batch", None, "act_ssm_inner"),
        "ssm": shard(jnp.zeros((batch, cfg.ssm_heads, s.head_dim, s.state_dim),
                               jnp.float32),
                     "batch", "act_ssm_heads", None, None),
    }
