"""Modality frontend STUBS for the [audio]/[vlm] assigned architectures.

Per the assignment these entries specify the transformer BACKBONE only; the
modality frontend supplies precomputed frame/patch embeddings.  These helpers
build those embeddings (random for smoke tests, ShapeDtypeStructs for the
dry-run) plus the M-RoPE position streams for qwen2-vl.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def audio_frame_embeddings(rng, batch: int, frames: int, d_model: int,
                           dtype=jnp.float32):
    """Stub for the speech encoder frontend (fbank -> conformer adapter)."""
    return 0.02 * jax.random.normal(rng, (batch, frames, d_model), dtype)


def vision_patch_embeddings(rng, batch: int, patches: int, d_model: int,
                            dtype=jnp.float32):
    """Stub for the ViT patch-merger frontend (dynamic-resolution patches)."""
    return 0.02 * jax.random.normal(rng, (batch, patches, d_model), dtype)


def mrope_positions(batch: int, seq: int, *, grid: Tuple[int, int, int] = None):
    """M-RoPE (t, h, w) position streams, [3, B, S].

    Text tokens advance all three streams together; vision tokens advance
    (t, h, w) according to their patch-grid coordinates.  ``grid=(T,H,W)``
    places a T*H*W vision block at the start of the sequence, text after.
    """
    if grid is None:
        p = np.broadcast_to(np.arange(seq)[None], (batch, seq))
        return jnp.asarray(np.broadcast_to(p[None], (3, batch, seq)),
                           jnp.int32)
    T, H, W = grid
    n_vis = T * H * W
    assert n_vis <= seq, (grid, seq)
    t_ids = np.repeat(np.arange(T), H * W)
    h_ids = np.tile(np.repeat(np.arange(H), W), T)
    w_ids = np.tile(np.arange(W), T * H)
    # text continues after the max vision position
    start = max(T, H, W)
    text = np.arange(seq - n_vis) + start
    pos = np.stack([np.concatenate([t_ids, text]),
                    np.concatenate([h_ids, text]),
                    np.concatenate([w_ids, text])])          # [3, S]
    return jnp.asarray(np.broadcast_to(pos[:, None], (3, batch, seq)),
                       jnp.int32)
