"""Model factory: one uniform interface over all assigned architectures.

``build_model(cfg, run)`` returns a ``Model`` whose members close over the
config — everything downstream (train step, serve engine, dry-run, Synapse
profiler) is family-agnostic.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.run import RunConfig
from repro.models import encdec as encdec_lib
from repro.models import hybrid as hybrid_lib
from repro.models import transformer as tr
from repro.models.params import (abstract_params, count_params, init_params,
                                 spec_tree)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    run: RunConfig
    pdefs: Dict[str, Any]
    forward: Callable          # (params, batch, cache=None, decode=False)
    init_cache: Callable       # (batch, max_len[, src_len]) -> cache
    logits: Callable           # (params, hidden) -> logits

    def init(self, rng):
        return init_params(self.pdefs, rng, self.run.pdtype)

    def abstract(self, mesh=None, rules=None):
        return abstract_params(self.pdefs, self.run.pdtype, mesh, rules)

    def param_specs(self, rules):
        return spec_tree(self.pdefs, rules)

    def num_params(self) -> int:
        return count_params(self.pdefs)


def build_model(cfg: ModelConfig, run: RunConfig) -> Model:
    if cfg.family == "encdec":
        pdefs = encdec_lib.def_encdec(cfg)

        def forward(params, batch, cache=None, decode=False):
            return encdec_lib.forward_encdec(params, batch, cfg=cfg, run=run,
                                             cache=cache, decode=decode)

        def initc(batch, max_len, src_len=None):
            return encdec_lib.init_encdec_cache(
                cfg, run, batch, max_len, src_len or max_len)

    elif cfg.family == "ssm":
        pdefs = hybrid_lib.def_ssm_lm(cfg)
        block = hybrid_lib.make_ssm_block(cfg, run)

        def forward(params, batch, cache=None, decode=False):
            return tr.forward_stack(params, batch, cfg=cfg, run=run,
                                    block_fn=block, cache=cache, decode=decode)

        def initc(batch, max_len, src_len=None):
            del max_len
            return hybrid_lib.init_ssm_cache(cfg, run, batch)

    elif cfg.family == "hybrid":
        pdefs = hybrid_lib.def_hybrid_lm(cfg)
        block = hybrid_lib.make_hybrid_block(cfg, run)

        def forward(params, batch, cache=None, decode=False):
            return tr.forward_stack(params, batch, cfg=cfg, run=run,
                                    block_fn=block, cache=cache, decode=decode)

        def initc(batch, max_len, src_len=None):
            return hybrid_lib.init_hybrid_cache(cfg, run, batch, max_len)

    else:  # dense | moe | vlm (decoder-only transformer)
        pdefs = tr.def_lm(cfg)

        def forward(params, batch, cache=None, decode=False):
            return tr.forward_lm(params, batch, cfg=cfg, run=run,
                                 cache=cache, decode=decode)

        def initc(batch, max_len, src_len=None):
            return tr.init_cache(cfg, run, batch, max_len)

    def logits(params, hidden):
        return tr.lm_logits(params, hidden, cfg, run)

    return Model(cfg=cfg, run=run, pdefs=pdefs, forward=forward,
                 init_cache=initc, logits=logits)
