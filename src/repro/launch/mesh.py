"""Production mesh builders (assignment MULTI-POD DRY-RUN step 1).

Functions, not module-level constants, so importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def describe(mesh) -> dict:
    return {name: int(size) for name, size in
            zip(mesh.axis_names, mesh.devices.shape)}
