import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
                           + " " + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture × input shape) cell, lower + compile the cell's step
function on the production mesh — 16×16 single pod and 2×16×16 multi-pod —
and record ``memory_analysis()`` (proves it fits), ``cost_analysis()``, and
the Synapse static-watcher analysis (trip-count-aware FLOPs / HBM bytes /
collective wire bytes) into a JSON artifact per cell under
``experiments/artifacts/``.  The roofline table (EXPERIMENTS.md §Roofline)
is generated from these artifacts.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
import argparse
import dataclasses
import gc
import json
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cell_is_runnable, get_config, list_archs
from repro.obs import clock as obs_clock
from repro.configs.run import RunConfig, for_shape
from repro.core import hlo_analysis
from repro.launch.mesh import describe, make_production_mesh
from repro.launch.specs import (batch_specs, cache_specs, decode_token_specs,
                                input_specs, rules_table_for)
from repro.models.model_zoo import build_model
from repro.optim.adamw import OptConfig
from repro.parallel.sharding import make_rules
from repro.serve.step import make_decode_step, make_prefill_step
from repro.train.step import abstract_train_state, make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "experiments", "artifacts")


# Gradient-accumulation defaults for the big train cells: activations scale
# with tokens/microbatch, so temp memory divides by m (§Perf iteration 3).
TRAIN_MICROBATCHES = {
    "llama4-scout-17b-a16e": 4,
    "qwen2-72b": 4,
    "moonshot-v1-16b-a3b": 4,
    "hymba-1.5b": 2,            # banded-bwd dk/dv carries need headroom
}


def _run_config(shape, overrides=None, arch=None) -> RunConfig:
    run = for_shape(shape.kind)
    if shape.kind == "train" and arch in TRAIN_MICROBATCHES:
        run = dataclasses.replace(
            run, microbatches=TRAIN_MICROBATCHES[arch])
    if overrides:
        run = dataclasses.replace(run, **overrides)
    return run


def lower_cell(cfg, shape, mesh, run: RunConfig):
    """Build and lower the cell's step function; returns (lowered, meta)."""
    model = build_model(cfg, run)
    rules = make_rules(mesh, rules_table_for(shape, run))
    meta = {"params": model.num_params(),
            "active_params": cfg.active_param_count()}

    if shape.kind == "train":
        step = make_train_step(model, OptConfig(), mesh,
                               rules_table=rules_table_for(shape, run))
        state = abstract_train_state(model, mesh, rules)
        (batch,) = input_specs(cfg, shape, mesh, run)
        lowered = jax.jit(step, donate_argnums=0).lower(state, batch)
        tokens = shape.global_batch * shape.seq_len
        if cfg.family == "encdec":
            tokens //= 2          # src/tgt split: each stack sees seq/2
        meta["model_flops"] = 6.0 * meta["active_params"] * tokens
    elif shape.kind == "prefill":
        S = shape.seq_len
        max_len = S // 2 if cfg.family == "encdec" else S
        src_len = S // 2 if cfg.family == "encdec" else None
        step = make_prefill_step(model, max_len=max_len, src_len=src_len,
                                 mesh=mesh)
        (batch,) = input_specs(cfg, shape, mesh, run)
        lowered = jax.jit(step).lower(model.abstract(mesh, rules), batch)
        tokens = shape.global_batch * shape.seq_len
        if cfg.family == "encdec":
            tokens //= 2
        meta["model_flops"] = 2.0 * meta["active_params"] * tokens
    else:  # decode
        step = make_decode_step(model, mesh=mesh,
                                rules_table=rules_table_for(shape))
        toks, cache = input_specs(cfg, shape, mesh, run)
        lowered = jax.jit(step, donate_argnums=2).lower(
            model.abstract(mesh, rules), toks, cache)
        meta["model_flops"] = 2.0 * meta["active_params"] * shape.global_batch
    return lowered, meta


def analyze(lowered, compiled, mesh, meta):
    n_dev = mesh.devices.size
    out = dict(meta)
    out["n_devices"] = int(n_dev)
    out["mesh"] = describe(mesh)

    ma = compiled.memory_analysis()
    if ma is not None:
        out["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "code_bytes": int(ma.generated_code_size_in_bytes),
        }
        out["memory"]["per_device_total"] = (
            out["memory"]["argument_bytes"] + out["memory"]["output_bytes"]
            + out["memory"]["temp_bytes"] - out["memory"]["alias_bytes"])

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):         # older jaxlib: list per device
        ca = ca[0] if ca else None
    if ca:
        out["xla_cost"] = {"flops": float(ca.get("flops", -1)),
                           "bytes_accessed": float(ca.get("bytes accessed", -1))}

    t0 = obs_clock.now()
    cost = hlo_analysis.analyze_hlo(compiled.as_text())
    out["walker"] = {
        "flops": cost.flops,
        "transcendentals": cost.transcendentals,
        "hbm_bytes": cost.hbm_bytes,
        "dot_bytes": cost.dot_bytes,
        "collective_bytes": cost.collective_bytes(),
        "collective_total": cost.collective_total,
        "collective_by_axis": hlo_analysis.attribute_axes(
            cost, describe(mesh)),
        "analysis_s": obs_clock.now() - t0,
        "top_ops": sorted(cost.op_flops.items(), key=lambda kv: -kv[1])[:12],
    }
    out["useful_flops_ratio"] = (
        meta["model_flops"] / (cost.flops * n_dev) if cost.flops else None)
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             overrides=None, tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    name = f"{arch}__{shape_name}__{mesh_tag}" + (f"__{tag}" if tag else "")
    record = {"arch": arch, "shape": shape_name, "mesh_tag": mesh_tag,
              "tag": tag, "ok": False}

    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        record.update({"skipped": True, "skip_reason": why, "ok": True})
        _write(out_dir, name, record)
        return record

    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        run = _run_config(shape, overrides, arch=arch)
        record["run_config"] = dataclasses.asdict(run)
        t0 = obs_clock.now()
        lowered, meta = lower_cell(cfg, shape, mesh, run)
        record["lower_s"] = obs_clock.now() - t0
        t0 = obs_clock.now()
        compiled = lowered.compile()
        record["compile_s"] = obs_clock.now() - t0
        record.update(analyze(lowered, compiled, mesh, meta))
        record["ok"] = True
        del compiled, lowered
        gc.collect()
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    _write(out_dir, name, record)
    return record


def _write(out_dir, name, record):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(record, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--override", default="",
                    help="comma k=v RunConfig overrides (ints/bools/strs)")
    args = ap.parse_args()

    overrides = {}
    for kv in filter(None, args.override.split(",")):
        k, v = kv.split("=")
        if v in ("True", "False"):
            v = v == "True"
        else:
            try:
                v = int(v)
            except ValueError:
                pass
        overrides[k] = v

    cells = []
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    for a, s, mp in cells:
        mesh_tag = "2x16x16" if mp else "16x16"
        name = f"{a}__{s}__{mesh_tag}" + (f"__{args.tag}" if args.tag else "")
        path = os.path.join(args.out, name + ".json")
        if args.skip_existing and os.path.exists(path):
            with open(path) as f:
                if json.load(f).get("ok"):
                    print(f"[skip] {name}")
                    continue
        t0 = obs_clock.now()
        rec = run_cell(a, s, mp, args.out, overrides or None, args.tag)
        status = "SKIP(" + rec.get("skip_reason", "")[:40] + ")" \
            if rec.get("skipped") else ("ok" if rec["ok"] else
                                        "FAIL " + rec.get("error", "")[:120])
        print(f"[{obs_clock.now()-t0:7.1f}s] {name}: {status}", flush=True)


if __name__ == "__main__":
    main()
