"""ShapeDtypeStruct stand-ins for every model input (assignment step 2).

``input_specs(cfg, shape, mesh, run)`` returns sharded, weak-type-correct
abstract inputs for the (arch × shape) cell — a training batch, a prefill
request batch, or a decode step (tokens + KV/SSM cache) — with no device
allocation.  ``rules_for_shape`` picks the rule table (train / prefill /
decode / long-decode context-parallel).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs.run import RunConfig
from repro.models.model_zoo import Model, build_model
from repro.parallel.sharding import (DECODE_RULES, LONG_DECODE_RULES,
                                     PREFILL_RULES, TRAIN_RULES, Rules,
                                     make_rules, use_sharding)


def rules_table_for(shape: ShapeConfig, run: Optional[RunConfig] = None) -> Dict:
    if shape.kind == "train":
        if run is not None and run.sharding_mode == "fsdp":
            from repro.parallel.sharding import FSDP_RULES
            return FSDP_RULES
        return TRAIN_RULES
    if shape.kind == "prefill":
        return PREFILL_RULES
    if shape.name == "long_500k":
        return LONG_DECODE_RULES
    return DECODE_RULES


def _sds(shape, dtype, mesh, rules: Optional[Rules], *axes):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    spec = rules.pspec_checked(tuple(shape), axes)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, rules,
                run: RunConfig) -> Dict[str, Any]:
    """Abstract train/prefill batch for this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32, cdt = jnp.int32, run.cdtype
    out: Dict[str, Any] = {}
    if cfg.family == "encdec":
        out["src_embeds"] = _sds((B, S // 2, cfg.d_model), cdt, mesh, rules,
                                 "batch", "seq", None)
        out["tgt_tokens"] = _sds((B, S // 2), i32, mesh, rules, "batch", "seq")
        if shape.kind == "train":
            out["targets"] = _sds((B, S // 2), i32, mesh, rules, "batch", "seq")
        return out
    if cfg.frontend == "vision_patches":
        out["embeds"] = _sds((B, S, cfg.d_model), cdt, mesh, rules,
                             "batch", "seq", None)
        out["positions"] = _sds((3, B, S), i32, mesh, rules,
                                None, "batch", "seq")
    elif cfg.frontend == "audio_frames":
        out["embeds"] = _sds((B, S, cfg.d_model), cdt, mesh, rules,
                             "batch", "seq", None)
    else:
        out["tokens"] = _sds((B, S), i32, mesh, rules, "batch", "seq")
    if shape.kind == "train":
        out["targets"] = _sds((B, S), i32, mesh, rules, "batch", "seq")
    return out


# ---------------------------------------------------------------------------
# Cache specs (decode cells): eval_shape the init then attach shardings
# ---------------------------------------------------------------------------

_CACHE_AXES = {
    # leaf key -> logical axes per rank (stacked leading layer dim)
    "k": {5: (None, "batch", "cache_seq", None, "head_dim")},
    "v": {5: (None, "batch", "cache_seq", None, "head_dim")},
    "pos": {2: (None, "batch")},
    "conv": {4: (None, "batch", None, "act_ssm_inner")},
    "ssm": {5: (None, "batch", "act_ssm_heads", None, None)},
    "cross_k": {5: (None, "batch", "cache_seq", None, "head_dim")},
    "cross_v": {5: (None, "batch", "cache_seq", None, "head_dim")},
}


def cache_specs(model: Model, batch: int, max_len: int, mesh, rules,
                src_len: Optional[int] = None):
    if model.cfg.family == "encdec":
        shapes = jax.eval_shape(
            lambda: model.init_cache(batch, max_len, src_len=src_len))
    else:
        shapes = jax.eval_shape(lambda: model.init_cache(batch, max_len))

    def attach(path, aval):
        key = None
        for p in reversed(path):
            if hasattr(p, "key"):
                key = p.key
                break
        axes_by_rank = _CACHE_AXES.get(key, {})
        axes = axes_by_rank.get(len(aval.shape),
                                tuple([None] * len(aval.shape)))
        return _sds(aval.shape, aval.dtype, mesh, rules, *axes)

    return jax.tree_util.tree_map_with_path(attach, shapes)


def decode_token_specs(cfg: ModelConfig, batch: int, mesh, rules):
    return _sds((batch, 1), jnp.int32, mesh, rules, "batch", "seq")


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, run: RunConfig):
    """Full abstract inputs for the cell's step function.

    train  -> (state_specs_handled_elsewhere, batch)
    prefill-> (batch,)
    decode -> (tokens, cache)
    """
    rules = make_rules(mesh, rules_table_for(shape, run))
    if shape.kind in ("train", "prefill"):
        return (batch_specs(cfg, shape, mesh, rules, run),)
    # decode: cache sized to seq_len, batch of single tokens
    model = build_model(cfg, run)
    B, S = shape.global_batch, shape.seq_len
    src_len = S // 2 if cfg.family == "encdec" else None
    max_len = S // 2 if cfg.family == "encdec" else S
    cache = cache_specs(model, B, max_len, mesh, rules, src_len=src_len)
    toks = decode_token_specs(cfg, B, mesh, rules)
    return (toks, cache)
