"""Llama-4 Scout 17B-A16E: 48L d=5120 40H (kv=8) MoE 16e top-1 + shared expert.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified] — MoE, early fusion.
"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,                 # shared-expert FFN width
    vocab_size=202048,
    moe=MoEConfig(num_experts=16, top_k=1, d_ff_expert=8192, shared_expert=True),
    attn=AttnConfig(rope_theta=5e5),
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
))
