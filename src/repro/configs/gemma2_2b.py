"""Gemma2-2B: 26L d=2304 8H (kv=4) ff=9216, local/global alternating + softcaps.

[arXiv:2408.00118; hf] — head_dim=256 (independent of d_model), window 4096.
"""
from repro.configs.base import AttnConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    tie_embeddings=True,
    sandwich_norms=True,
    embed_scale=True,
    attn=AttnConfig(logit_softcap=50.0, final_softcap=30.0,
                    sliding_window=4096, layer_pattern="local_global",
                    rope_theta=1e4),
    source="arXiv:2408.00118",
))
