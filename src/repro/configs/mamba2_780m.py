"""Mamba2-780M: 48L d=1536 attention-free, SSD state=128. [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_dim=4,
                  chunk_size=256, ngroups=1),
    source="arXiv:2405.21060",
))
