"""Qwen2-72B: 80L d=8192 64H (kv=8) ff=29568. GQA + QKV bias. [arXiv:2407.10671; hf]"""
from repro.configs.base import AttnConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    attn=AttnConfig(qkv_bias=True, rope_theta=1e6),
    source="arXiv:2407.10671",
))
