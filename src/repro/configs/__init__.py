from repro.configs.base import (  # noqa: F401
    SHAPES, AttnConfig, ModelConfig, MoEConfig, ShapeConfig, SSMConfig,
    cell_is_runnable, get_config, list_archs, reduced_config, register,
)
