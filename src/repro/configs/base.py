"""Configuration system: model architectures, input shapes, run settings.

Every assigned architecture is a frozen ``ModelConfig``; every assigned input
shape is a ``ShapeConfig``.  The cross product (minus documented skips) is the
40-cell dry-run/roofline matrix.  Configs are plain frozen dataclasses so they
hash, compare, and serialize trivially (the Synapse profile store keys off
them as "tags", mirroring the paper's command+tag indexing).
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    shared_expert: bool = False          # llama4 has a shared expert alongside routed
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) settings."""
    state_dim: int = 128                 # N
    head_dim: int = 64                   # P
    expand: int = 2                      # d_inner = expand * d_model
    conv_dim: int = 4                    # depthwise causal conv width
    chunk_size: int = 256                # SSD chunk length (matmul form)
    ngroups: int = 1                     # B/C groups


@dataclass(frozen=True)
class AttnConfig:
    qkv_bias: bool = False               # qwen2 family uses bias on qkv
    logit_softcap: Optional[float] = None  # gemma2: 50.0 on attn logits
    final_softcap: Optional[float] = None  # gemma2: 30.0 on lm logits
    sliding_window: Optional[int] = None   # local attention window (tokens)
    # layer_pattern: 'global' | 'local_global' (alternating, gemma2)
    #                | 'hymba' (3 global layers, rest sliding window)
    layer_pattern: str = "global"
    rope_theta: float = 1e6
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                          # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                       # 0 for attention-free archs
    num_kv_heads: int
    d_ff: int                            # dense FFN width (0 if pure MoE / ssm)
    vocab_size: int
    head_dim: int = 128
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn: AttnConfig = field(default_factory=AttnConfig)
    tie_embeddings: bool = False
    sandwich_norms: bool = False         # gemma2: post-attn/post-ffn extra norms
    embed_scale: bool = False            # gemma2/seamless: x *= sqrt(d_model)
    norm_eps: float = 1e-6
    # enc-dec only:
    num_encoder_layers: int = 0
    # modality frontend stub: 'none' | 'audio_frames' | 'vision_patches'
    frontend: str = "none"
    source: str = ""                     # provenance tag from the assignment

    # ---- derived ----------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if decode over very long context is linear-ish (long_500k gate)."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return (self.ssm.expand * self.d_model) if self.ssm else 0

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm.head_dim if self.ssm else 0

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-flops accounting)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        total = V * d                                    # embedding
        if not self.tie_embeddings:
            total += V * d                               # lm head
        per_layer = 0
        if self.family != "ssm":
            # attention
            hq, hk, hd = self.num_heads, self.num_kv_heads, self.head_dim
            per_layer += d * hq * hd + 2 * d * hk * hd + hq * hd * d
            if self.attn.qkv_bias:
                per_layer += (hq + 2 * hk) * hd
        if self.ssm is not None:
            di, N, P = self.d_inner, self.ssm.state_dim, self.ssm.head_dim
            nh, G = self.ssm_heads, self.ssm.ngroups
            # in_proj -> [z, x, B, C, dt], conv over (x,B,C), out_proj
            per_layer += d * (2 * di + 2 * G * N + nh)
            per_layer += (di + 2 * G * N) * self.ssm.conv_dim
            per_layer += di * d + nh + nh  # out_proj, A_log, D
        if self.moe is not None:
            e, f = self.moe.num_experts, self.moe.d_ff_expert
            per_layer += d * e                            # router
            per_layer += e * (3 * d * f)                  # gate/up/down per expert
            if self.moe.shared_expert:
                per_layer += 3 * d * self.d_ff
        elif self.d_ff > 0:
            per_layer += 3 * d * self.d_ff                # swiglu
        per_layer += 2 * d                                # norms
        total += L * per_layer
        if self.num_encoder_layers:
            # encoder layers: self-attn + mlp; decoder layers already counted,
            # add cross-attention for decoder layers
            hq, hk, hd = self.num_heads, self.num_kv_heads, self.head_dim
            enc_layer = (d * hq * hd + 2 * d * hk * hd + hq * hd * d
                         + 3 * d * self.d_ff + 2 * d)
            total += self.num_encoder_layers * enc_layer
            cross = d * hq * hd + 2 * d * hk * hd + hq * hd * d + d
            total += L * cross
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k routed + shared)."""
        if self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        e, k, f = self.moe.num_experts, self.moe.top_k, self.moe.d_ff_expert
        inactive_experts_per_layer = (e - k) * (3 * d * f)
        return self.param_count() - L * inactive_experts_per_layer

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                            # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k gate per the assignment + DESIGN.md §4."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        if cfg.name == "gemma2-2b":
            return False, "alternating local/global: global layers are full attention (not sub-quadratic)"
        if cfg.family == "encdec":
            return False, "enc-dec: quadratic encoder self-attention over 512k source frames"
        return False, "pure full-attention arch: long_500k requires sub-quadratic attention"
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    # Import every config module once, which registers its arch.
    if _REGISTRY:
        return
    from repro.configs import (  # noqa: F401
        llama4_scout_17b_a16e, moonshot_v1_16b_a3b, qwen2_7b, qwen2_72b,
        gemma2_2b, qwen2_1_5b, seamless_m4t_medium, qwen2_vl_2b,
        mamba2_780m, hymba_1_5b,
    )


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (per the assignment)."""
    kw = dict(
        name=cfg.name + "-smoke",
        family=cfg.family,
        num_layers=2,
        d_model=64,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=min(2, cfg.num_kv_heads) if cfg.num_kv_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        head_dim=16 if cfg.num_heads else cfg.head_dim,
        attn=dataclasses.replace(
            cfg.attn,
            sliding_window=8 if cfg.attn.sliding_window else None,
            mrope_sections=(2, 3, 3) if cfg.attn.mrope_sections else None,
        ),
        tie_embeddings=cfg.tie_embeddings,
        num_encoder_layers=2 if cfg.num_encoder_layers else 0,
        frontend=cfg.frontend,
        source="smoke",
    )
    if cfg.moe:
        kw["moe"] = MoEConfig(num_experts=4, top_k=min(2, cfg.moe.top_k),
                              d_ff_expert=64, shared_expert=cfg.moe.shared_expert,
                              capacity_factor=2.0)
        kw["d_ff"] = 128 if cfg.moe.shared_expert else 0
    if cfg.ssm:
        kw["ssm"] = SSMConfig(state_dim=16, head_dim=16, expand=2,
                              conv_dim=4, chunk_size=8, ngroups=1)
    return ModelConfig(**kw)
