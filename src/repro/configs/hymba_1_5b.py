"""Hymba-1.5B: 32L d=1600 25H (kv=5) ff=5504, parallel attn+mamba heads.

[arXiv:2411.13676; hf] — hybrid heads per layer; 3 full-attention layers
(first/middle/last), sliding window elsewhere; ssm_state=16.
"""
from repro.configs.base import AttnConfig, ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm=SSMConfig(state_dim=16, head_dim=64, expand=2, conv_dim=4,
                  chunk_size=256, ngroups=1),
    attn=AttnConfig(sliding_window=2048, layer_pattern="hymba", rope_theta=1e4),
    source="arXiv:2411.13676",
))
