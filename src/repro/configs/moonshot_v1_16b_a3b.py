"""Moonlight 16B-A3B (kimi/moonshot): 48L d=2048 16H MoE 64e top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf]
"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=0,                    # routed experts only (plus shared)
    vocab_size=163840,
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408, shared_expert=False),
    attn=AttnConfig(rope_theta=5e4),
    source="hf:moonshotai/Moonlight-16B-A3B",
))
