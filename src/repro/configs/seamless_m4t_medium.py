"""SeamlessM4T-medium backbone: 12L enc + 12L dec, d=1024 16H ff=4096.

[arXiv:2308.11596; hf] — enc-dec, multimodal; audio frontend is a STUB
(input_specs feeds precomputed frame embeddings per the assignment).
"""
from repro.configs.base import AttnConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,             # decoder layers
    num_encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    frontend="audio_frames",
    embed_scale=True,
    attn=AttnConfig(rope_theta=1e4),
    source="arXiv:2308.11596",
))
