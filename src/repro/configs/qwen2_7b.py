"""Qwen2-7B: 28L d=3584 28H (kv=4) ff=18944. GQA + QKV bias. [arXiv:2407.10671; hf]"""
from repro.configs.base import AttnConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    attn=AttnConfig(qkv_bias=True, rope_theta=1e6),
    source="arXiv:2407.10671",
))
