"""Run settings orthogonal to the architecture: dtypes, remat, attention impl,
loss chunking, parallelism toggles.  These are the hillclimb knobs — §Perf in
EXPERIMENTS.md iterates on them per cell.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

import jax.numpy as jnp

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
           "float16": jnp.float16}


@dataclass(frozen=True)
class RunConfig:
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    cache_dtype: str = "bfloat16"
    # attention
    attn_impl: str = "auto"              # auto | full | blocked | pallas
    block_q: int = 512
    block_kv: int = 1024
    blocked_threshold: int = 2048
    skip_attn_blocks: bool = False       # static causal block skipping
    # memory / remat
    remat: str = "full"                  # none | dots | full
    loss_chunk: int = 512                # 0 = unchunked [B,S,V] logits
    # optimizer / distribution
    zero1: bool = True                   # shard optimizer state over 'data'
    sharding_mode: str = "megatron"      # megatron (TP+SP+FSDP) | fsdp (ZeRO-3 only)
    grad_compression: str = "none"       # none | int8_ef
    microbatches: int = 1
    pipeline_stages: int = 1             # >1 routes pod axis to pipeline
    # moe
    moe_dense_smoke: bool = False        # tiny-model testing aid
    # serving
    max_cache_len: int = 0               # 0 = derived from shape

    @property
    def pdtype(self):
        return _DTYPES[self.param_dtype]

    @property
    def cdtype(self):
        return _DTYPES[self.compute_dtype]

    @property
    def kvdtype(self):
        return _DTYPES[self.cache_dtype]


TRAIN_RUN = RunConfig()
SERVE_RUN = RunConfig(param_dtype="bfloat16", remat="none")


def for_shape(shape_kind: str) -> RunConfig:
    return TRAIN_RUN if shape_kind == "train" else SERVE_RUN
