"""Qwen2-1.5B: 28L d=1536 12H (kv=2) ff=8960. GQA + QKV bias. [arXiv:2407.10671; hf]"""
from repro.configs.base import AttnConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    tie_embeddings=True,
    attn=AttnConfig(qkv_bias=True, rope_theta=1e6),
    source="arXiv:2407.10671",
))
