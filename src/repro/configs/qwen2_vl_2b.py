"""Qwen2-VL-2B backbone: 28L d=1536 12H (kv=2) ff=8960, M-RoPE.

[arXiv:2409.12191; hf] — vision frontend is a STUB (precomputed patch
embeddings + 3-stream M-RoPE position ids via input_specs).
"""
from repro.configs.base import AttnConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    tie_embeddings=True,
    frontend="vision_patches",
    attn=AttnConfig(qkv_bias=True, rope_theta=1e6,
                    mrope_sections=(16, 24, 24)),   # t/h/w splits of head_dim/2
    source="arXiv:2409.12191",
))
