"""Synthetic scenario engine: generators that emit SynapseProfiles directly.

The registry (``base``) plus one module per scenario family — importing this
package registers them all:

  * ``training_scan``     — identical train steps + periodic checkpoint bursts
  * ``serving_traffic``   — Poisson arrivals over prefill/decode rooflines
  * ``fanout_straggler``  — N parallel workers, one tail-latency outlier
  * ``retry_storm``       — flaky work re-consumed under exponential backoff
  * ``mixed_fleet``       — weighted blend of the families above
  * ``dag_diamond``       — fork-join diamond, one seeded straggler branch
  * ``deep_chain``        — deep sequential chain, all critical path

``algebra`` composes profiles (``concat``/``overlay``/``scale``) and
structures them as dependency DAGs (``WorkloadDag`` via ``chain``/
``fork_join``) — feed a ``WorkloadDag`` to ``Emulator.emulate_many``
(process/remote) for frontier-scheduled replay with critical-path
metrics in ``FleetReport.dag``.

``driver.run_scenario`` wires a scenario end-to-end
(generate -> predict -> emulate -> store); ``driver.run_fleet`` replays many
concurrently through ``Emulator.emulate_many`` — on worker threads, or on
the process-level fleet executor (``repro.fleet``) via
``executor="process"``.  ``python -m repro.scenarios list|run|fleet`` is
the command-line front door (see ``__main__``).
"""
from repro.scenarios import dag, fanout, mixed, retry, serving, training  # noqa
from repro.scenarios.algebra import (DagNode, WorkloadDag,  # noqa
                                     chain, concat, fork_join, overlay,
                                     scale)
from repro.scenarios.base import (ScenarioSpec, generate,  # noqa
                                  get_scenario, list_scenarios, register,
                                  validate)
from repro.scenarios.driver import (DEFAULT_SPECS, FleetResult,  # noqa
                                    ScenarioResult, run_fleet, run_scenario)
