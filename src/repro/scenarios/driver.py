"""End-to-end scenario driver: generate -> predict -> emulate -> store.

``run_scenario`` pushes one synthesized profile through the paper's whole
lifecycle on machines we do have (emulation atoms) and machines we don't
(roofline prediction via ``predictor.compare``), then persists it to a
``ProfileStore`` under its scenario tags.  ``run_fleet`` does the same for a
batch of scenarios and replays them concurrently through
``Emulator.emulate_many`` with a shared plan cache.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.emulator import UNSET, EmulationReport, Emulator, FleetReport
from repro.core.hardware import (HOST_I7_M620, HOST_STAMPEDE_NODE, TPU_V5E,
                                 HardwareSpec)
from repro.core.metrics import SynapseProfile
from repro.core.predictor import compare, predict_fleet
from repro.core.store import ProfileStore
from repro.scenarios.base import generate

DEFAULT_SPECS = [TPU_V5E, HOST_I7_M620, HOST_STAMPEDE_NODE]


@dataclass
class ScenarioResult:
    name: str
    profile: SynapseProfile
    predictions: Dict[str, Dict]             # hw name -> compare() row
    report: Optional[EmulationReport] = None
    run_id: Optional[str] = None

    def summary(self) -> Dict:
        out = {"scenario": self.name, "n_samples": len(self.profile.samples),
               "gflops": self.profile.totals.flops / 1e9,
               "predictions": self.predictions}
        if self.report is not None:
            out["emulated_ttc_s"] = self.report.ttc_s
        if self.run_id is not None:
            out["run_id"] = self.run_id
        return out


def run_scenario(name: str, *, store: Optional[ProfileStore] = None,
                 specs: Optional[Sequence[HardwareSpec]] = None,
                 emulator: Optional[Emulator] = None, emulate: bool = True,
                 fused: bool = True, **params) -> ScenarioResult:
    """Generate one scenario, predict it across hardware, emulate it here,
    and (optionally) persist it under its scenario tags.  ``fused`` selects
    the schedule-compiler replay path (O(segments) dispatches); pass False
    to force the legacy per-sample loop."""
    profile = generate(name, **params)
    predictions = compare(profile, list(specs or DEFAULT_SPECS))
    profile.meta["predictions"] = predictions    # persisted with the profile
    report = None
    if emulate:
        report = (emulator or Emulator()).emulate(profile, fused=fused)
        profile.meta["emulated_ttc_s"] = report.ttc_s
    run_id = store.add(profile) if store is not None else None
    return ScenarioResult(name=name, profile=profile, predictions=predictions,
                          report=report, run_id=run_id)


@dataclass
class FleetResult:
    results: List[ScenarioResult]
    fleet: FleetReport
    predictions: Dict = field(default_factory=dict)  # predict_fleet() row
    n_streamed: int = 0                  # profiles pulled from ``profiles``


def run_fleet(jobs: Sequence[Tuple[str, Dict]] = (), *,
              profiles: Optional[Iterable[SynapseProfile]] = None,
              store: Optional[ProfileStore] = None,
              hw: HardwareSpec = TPU_V5E,
              specs: Optional[Sequence[HardwareSpec]] = None,
              emulator: Optional[Emulator] = None,
              fused: bool = True, config=None, collect: str = "reports",
              # legacy fleet kwargs — fold into FleetConfig + warning
              max_workers=UNSET, executor=UNSET, mesh_spec=UNSET,
              hosts=UNSET, listen=UNSET, agents=UNSET,
              timeout=UNSET) -> FleetResult:
    """Synthesize and/or pull a fleet of profiles and replay it concurrently.

    ``jobs`` is a sequence of (scenario_name, params) pairs.  ``profiles``
    feeds the fleet from pre-built profiles instead of (or in addition to)
    generators — typically ``ProfileStore.stream(tags)``, the replay-a-
    captured-day path.  The whole pipeline is *lazy end-to-end*: jobs are
    generated/predicted and stored profiles pulled off disk only as the
    fleet's compile-ahead window drains, so a production day streams
    through the executor at bounded coordinator memory instead of being
    drained into a job list first.  Streamed profiles reuse any
    predictions persisted in their meta and are not re-stored (they
    usually came from ``store``); generated profiles are stored only after
    emulation so the persisted meta carries ``emulated_ttc_s`` exactly
    like single ``run_scenario`` calls.

    ``config`` (a ``repro.fleet.FleetConfig``) selects and shapes the
    fleet backend — thread pool, local ``ProcessFleet`` worker processes,
    or a ``RemoteFleet`` of TCP host agents — including the compile-ahead
    ``window`` and ``autoscale`` elasticity; the legacy
    ``executor``/``max_workers``/``mesh_spec``/``hosts``/``listen``/
    ``agents``/``timeout`` kwargs still work but fold into a FleetConfig
    under a DeprecationWarning.  ``collect="totals"`` drops per-profile
    results/reports and returns aggregates only (``FleetResult.results``
    stays empty) — the bounded-memory mode for unbounded streams.
    """
    from repro.fleet.config import FleetConfig
    # fold (and config-validate) before paying generate/predict cost
    cfg = FleetConfig.fold(
        config,
        dict(max_workers=max_workers, executor=executor, mesh_spec=mesh_spec,
             hosts=hosts, listen=listen, agents=agents, timeout=timeout),
        caller="run_fleet")
    if not jobs and profiles is None:
        raise ValueError("run_fleet needs jobs and/or profiles to replay")
    capture = collect == "reports"
    results: List[ScenarioResult] = []   # grows as the fleet pulls
    n_streamed = 0

    def _source():
        nonlocal n_streamed
        for name, params in jobs:
            r = run_scenario(name, emulate=False, specs=specs, **params)
            if capture:
                results.append(r)
            yield r.profile
        for p in (profiles or ()):
            n_streamed += 1
            if capture:
                results.append(
                    ScenarioResult(name=p.tags.get("scenario", p.command),
                                   profile=p,
                                   predictions=p.meta.get("predictions", {})))
            yield p

    em = emulator or Emulator()
    fleet = em.emulate_many(_source(), fused=fused, config=cfg,
                            collect=collect)
    if fleet.n_profiles == 0:
        raise ValueError("run_fleet needs jobs and/or profiles to replay "
                         "(the profile stream was empty)")
    # ReportFold emits reports in source order, so they zip with results
    n_generated = len(jobs)
    for i, (r, rep) in enumerate(zip(results, fleet.reports)):
        r.report = rep
        r.profile.meta["emulated_ttc_s"] = rep.ttc_s
        if store is not None and i < n_generated:
            r.run_id = store.add(r.profile)
    return FleetResult(results=results, fleet=fleet,
                       predictions=predict_fleet(
                           [r.profile for r in results], hw)
                       if capture else {},
                       n_streamed=n_streamed)
