"""End-to-end scenario driver: generate -> predict -> emulate -> store.

``run_scenario`` pushes one synthesized profile through the paper's whole
lifecycle on machines we do have (emulation atoms) and machines we don't
(roofline prediction via ``predictor.compare``), then persists it to a
``ProfileStore`` under its scenario tags.  ``run_fleet`` does the same for a
batch of scenarios and replays them concurrently through
``Emulator.emulate_many`` with a shared plan cache.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.emulator import EmulationReport, Emulator, FleetReport
from repro.core.hardware import (HOST_I7_M620, HOST_STAMPEDE_NODE, TPU_V5E,
                                 HardwareSpec)
from repro.core.metrics import SynapseProfile
from repro.core.predictor import compare, predict_fleet
from repro.core.store import ProfileStore
from repro.scenarios.base import generate

DEFAULT_SPECS = [TPU_V5E, HOST_I7_M620, HOST_STAMPEDE_NODE]


@dataclass
class ScenarioResult:
    name: str
    profile: SynapseProfile
    predictions: Dict[str, Dict]             # hw name -> compare() row
    report: Optional[EmulationReport] = None
    run_id: Optional[str] = None

    def summary(self) -> Dict:
        out = {"scenario": self.name, "n_samples": len(self.profile.samples),
               "gflops": self.profile.totals.flops / 1e9,
               "predictions": self.predictions}
        if self.report is not None:
            out["emulated_ttc_s"] = self.report.ttc_s
        if self.run_id is not None:
            out["run_id"] = self.run_id
        return out


def run_scenario(name: str, *, store: Optional[ProfileStore] = None,
                 specs: Optional[Sequence[HardwareSpec]] = None,
                 emulator: Optional[Emulator] = None, emulate: bool = True,
                 fused: bool = True, **params) -> ScenarioResult:
    """Generate one scenario, predict it across hardware, emulate it here,
    and (optionally) persist it under its scenario tags.  ``fused`` selects
    the schedule-compiler replay path (O(segments) dispatches); pass False
    to force the legacy per-sample loop."""
    profile = generate(name, **params)
    predictions = compare(profile, list(specs or DEFAULT_SPECS))
    profile.meta["predictions"] = predictions    # persisted with the profile
    report = None
    if emulate:
        report = (emulator or Emulator()).emulate(profile, fused=fused)
        profile.meta["emulated_ttc_s"] = report.ttc_s
    run_id = store.add(profile) if store is not None else None
    return ScenarioResult(name=name, profile=profile, predictions=predictions,
                          report=report, run_id=run_id)


@dataclass
class FleetResult:
    results: List[ScenarioResult]
    fleet: FleetReport
    predictions: Dict = field(default_factory=dict)  # predict_fleet() row


def run_fleet(jobs: Sequence[Tuple[str, Dict]], *,
              store: Optional[ProfileStore] = None,
              hw: HardwareSpec = TPU_V5E,
              specs: Optional[Sequence[HardwareSpec]] = None,
              emulator: Optional[Emulator] = None,
              max_workers: int = 4, fused: bool = True,
              executor: str = "thread", mesh_spec=None) -> FleetResult:
    """Synthesize a fleet of scenarios and replay it concurrently.

    ``jobs`` is a sequence of (scenario_name, params) pairs.  Profiles are
    generated and predicted up front (across ``specs``, forwarded to each
    ``run_scenario`` call — defaulting to ``DEFAULT_SPECS``), then handed
    to ``emulate_many`` so the shared plan cache dedups identical
    (atom, amount) plans fleet-wide; profiles are stored only after
    emulation so the persisted meta carries ``emulated_ttc_s`` exactly
    like single ``run_scenario`` calls.

    ``executor``/``mesh_spec`` select the fleet backend: worker threads in
    this process (default) or a ``repro.fleet.ProcessFleet`` of worker
    processes, each with its own emulator and — given a ``MeshSpec`` —
    its own mesh, so scenarios with collective legs execute them.
    """
    results = [run_scenario(name, emulate=False, specs=specs, **params)
               for name, params in jobs]
    em = emulator or Emulator()
    fleet = em.emulate_many([r.profile for r in results],
                            max_workers=max_workers, fused=fused,
                            executor=executor, mesh_spec=mesh_spec)
    for r, rep in zip(results, fleet.reports):
        r.report = rep
        r.profile.meta["emulated_ttc_s"] = rep.ttc_s
        if store is not None:
            r.run_id = store.add(r.profile)
    return FleetResult(results=results, fleet=fleet,
                       predictions=predict_fleet(
                           [r.profile for r in results], hw))
