"""End-to-end scenario driver: generate -> predict -> emulate -> store.

``run_scenario`` pushes one synthesized profile through the paper's whole
lifecycle on machines we do have (emulation atoms) and machines we don't
(roofline prediction via ``predictor.compare``), then persists it to a
``ProfileStore`` under its scenario tags.  ``run_fleet`` does the same for a
batch of scenarios and replays them concurrently through
``Emulator.emulate_many`` with a shared plan cache.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.emulator import (VALID_EXECUTORS, EmulationReport, Emulator,
                                 FleetReport)
from repro.core.hardware import (HOST_I7_M620, HOST_STAMPEDE_NODE, TPU_V5E,
                                 HardwareSpec)
from repro.core.metrics import SynapseProfile
from repro.core.predictor import compare, predict_fleet
from repro.core.store import ProfileStore
from repro.scenarios.base import generate

DEFAULT_SPECS = [TPU_V5E, HOST_I7_M620, HOST_STAMPEDE_NODE]


@dataclass
class ScenarioResult:
    name: str
    profile: SynapseProfile
    predictions: Dict[str, Dict]             # hw name -> compare() row
    report: Optional[EmulationReport] = None
    run_id: Optional[str] = None

    def summary(self) -> Dict:
        out = {"scenario": self.name, "n_samples": len(self.profile.samples),
               "gflops": self.profile.totals.flops / 1e9,
               "predictions": self.predictions}
        if self.report is not None:
            out["emulated_ttc_s"] = self.report.ttc_s
        if self.run_id is not None:
            out["run_id"] = self.run_id
        return out


def run_scenario(name: str, *, store: Optional[ProfileStore] = None,
                 specs: Optional[Sequence[HardwareSpec]] = None,
                 emulator: Optional[Emulator] = None, emulate: bool = True,
                 fused: bool = True, **params) -> ScenarioResult:
    """Generate one scenario, predict it across hardware, emulate it here,
    and (optionally) persist it under its scenario tags.  ``fused`` selects
    the schedule-compiler replay path (O(segments) dispatches); pass False
    to force the legacy per-sample loop."""
    profile = generate(name, **params)
    predictions = compare(profile, list(specs or DEFAULT_SPECS))
    profile.meta["predictions"] = predictions    # persisted with the profile
    report = None
    if emulate:
        report = (emulator or Emulator()).emulate(profile, fused=fused)
        profile.meta["emulated_ttc_s"] = report.ttc_s
    run_id = store.add(profile) if store is not None else None
    return ScenarioResult(name=name, profile=profile, predictions=predictions,
                          report=report, run_id=run_id)


@dataclass
class FleetResult:
    results: List[ScenarioResult]
    fleet: FleetReport
    predictions: Dict = field(default_factory=dict)  # predict_fleet() row


def run_fleet(jobs: Sequence[Tuple[str, Dict]] = (), *,
              profiles: Optional[Iterable[SynapseProfile]] = None,
              store: Optional[ProfileStore] = None,
              hw: HardwareSpec = TPU_V5E,
              specs: Optional[Sequence[HardwareSpec]] = None,
              emulator: Optional[Emulator] = None,
              max_workers: int = 4, fused: bool = True,
              executor: str = "thread", mesh_spec=None,
              hosts=None, listen=None, agents=None,
              timeout: float = 600.0) -> FleetResult:
    """Synthesize and/or pull a fleet of profiles and replay it concurrently.

    ``jobs`` is a sequence of (scenario_name, params) pairs.  Profiles are
    generated and predicted up front (across ``specs``, forwarded to each
    ``run_scenario`` call — defaulting to ``DEFAULT_SPECS``), then handed
    to ``emulate_many`` so the shared plan cache dedups identical
    (atom, amount) plans fleet-wide; generated profiles are stored only
    after emulation so the persisted meta carries ``emulated_ttc_s``
    exactly like single ``run_scenario`` calls.

    ``profiles`` feeds the fleet from pre-built profiles instead of (or in
    addition to) generators — typically ``ProfileStore.stream(tags)``, the
    replay-a-captured-day path.  Streamed profiles are drained lazily into
    the job list, reuse any predictions persisted in their meta, and are
    *not* re-stored (they usually came from ``store``).

    ``executor`` selects the fleet backend (``repro.core.emulator.
    VALID_EXECUTORS``): worker threads in this process, a
    ``repro.fleet.ProcessFleet`` of local worker processes, or a
    ``repro.fleet.RemoteFleet`` of host agents over TCP
    (``hosts``/``listen``/``agents``, see ``emulate_many``).  With a
    ``MeshSpec`` every process/remote worker builds its own mesh, so
    scenarios with collective legs execute them.  ``timeout`` bounds the
    replay (strict for process/remote; best-effort for threads).
    """
    if executor not in VALID_EXECUTORS:
        # fail before paying generate/predict cost for the whole fleet
        raise ValueError(
            f"unknown executor {executor!r}; valid choices: "
            + ", ".join(repr(e) for e in VALID_EXECUTORS))
    results = [run_scenario(name, emulate=False, specs=specs, **params)
               for name, params in jobs]
    pulled = [ScenarioResult(name=p.tags.get("scenario", p.command),
                             profile=p,
                             predictions=p.meta.get("predictions", {}))
              for p in (profiles or ())]
    results = results + pulled
    if not results:
        raise ValueError("run_fleet needs jobs and/or profiles to replay")
    em = emulator or Emulator()
    fleet = em.emulate_many([r.profile for r in results],
                            max_workers=max_workers, fused=fused,
                            executor=executor, mesh_spec=mesh_spec,
                            hosts=hosts, listen=listen, agents=agents,
                            timeout=timeout)
    n_generated = len(results) - len(pulled)
    for i, (r, rep) in enumerate(zip(results, fleet.reports)):
        r.report = rep
        r.profile.meta["emulated_ttc_s"] = rep.ttc_s
        if store is not None and i < n_generated:
            r.run_id = store.add(r.profile)
    return FleetResult(results=results, fleet=fleet,
                       predictions=predict_fleet(
                           [r.profile for r in results], hw))
