"""Fanout-straggler scenario: N parallel workers, one tail-latency outlier.

A planner fanning work out to ``n_workers`` identical workers, except one
straggler doing ``straggler_factor``× the work — the classic p99-hides-in-
the-mean shape (aggregate metrics look healthy while batch completion is
gated on the one slow worker).  Profile samples are ordered, so the
straggler shows up as the sample that dominates TTC; ``meta`` records which
one so analysis tools don't have to rediscover it.
"""
from __future__ import annotations

import numpy as np

from repro.core.metrics import ResourceVector, Sample, SynapseProfile
from repro.scenarios.base import register


@register("fanout_straggler",
          n_workers=8, work_flops=5e7, work_hbm=8e6,
          straggler_factor=6.0, straggler_index=-1, jitter=0.05, seed=0)
def fanout_straggler(n_workers: int, work_flops: float, work_hbm: float,
                     straggler_factor: float, straggler_index: int,
                     jitter: float, seed: int) -> SynapseProfile:
    """N parallel workers with one straggler_factor× tail outlier."""
    if n_workers < 1 or straggler_factor < 1.0:
        raise ValueError("fanout_straggler needs n_workers >= 1 and "
                         "straggler_factor >= 1")
    rng = np.random.default_rng(seed)
    idx = straggler_index if 0 <= straggler_index < n_workers \
        else int(rng.integers(n_workers))
    samples = []
    for i in range(n_workers):
        noise = 1.0 + jitter * float(rng.standard_normal()) if jitter else 1.0
        scale = max(noise, 0.1) * (straggler_factor if i == idx else 1.0)
        rv = ResourceVector(flops=work_flops * scale,
                            hbm_bytes=work_hbm * scale)
        samples.append(Sample(index=i, resources=rv,
                              label="straggler" if i == idx else "worker"))
    return SynapseProfile(command="scenario:fanout_straggler", samples=samples,
                          meta={"straggler_index": idx,
                                "straggler_factor": straggler_factor})
