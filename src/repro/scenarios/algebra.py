"""Scenario algebra: compose ``SynapseProfile``s, and structure them as DAGs.

Every scenario generator emits one linear profile; real distributed
workloads are *dependency-structured* — fork-join diamonds, deep chains,
fanout with cross-profile edges — and their product is tail latency, not
totals (ROADMAP item 4; Cornebize & Legrand, arXiv 2102.07674, on why
aggregate means hide exactly the straggler effects a critical path
exposes).  This module supplies both halves:

* **profile operators** — pure functions over ``SynapseProfile``s:

    - ``concat(a, b, ...)``   sequential composition: samples appended in
      order, indices re-stamped 0..n-1 (associative — the sample list of
      ``concat(a, concat(b, c))`` is identical to
      ``concat(concat(a, b), c)``);
    - ``overlay(a, b, ...)``  parallel composition: samplewise resource
      sum, missing tails treated as zero (commutative — field-wise float
      addition commutes bitwise, so ``overlay(a, b)`` and
      ``overlay(b, a)`` agree sample by sample);
    - ``scale(p, f)``         per-sample resource scaling (the straggler
      knob: one branch scaled is a seeded tail outlier).

* **the DAG workload model** — ``WorkloadDag``: an ordered list of
  ``DagNode(profile, parents)`` where parents index *earlier* nodes, so
  every dag is topologically ordered by construction and cycles are
  unrepresentable.  ``chain(...)`` and ``fork_join(...)`` build the two
  canonical shapes (the ``chain``/``dag`` patterns of
  iocane-ai/synthetic-agents that expose "death by a thousand cuts" and
  straggler-hidden-by-aggregates failure modes).  A ``WorkloadDag`` feeds
  straight into ``Emulator.emulate_many`` (process/remote executors):
  each node becomes a ``ScheduleBundle`` whose ``parents`` edges gate its
  dispatch in ``FleetBase.stream``'s frontier scheduler, and the run's
  ``FleetReport.dag`` carries critical-path accounting.

``linearize()`` folds a dag back into one concatenated profile (nodes in
index order) with the structure recorded under ``meta["dag"]`` — the
registry-compatible single-profile view ``repro.scenarios.dag`` uses, and
the equivalence anchor: an edge-free dag replays to exactly the same
totals as its linearized profile stream.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Sequence, Tuple

from repro.core.metrics import ResourceVector, Sample, SynapseProfile


def _restamp(samples: Iterable[Sample]) -> List[Sample]:
    """Copy samples with indices re-stamped 0..n-1 (the registry's
    well-formedness contract)."""
    return [Sample(index=i, resources=s.resources, duration_s=s.duration_s,
                   label=s.label)
            for i, s in enumerate(samples)]


def concat(*profiles: SynapseProfile, command: str = "") -> SynapseProfile:
    """Sequential composition: ``a`` then ``b`` then ... as one profile.

    Associative on samples and totals: only the indices are re-stamped,
    so any parenthesization yields the identical sample list.
    """
    if not profiles:
        raise ValueError("concat needs at least one profile")
    samples: List[Sample] = []
    for p in profiles:
        samples.extend(p.samples)
    return SynapseProfile(
        command=command or "concat:" + "+".join(p.command for p in profiles),
        samples=_restamp(samples))


def overlay(*profiles: SynapseProfile, command: str = "") -> SynapseProfile:
    """Parallel composition: samplewise resource sum, zero-padded tails.

    Sample ``i`` of the overlay consumes the sum of every operand's
    sample ``i`` — two workloads sharing a host, expressed as one
    profile.  Commutative: ``ResourceVector.add`` is field-wise float
    addition, so operand order never changes a bit (and operands on
    disjoint resource types compose without interacting at all).
    """
    if not profiles:
        raise ValueError("overlay needs at least one profile")
    n = max(len(p.samples) for p in profiles)
    samples = []
    for i in range(n):
        rv = ResourceVector()
        for p in profiles:
            if i < len(p.samples):
                rv = rv.add(p.samples[i].resources)
        samples.append(Sample(index=i, resources=rv))
    return SynapseProfile(
        command=command or "overlay:" + "+".join(p.command for p in profiles),
        samples=samples)


def scale(profile: SynapseProfile, factor: float, *,
          command: str = "") -> SynapseProfile:
    """Scale every sample's resources by ``factor`` (>= 0).

    The straggler knob: ``scale(branch, 6.0)`` is a branch doing 6x the
    work — the tail outlier a dag's critical path exposes and aggregate
    totals hide.
    """
    if not (factor >= 0.0):
        raise ValueError(f"scale factor must be >= 0, got {factor!r}")
    return SynapseProfile(
        command=command or f"scale[{factor:g}]:{profile.command}",
        samples=[Sample(index=s.index, resources=s.resources.scale(factor),
                        duration_s=s.duration_s, label=s.label)
                 for s in profile.samples])


# ---------------------------------------------------------------------------
# the DAG workload model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DagNode:
    """One dag node: a profile plus the indices of the nodes whose results
    must land before this one may dispatch."""
    profile: SynapseProfile
    parents: Tuple[int, ...] = ()


@dataclass
class WorkloadDag:
    """An ordered, topologically-sorted dependency-structured workload.

    Nodes are appended with ``add``; parents must index earlier nodes, so
    the list order *is* a topological order and forward/self references
    (the only way to express a cycle) are rejected at construction —
    the same contract ``FleetBase.stream`` enforces per-bundle.
    """
    nodes: List[DagNode] = field(default_factory=list)

    def __post_init__(self):
        for i, node in enumerate(self.nodes):
            self._check(i, node.parents)

    def _check(self, idx: int, parents: Sequence[int]) -> None:
        bad = sorted({p for p in parents
                      if not isinstance(p, int) or p < 0 or p >= idx})
        if bad:
            raise ValueError(
                f"dag node {idx} lists parent(s) {bad}: parents must index "
                "earlier nodes (0..idx-1) — forward or self references "
                "would be unsatisfiable cycles")
        if len(set(parents)) != len(parents):
            raise ValueError(f"dag node {idx} repeats a parent: {parents}")

    def add(self, profile: SynapseProfile,
            parents: Sequence[int] = ()) -> int:
        """Append a node; returns its index (usable as a later parent)."""
        parents = tuple(parents)
        self._check(len(self.nodes), parents)
        self.nodes.append(DagNode(profile=profile, parents=parents))
        return len(self.nodes) - 1

    def __len__(self) -> int:
        return len(self.nodes)

    @property
    def parents_map(self) -> Dict[int, Tuple[int, ...]]:
        return {i: n.parents for i, n in enumerate(self.nodes)}

    @property
    def n_edges(self) -> int:
        return sum(len(n.parents) for n in self.nodes)

    def profiles(self) -> List[SynapseProfile]:
        return [n.profile for n in self.nodes]

    @property
    def totals(self) -> ResourceVector:
        """Aggregate resources, folded in node-index order — the exact
        analytic expectation an index-order ``ReportFold`` of a dag run
        must reproduce bit-for-bit."""
        t = ResourceVector()
        for n in self.nodes:
            t = t.add(n.profile.totals)
        return t

    def linearize(self, *, command: str = "") -> SynapseProfile:
        """One concatenated profile (nodes in index order), the structure
        preserved under ``meta["dag"]`` so single-profile surfaces
        (predict, in-process emulate, the scenario registry) can carry a
        dag without understanding edges."""
        prof = concat(*[n.profile for n in self.nodes],
                      command=command or "dag:"
                      + "+".join(n.profile.command for n in self.nodes))
        prof.meta["dag"] = {
            "parents": [list(n.parents) for n in self.nodes],
            "nodes": [{"command": n.profile.command,
                       "n_samples": len(n.profile.samples)}
                      for n in self.nodes]}
        return prof


def chain(profiles: Sequence[SynapseProfile]) -> WorkloadDag:
    """Deep chain: node i depends on node i-1 — no parallelism at all,
    makespan == sum of work, every node on the critical path."""
    if not profiles:
        raise ValueError("chain needs at least one profile")
    dag = WorkloadDag()
    prev = None
    for p in profiles:
        prev = dag.add(p, () if prev is None else (prev,))
    return dag


def fork_join(source: SynapseProfile, branches: Sequence[SynapseProfile],
              sink: SynapseProfile) -> WorkloadDag:
    """Fork-join diamond: ``source`` fans out to every branch, ``sink``
    joins them — the sink dispatches only after the slowest branch, so
    one straggler branch gates the makespan while totals look healthy."""
    if not branches:
        raise ValueError("fork_join needs at least one branch")
    dag = WorkloadDag()
    root = dag.add(source)
    mids = [dag.add(b, (root,)) for b in branches]
    dag.add(sink, tuple(mids))
    return dag
