"""Scenario CLI: drive the scenario engine from the command line.

    python -m repro.scenarios list
    python -m repro.scenarios run training_scan -p n_steps=6 -p ckpt_every=3
    python -m repro.scenarios fleet training_scan:n_steps=6 serving_traffic \
        --executor process --workers 2 --mesh 2
    python -m repro.scenarios fleet --store runs/ --from-store scenario=x \
        --executor remote --host host1:9000 --host host2:9000
    python -m repro.scenarios serve --port 8787
    python -m repro.scenarios trace training_scan:n_steps=4 --repeat 8 \
        --workers 2 --kill-every 5 --out /tmp/fleet_trace.json

``list`` shows every registered generator with its defaults; ``run`` pushes
one scenario through generate -> predict -> emulate (-> store with
``--store``); ``fleet`` replays a batch concurrently, with ``--executor``
selecting the in-process thread pool, the process-level fleet executor
(``repro.fleet``), or a remote fleet of host agents over TCP (``--host``
dials listening ``python -m repro.fleet.agent`` processes; ``--listen`` +
``--agents`` accepts dial-in ones) and ``--mesh N`` giving each worker
process an N-device mesh so collective legs execute.  ``--from-store``
turns ``--store`` into a profile *source*: matching stored profiles are
streamed into the fleet alongside (or instead of) generated jobs.
``serve`` starts the live traffic emulation service
(:mod:`repro.service.http`): open-loop load runs against a standing
fleet, driven and reported over HTTP.  ``trace`` replays a (optionally
chaos-injected) batch on a process fleet with the flight recorder on
and writes the merged timeline as Chrome trace-event JSON — open the
file at https://ui.perfetto.dev (or ``chrome://tracing``) to see queue/
replay spans per worker and fault/scale instants.  ``--window 1`` (the
default there) serializes dispatch, so a seeded chaos run produces the
same event sequence every time.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple


def _coerce(text: str):
    """CLI param values: int -> float -> bool -> str, first parse wins."""
    for cast in (int, float):
        try:
            return cast(text)
        except ValueError:
            pass
    if text.lower() in ("true", "false"):
        return text.lower() == "true"
    return text


def _parse_params(pairs: List[str]) -> Dict:
    params = {}
    for pair in pairs:
        if "=" not in pair:
            raise SystemExit(f"bad -p {pair!r}: expected key=value")
        k, v = pair.split("=", 1)
        params[k.strip()] = _coerce(v.strip())
    return params


def _parse_job(text: str) -> Tuple[str, Dict]:
    """``name`` or ``name:k=v,k=v`` -> (name, params)."""
    name, _, rest = text.partition(":")
    params = _parse_params(rest.split(",")) if rest else {}
    return name.strip(), params


def _cmd_list(args) -> int:
    from repro.scenarios import get_scenario, list_scenarios
    for name in list_scenarios():
        spec = get_scenario(name)
        print(f"{name:20s} {spec.description}")
        defaults = ", ".join(f"{k}={v}" for k, v in spec.defaults.items())
        print(f"{'':20s}   defaults: {defaults}")
    return 0


def _store(path: Optional[str]):
    if path is None:
        return None
    from repro.core import ProfileStore
    return ProfileStore(path)


def _cmd_run(args) -> int:
    from repro.scenarios import run_scenario
    res = run_scenario(args.name, store=_store(args.store),
                       emulate=not args.no_emulate,
                       fused=not args.per_sample,
                       **_parse_params(args.param))
    out = res.summary()
    if res.report is not None:
        out["report"] = res.report.summary()
    if args.json:
        print(json.dumps(out, indent=2, default=str))
        return 0
    print(f"scenario {res.name}: {len(res.profile.samples)} samples, "
          f"{res.profile.totals.flops / 1e9:.3f} GFLOP")
    for hw, row in res.predictions.items():
        print(f"  predicted on {hw:18s} ttc_max={row['ttc_max']:.3e}s "
              f"dominant={row['dominant_total']}")
    if res.report is not None:
        r = res.report
        print(f"  emulated here: ttc={r.ttc_s:.3f}s mode={r.mode} "
              f"dispatches={r.n_dispatches}")
    if res.run_id is not None:
        print(f"  stored as {res.run_id}")
    return 0


def _cmd_fleet(args) -> int:
    from repro.fleet import FleetConfig
    from repro.scenarios import run_fleet
    mesh_spec = None
    if args.mesh:
        from repro.fleet import MeshSpec
        mesh_spec = MeshSpec(shape=(args.mesh,), axes=("model",))
    config = FleetConfig(executor=args.executor, max_workers=args.workers,
                         mesh_spec=mesh_spec, hosts=args.host or None,
                         listen=args.listen, agents=args.agents,
                         timeout=args.timeout, window=args.window,
                         autoscale=args.autoscale is not None,
                         min_workers=args.autoscale,
                         max_attempts=args.max_attempts,
                         liveness_timeout=args.liveness,
                         on_failure=args.on_failure)
    jobs = [_parse_job(j) for j in args.job]
    store = _store(args.store)
    profiles = None
    if args.from_store is not None:
        # _parse_params coercion (int -> float -> bool -> str) matches the
        # JSON types tag values round-trip through the store with
        tags = _parse_params(args.from_store.split(",")) \
            if args.from_store else {}
        profiles = store.stream(tags)
    out = run_fleet(jobs, profiles=profiles, store=store, config=config,
                    fused=not args.per_sample)
    f = out.fleet
    if args.json:
        print(json.dumps({"fleet": f.summary(),
                          "reports": [r.report.summary()
                                      for r in out.results]},
                         indent=2, default=str))
        return 0
    print(f"fleet: {f.n_profiles} profiles on {f.max_workers} "
          f"{args.executor} worker(s) in {f.wall_s:.3f}s "
          f"(per-profile TTCs sum to {f.serial_s:.3f}s)")
    for r in out.results:
        rep = r.report
        coll = (f" collective_dispatches={rep.n_collective_dispatches}"
                if rep.n_collective_dispatches else "")
        print(f"  {r.name:20s} ttc={rep.ttc_s:.3f}s mode={rep.mode}"
              f" dispatches={rep.n_dispatches}{coll}")
    if f.scaling:
        print("  scaling:", ", ".join(f"{k}={v}"
                                      for k, v in f.scaling.items()))
    if f.recovery:
        print("  recovery:", ", ".join(f"{k}={v}"
                                       for k, v in f.recovery.items()))
    extra = {k: v for k, v in f.cache_stats.items()}
    if extra:
        print("  stats:", ", ".join(f"{k}={v}" for k, v in extra.items()))
    return 0


def _cmd_serve(args) -> int:
    from repro.service.http import serve
    serve(args.serve_host, args.port)
    return 0


def _cmd_trace(args) -> int:
    from repro.fleet import FleetConfig
    from repro.fleet.chaos import ChaosPolicy
    from repro.obs.recorder import Event, event_sequence
    from repro.obs.trace import to_chrome_trace, write_trace
    from repro.scenarios import run_fleet

    chaos_knobs = {k: v for k, v in (
        ("kill_every", args.kill_every), ("hang_nth", args.hang_nth),
        ("fail_nth", args.fail_nth)) if v}
    chaos = ChaosPolicy(seed=args.chaos_seed, max_faults=args.max_faults,
                        **chaos_knobs) if chaos_knobs else None
    config = FleetConfig.process(
        max_workers=args.workers, window=args.window, chaos=chaos,
        liveness_timeout=5.0 if chaos is not None else None,
        on_failure="skip",             # a poison job must still trace
        max_respawns=max(8, args.workers * 4), timeout=args.timeout)
    jobs = [_parse_job(j) for j in args.job] * args.repeat
    out = run_fleet(jobs, config=config, collect="totals")
    obs = out.fleet.obs
    events = [Event.from_dict(d) for d in obs.get("events", ())]
    trace = to_chrome_trace(events, meta={
        "jobs": args.job, "repeat": args.repeat, "workers": args.workers,
        "window": args.window, "chaos": repr(chaos),
        "dropped_events": obs.get("dropped_events", 0)})
    write_trace(args.out, trace)
    seq = event_sequence(events)
    rec = out.fleet.recovery
    print(f"trace: {len(events)} events ({len(seq)} in the deterministic "
          f"sequence), {obs.get('dropped_events', 0)} dropped")
    if rec:
        print("recovery:", ", ".join(f"{k}={v}" for k, v in rec.items()
                                     if k != "fault_events"))
    print(f"wrote {args.out} — open it at https://ui.perfetto.dev")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.scenarios",
        description="Synapse scenario engine CLI")
    sub = ap.add_subparsers(dest="cmd", required=True)

    sub.add_parser("list", help="list registered scenarios")

    run_p = sub.add_parser("run", help="run one scenario end-to-end")
    run_p.add_argument("name")
    run_p.add_argument("-p", "--param", action="append", default=[],
                       metavar="KEY=VALUE", help="scenario parameter")
    run_p.add_argument("--store", default=None, help="ProfileStore directory")
    run_p.add_argument("--no-emulate", action="store_true",
                       help="generate + predict only")
    run_p.add_argument("--per-sample", action="store_true",
                       help="force the legacy per-sample replay path")
    run_p.add_argument("--json", action="store_true")

    fl = sub.add_parser("fleet", help="replay a batch of scenarios")
    fl.add_argument("job", nargs="*",
                    metavar="NAME[:k=v,k=v]", help="scenario job spec")
    fl.add_argument("--executor", choices=("thread", "process", "remote"),
                    default="thread")
    fl.add_argument("--workers", type=int, default=4)
    fl.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="give each process/remote worker an N-device mesh "
                         "(not available on the thread executor)")
    fl.add_argument("--per-sample", action="store_true",
                    help="force the legacy per-sample replay path "
                         "(thread executor only)")
    fl.add_argument("--window", type=int, default=None, metavar="N",
                    help="compile-ahead window: the coordinator holds at "
                         "most N profiles/bundles pulled-but-unfinished, "
                         "backpressuring the source (default: 2x workers)")
    fl.add_argument("--autoscale", type=int, default=None, metavar="MIN",
                    help="make the process/remote pool elastic: start at "
                         "MIN workers, grow to --workers on queue depth, "
                         "retire idle capacity when the stream drains")
    fl.add_argument("--timeout", type=float, default=600.0, metavar="S",
                    help="abort the fleet replay after S seconds "
                         "(default 600)")
    fl.add_argument("--max-attempts", type=int, default=3, metavar="N",
                    help="per-profile dispatch budget before it is "
                         "declared poison (default 3)")
    fl.add_argument("--liveness", type=float, default=None, metavar="S",
                    help="reap a worker/agent silent for S seconds and "
                         "requeue its profiles (process/remote; arms "
                         "heartbeats)")
    fl.add_argument("--on-failure", choices=("raise", "skip"),
                    default="raise",
                    help="poison profile handling: fail the run (raise, "
                         "default) or complete degraded with the holes "
                         "listed under recovery (skip)")
    fl.add_argument("--host", action="append", default=[],
                    metavar="HOST:PORT",
                    help="dial a remote agent listening at HOST:PORT "
                         "(repeatable; remote executor only)")
    fl.add_argument("--listen", default=None, metavar="HOST:PORT",
                    help="listen at HOST:PORT for dial-in remote agents "
                         "(remote executor only)")
    fl.add_argument("--agents", type=int, default=None, metavar="N",
                    help="with --listen: wait for N agents to join "
                         "before replaying")
    fl.add_argument("--store", default=None, help="ProfileStore directory")
    fl.add_argument("--from-store", default=None, nargs="?", const="",
                    metavar="TAGS",
                    help="stream profiles matching TAGS (k=v,k=v; empty "
                         "for all) out of --store into the fleet")
    fl.add_argument("--json", action="store_true")

    tr = sub.add_parser("trace",
                        help="replay a batch with the flight recorder on "
                             "and export a Perfetto-loadable trace")
    tr.add_argument("job", nargs="+", metavar="NAME[:k=v,k=v]",
                    help="scenario job spec (repeatable)")
    tr.add_argument("--repeat", type=int, default=1, metavar="N",
                    help="replay the job list N times (default 1)")
    tr.add_argument("--workers", type=int, default=2)
    tr.add_argument("--window", type=int, default=1, metavar="N",
                    help="compile-ahead window (default 1: dispatch is "
                         "serialized, so a seeded chaos run emits a "
                         "deterministic event sequence)")
    tr.add_argument("--kill-every", type=int, default=0, metavar="N",
                    help="chaos: kill a worker on its every-Nth dispatch")
    tr.add_argument("--hang-nth", type=int, default=0, metavar="N",
                    help="chaos: hang a worker on its Nth dispatch")
    tr.add_argument("--fail-nth", type=int, default=0, metavar="N",
                    help="chaos: inject a failure on the Nth dispatch")
    tr.add_argument("--max-faults", type=int, default=0, metavar="N",
                    help="cap injected faults per worker (0 = unlimited)")
    tr.add_argument("--chaos-seed", type=int, default=0)
    tr.add_argument("--timeout", type=float, default=600.0, metavar="S")
    tr.add_argument("--out", default="fleet_trace.json", metavar="PATH",
                    help="trace file to write (default fleet_trace.json)")

    sv = sub.add_parser("serve",
                        help="start the live traffic emulation service "
                             "(open-loop load runs over HTTP)")
    sv.add_argument("--host", dest="serve_host", default="127.0.0.1")
    sv.add_argument("--port", type=int, default=8787,
                    help="0 picks a free port (printed at startup)")

    args = ap.parse_args(argv)
    if args.cmd == "fleet":
        if args.mesh and args.executor == "thread":
            ap.error("--mesh requires --executor process or remote "
                     "(threads cannot own per-worker meshes)")
        if args.per_sample and args.executor != "thread":
            ap.error(f"--per-sample is incompatible with --executor "
                     f"{args.executor}: process/remote fleets ship "
                     "compiled (fused) schedules")
        if args.autoscale is not None and args.executor == "thread":
            ap.error("--autoscale requires --executor process or remote "
                     "(the thread pool is fixed-size)")
        if args.autoscale is not None and args.autoscale < 1:
            ap.error("--autoscale MIN must be >= 1")
        if args.max_attempts < 1:
            ap.error("--max-attempts must be >= 1")
        if args.liveness is not None and args.executor == "thread":
            ap.error("--liveness requires --executor process or remote "
                     "(threads have no peer to heartbeat)")
        if (args.host or args.listen or args.agents is not None) \
                and args.executor != "remote":
            ap.error("--host/--listen/--agents require --executor remote")
        if args.executor == "remote" and not args.host and not args.listen:
            ap.error("--executor remote needs --host HOST:PORT (dial "
                     "listening agents) and/or --listen HOST:PORT "
                     "[--agents N] (accept dial-in agents)")
        if args.from_store is not None and args.store is None:
            ap.error("--from-store streams out of --store; pass --store "
                     "DIR too")
        if not args.job and args.from_store is None:
            ap.error("nothing to replay: give scenario jobs and/or "
                     "--from-store")
    if args.cmd == "trace":
        if args.repeat < 1:
            ap.error("--repeat must be >= 1")
        if args.window is not None and args.window < 1:
            ap.error("--window must be >= 1")
    return {"list": _cmd_list, "run": _cmd_run, "fleet": _cmd_fleet,
            "serve": _cmd_serve, "trace": _cmd_trace}[args.cmd](args)


if __name__ == "__main__":
    sys.exit(main())
