"""Mixed-fleet scenario: a weighted blend of the other scenario families.

Draws each of ``total_samples`` samples from a component scenario chosen by
weight (training/serving/fanout/retry by default), cycling through that
component's own sample stream.  This is the "production mix" knob: one
profile whose resource texture interleaves scan steps, request bursts,
stragglers and retries — and the stress case for the fleet plan cache,
which must dedup across families, not just within one.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.metrics import Sample, SynapseProfile
from repro.scenarios.base import generate, get_scenario, register

DEFAULT_WEIGHTS = {"training_scan": 0.4, "serving_traffic": 0.3,
                   "fanout_straggler": 0.2, "retry_storm": 0.1}


@register("mixed_fleet", total_samples=16, weights=None, seed=0)
def mixed_fleet(total_samples: int, weights: Optional[Dict[str, float]],
                seed: int) -> SynapseProfile:
    """Weighted interleave of the registered scenario families."""
    if total_samples < 1:
        raise ValueError("mixed_fleet needs total_samples >= 1")
    weights = dict(weights or DEFAULT_WEIGHTS)
    if not weights or any(w < 0 for w in weights.values()) \
            or sum(weights.values()) <= 0:
        raise ValueError(f"bad mixed_fleet weights {weights}")
    rng = np.random.default_rng(seed)
    pools, cursors = {}, {}
    for name in sorted(weights):
        spec = get_scenario(name)
        kw = {"seed": seed} if "seed" in spec.defaults else {}
        pools[name] = generate(name, **kw).samples
        cursors[name] = 0
    names = sorted(weights)
    probs = np.array([weights[n] for n in names], dtype=float)
    probs /= probs.sum()
    samples = []
    for i in range(total_samples):
        name = names[int(rng.choice(len(names), p=probs))]
        src = pools[name][cursors[name] % len(pools[name])]
        cursors[name] += 1
        samples.append(Sample(index=i, resources=src.resources,
                              duration_s=src.duration_s,
                              label=f"{name}:{src.label}"))
    return SynapseProfile(
        command="scenario:mixed_fleet", samples=samples,
        meta={"weights": {n: float(weights[n]) for n in names},
              "component_draws": dict(cursors)})
