"""Retry-storm scenario: flaky work re-consumed under exponential backoff.

Each of ``n_tasks`` consumes its full resource vector per attempt; an
attempt fails with probability ``error_rate`` (up to ``max_retries``
retries, the final attempt always lands), and retry k waits
``backoff_base_s · 2^(k-1)`` first — recorded as the sample's
``duration_s``.  The aggregate symptom this synthesizes: load amplification
with no increase in offered traffic, plus intermittent latency spikes from
the backoff tail.
"""
from __future__ import annotations

import numpy as np

from repro.core.metrics import ResourceVector, Sample, SynapseProfile
from repro.scenarios.base import register


@register("retry_storm",
          n_tasks=6, error_rate=0.3, max_retries=3,
          work_flops=5e7, work_hbm=4e6, backoff_base_s=0.01, seed=0)
def retry_storm(n_tasks: int, error_rate: float, max_retries: int,
                work_flops: float, work_hbm: float, backoff_base_s: float,
                seed: int) -> SynapseProfile:
    """Flaky tasks whose failures re-consume work with exponential backoff."""
    if n_tasks < 1 or not 0.0 <= error_rate < 1.0:
        raise ValueError("retry_storm needs n_tasks >= 1, 0 <= error_rate < 1")
    rng = np.random.default_rng(seed)
    rv = ResourceVector(flops=float(work_flops), hbm_bytes=float(work_hbm))
    samples, attempts = [], []
    for task in range(n_tasks):
        attempt = 0
        while True:
            attempt += 1
            failed = attempt <= max_retries and rng.random() < error_rate
            backoff = backoff_base_s * 2 ** (attempt - 2) if attempt > 1 \
                else 0.0
            tag = "fail" if failed else "ok"
            samples.append(Sample(index=len(samples), resources=rv,
                                  duration_s=backoff,
                                  label=f"task{task}:try{attempt}:{tag}"))
            if not failed:
                break
        attempts.append(attempt)
    return SynapseProfile(
        command="scenario:retry_storm", samples=samples,
        meta={"attempts": attempts, "total_attempts": sum(attempts),
              "amplification": sum(attempts) / n_tasks})
