"""DAG scenarios: fork-join diamonds and deep chains, registry-integrated.

Two first-class dependency-structured workloads (the iocane-ai/
synthetic-agents ``dag``/``chain`` shapes):

* ``dag_diamond`` — a planner fans out to ``fanout`` parallel branches
  and a reducer joins them.  One seeded straggler branch does
  ``straggler_factor``x the work, so the join (and therefore the
  makespan) is gated on it while aggregate totals look healthy —
  straggler-hidden-by-aggregates, exposed by critical-path accounting.
* ``deep_chain`` — ``depth`` strictly sequential stages, optionally
  decaying in size.  Zero parallelism: every stage is on the critical
  path, and per-stage overheads compound ("death by a thousand cuts").

Each shape exists in two forms.  ``dag_diamond_workload`` /
``deep_chain_workload`` build the real multi-node ``WorkloadDag`` (feed
it to ``Emulator.emulate_many`` on a process/remote fleet for
frontier-scheduled replay with ``FleetReport.dag`` critical-path
metrics).  The registered scenarios return that dag *linearized* — one
concatenated profile, nodes in topological order, edges preserved under
``meta["dag"]`` — so the registry contract (one validated
``SynapseProfile``) holds and single-profile surfaces (predict,
in-process emulate, the store) work unchanged.  The two views are
total-equivalent by construction: the linearized profile's totals equal
the workload's node-index-order fold bit for bit.
"""
from __future__ import annotations

import numpy as np

from repro.core.metrics import ResourceVector, Sample, SynapseProfile
from repro.scenarios.algebra import (WorkloadDag, chain, fork_join, scale)
from repro.scenarios.base import register


def _stage(command: str, flops: float, hbm: float, samples: int,
           label: str = "") -> SynapseProfile:
    return SynapseProfile(
        command=command,
        samples=[Sample(index=i,
                        resources=ResourceVector(flops=flops, hbm_bytes=hbm),
                        label=label)
                 for i in range(samples)])


def dag_diamond_workload(fanout: int = 4, work_flops: float = 5e7,
                         work_hbm: float = 8e6, samples_per: int = 2,
                         straggler_factor: float = 4.0,
                         straggler_index: int = -1,
                         seed: int = 0) -> WorkloadDag:
    """Fork-join diamond as a ``WorkloadDag``: source -> ``fanout``
    branches (one seeded straggler) -> sink."""
    if fanout < 1 or samples_per < 1:
        raise ValueError("dag_diamond needs fanout >= 1 and samples_per >= 1")
    if straggler_factor < 1.0:
        raise ValueError("straggler_factor must be >= 1")
    rng = np.random.default_rng(seed)
    idx = straggler_index if 0 <= straggler_index < fanout \
        else int(rng.integers(fanout))
    source = _stage("dag:diamond:source", work_flops, work_hbm, samples_per,
                    label="source")
    branches = []
    for i in range(fanout):
        b = _stage(f"dag:diamond:branch{i}", work_flops, work_hbm,
                   samples_per, label="straggler" if i == idx else "branch")
        if i == idx and straggler_factor > 1.0:
            b = scale(b, straggler_factor, command=b.command)
        branches.append(b)
    sink = _stage("dag:diamond:sink", work_flops, work_hbm, samples_per,
                  label="sink")
    dag = fork_join(source, branches, sink)
    return dag


def deep_chain_workload(depth: int = 6, work_flops: float = 5e7,
                        work_hbm: float = 8e6, samples_per: int = 2,
                        decay: float = 1.0) -> WorkloadDag:
    """Deep chain as a ``WorkloadDag``: ``depth`` sequential stages, stage
    k scaled by ``decay**k`` (decay < 1 models shrinking pipeline
    stages)."""
    if depth < 1 or samples_per < 1:
        raise ValueError("deep_chain needs depth >= 1 and samples_per >= 1")
    if not (decay > 0.0):
        raise ValueError(f"decay must be > 0, got {decay!r}")
    stages = []
    for k in range(depth):
        s = _stage(f"dag:chain:stage{k}", work_flops, work_hbm, samples_per,
                   label=f"stage{k}")
        if decay != 1.0:
            s = scale(s, decay ** k, command=s.command)
        stages.append(s)
    return chain(stages)


@register("dag_diamond", fanout=4, work_flops=5e7, work_hbm=8e6,
          samples_per=2, straggler_factor=4.0, straggler_index=-1, seed=0)
def dag_diamond(fanout, work_flops, work_hbm, samples_per,
                straggler_factor, straggler_index, seed) -> SynapseProfile:
    """Fork-join diamond with one seeded straggler branch (linearized)."""
    dag = dag_diamond_workload(fanout=fanout, work_flops=work_flops,
                               work_hbm=work_hbm, samples_per=samples_per,
                               straggler_factor=straggler_factor,
                               straggler_index=straggler_index, seed=seed)
    prof = dag.linearize(command="scenario:dag_diamond")
    prof.meta["fanout"] = fanout
    return prof


@register("deep_chain", depth=6, work_flops=5e7, work_hbm=8e6,
          samples_per=2, decay=1.0)
def deep_chain(depth, work_flops, work_hbm, samples_per,
               decay) -> SynapseProfile:
    """Deep sequential chain — zero parallelism, all critical path
    (linearized)."""
    dag = deep_chain_workload(depth=depth, work_flops=work_flops,
                              work_hbm=work_hbm, samples_per=samples_per,
                              decay=decay)
    prof = dag.linearize(command="scenario:deep_chain")
    prof.meta["depth"] = depth
    return prof
