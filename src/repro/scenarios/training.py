"""Training-scan scenario: a steady layer-scan loop with checkpoint bursts.

The canonical LM-training shape the rest of the repo profiles for real
(``benchmarks.common.tiny_train_workload``), but synthesized: ``n_steps``
identical compute+memory samples — exactly the consecutive-identical-sample
pattern the emulator collapses and the fleet plan cache dedups — with a
storage-write burst every ``ckpt_every`` steps (the checkpoint leg runs on
the emulator's I/O worker thread, concurrent with the device-side atoms,
like the real async checkpointer in ``repro.checkpoint``).
"""
from __future__ import annotations

from repro.core.metrics import ResourceVector, Sample, SynapseProfile
from repro.scenarios.base import register


@register("training_scan",
          n_steps=8, flops_per_step=6e7, hbm_per_step=1.6e7,
          ici_per_step=0.0, ckpt_every=4, ckpt_bytes=4e6)
def training_scan(n_steps: int, flops_per_step: float, hbm_per_step: float,
                  ici_per_step: float, ckpt_every: int,
                  ckpt_bytes: float) -> SynapseProfile:
    """Repeated identical train steps with periodic checkpoint-write bursts."""
    if n_steps < 1:
        raise ValueError("training_scan needs n_steps >= 1")
    samples = []
    n_ckpts = 0
    for i in range(n_steps):
        is_ckpt = ckpt_every > 0 and (i + 1) % ckpt_every == 0
        n_ckpts += is_ckpt
        ici = {"all-reduce": float(ici_per_step)} if ici_per_step > 0 else {}
        rv = ResourceVector(
            flops=float(flops_per_step), hbm_bytes=float(hbm_per_step),
            ici_bytes=ici,
            storage_write_bytes=float(ckpt_bytes) if is_ckpt else 0.0)
        samples.append(Sample(index=i, resources=rv,
                              label="step+ckpt" if is_ckpt else "step"))
    return SynapseProfile(command="scenario:training_scan", samples=samples,
                          meta={"n_ckpts": n_ckpts})
