"""Scenario registry: parameterized generators that *synthesize* profiles.

The paper's core pitch is that synthetic profiles "can be tuned at arbitrary
levels of granularity in ways that are simply not possible using real
applications".  A scenario is that knob surface made first-class: a named,
parameterized generator that emits a well-formed ``SynapseProfile`` without
running any real application.  Generated profiles carry
``tags={"scenario": name, <param>: <value>, ...}`` so the store keys them
exactly like captured profiles, and every generator is deterministic in its
``seed`` parameter (where it has one).

Adding a scenario::

    @register("my_scenario", n=8, seed=0)
    def my_scenario(n, seed):
        return SynapseProfile(command="scenario:my_scenario", samples=[...])

Registration validates nothing; ``generate()`` applies defaults, stamps the
tags, and checks well-formedness (ordered sample indices, finite nonnegative
resource vectors) on every emitted profile.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, List

from repro.core.metrics import SynapseProfile

_REGISTRY: Dict[str, "ScenarioSpec"] = {}


@dataclass(frozen=True)
class ScenarioSpec:
    name: str
    fn: Callable[..., SynapseProfile]
    description: str
    defaults: Dict[str, object]


def register(name: str, description: str = "", **defaults):
    """Decorator: add a generator to the registry with default params."""
    def deco(fn: Callable[..., SynapseProfile]):
        if name in _REGISTRY:
            raise ValueError(f"scenario {name!r} already registered")
        doc = (fn.__doc__ or "").strip().splitlines()
        _REGISTRY[name] = ScenarioSpec(
            name=name, fn=fn,
            description=description or (doc[0] if doc else name),
            defaults=dict(defaults))
        return fn
    return deco


def get_scenario(name: str) -> ScenarioSpec:
    if name not in _REGISTRY:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"registered: {list_scenarios()}")
    return _REGISTRY[name]


def list_scenarios() -> List[str]:
    return sorted(_REGISTRY)


def generate(name: str, **params) -> SynapseProfile:
    """Generate one profile: defaults + overrides -> generator -> validated,
    tagged ``SynapseProfile``."""
    spec = get_scenario(name)
    unknown = set(params) - set(spec.defaults)
    if unknown:
        raise TypeError(f"scenario {name!r} got unknown params {unknown}; "
                        f"accepts {sorted(spec.defaults)}")
    kw = {**spec.defaults, **params}
    profile = spec.fn(**kw)
    profile.tags["scenario"] = name
    for k, v in kw.items():
        if isinstance(v, (str, int, float, bool)) and v is not None:
            profile.tags.setdefault(k, str(v))
        elif isinstance(v, dict) and v:
            # dict params (e.g. mixed_fleet weights) must reach the store
            # key too, or different mixes collide as "repeated runs"
            profile.tags.setdefault(
                k, ",".join(f"{kk}={vv}" for kk, vv in sorted(v.items())))
    validate(profile)
    return profile


def validate(profile: SynapseProfile) -> None:
    """Well-formedness contract every generated profile must satisfy."""
    if not profile.samples:
        raise ValueError(f"{profile.command}: scenario emitted no samples")
    for i, s in enumerate(profile.samples):
        if s.index != i:
            raise ValueError(f"{profile.command}: sample indices must be "
                             f"0..n-1 in order, got {s.index} at {i}")
        r = s.resources
        fields = {"flops": r.flops, "hbm_bytes": r.hbm_bytes,
                  "storage_read_bytes": r.storage_read_bytes,
                  "storage_write_bytes": r.storage_write_bytes,
                  **{f"ici[{k}]": v for k, v in r.ici_bytes.items()}}
        for fname, val in fields.items():
            if not math.isfinite(val) or val < 0:
                raise ValueError(f"{profile.command}: sample {i} has bad "
                                 f"{fname}={val!r}")
