"""Serving-traffic scenario: Poisson arrivals over prefill/decode rooflines.

Each request becomes two ordered samples — a prefill vector (compute-heavy)
and a decode vector (memory-heavy) — built by
``predictor.llm_request_resources`` from a parameter count and token
budgets.  ``duration_s`` is the roofline ``t_max`` of the sample on the
reference HardwareSpec, so the synthesized profile carries a predicted
serving timeline; arrival times (exponential inter-arrival gaps at
``rate_hz``) live in ``meta["arrival_s"]``.
"""
from __future__ import annotations

import numpy as np

from repro.core.hardware import get_spec
from repro.core.metrics import Sample, SynapseProfile
from repro.core.predictor import llm_request_resources, terms_for
from repro.scenarios.base import register


@register("serving_traffic",
          n_requests=8, rate_hz=50.0, prefill_tokens=128, decode_tokens=16,
          n_params=4e6, bytes_per_param=2.0, kv_bytes_per_token=0.0,
          hw="tpu_v5e", seed=0)
def serving_traffic(n_requests: int, rate_hz: float, prefill_tokens: int,
                    decode_tokens: int, n_params: float,
                    bytes_per_param: float, kv_bytes_per_token: float,
                    hw: str, seed: int) -> SynapseProfile:
    """Poisson request stream mapped to prefill/decode resource vectors."""
    if n_requests < 1 or rate_hz <= 0:
        raise ValueError("serving_traffic needs n_requests >= 1, rate_hz > 0")
    rng = np.random.default_rng(seed)
    spec = get_spec(hw)
    gaps = rng.exponential(1.0 / rate_hz, size=n_requests)
    prefill, decode = llm_request_resources(
        prefill_tokens, decode_tokens, n_params, bytes_per_param,
        kv_bytes_per_token)
    tp, td = terms_for(prefill, spec), terms_for(decode, spec)
    samples, arrivals, t = [], [], 0.0
    for i in range(n_requests):
        t += float(gaps[i])
        arrivals.append(t)
        samples.append(Sample(index=2 * i, resources=prefill,
                              duration_s=tp.t_max,
                              label=f"prefill:{tp.dominant}"))
        samples.append(Sample(index=2 * i + 1, resources=decode,
                              duration_s=td.t_max,
                              label=f"decode:{td.dominant}"))
    return SynapseProfile(
        command="scenario:serving_traffic", samples=samples,
        meta={"arrival_s": arrivals,
              "prefill_dominant": tp.dominant, "decode_dominant": td.dominant,
              "ref_hw": spec.name})
