"""End-to-end behaviour of the paper's system: the full
profile -> store -> emulate -> predict pipeline on a real workload, and one
real dry-run cell (subprocess: the dry-run needs its own device count)."""
import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_profile_store_emulate_predict_pipeline(tmp_path):
    """The paper's whole lifecycle on a real (tiny) LM training run."""
    sys.path.insert(0, ROOT)
    from benchmarks.common import tiny_train_workload
    from benchmarks.bench_profiling_consistency import (_abstract_batch,
                                                        _abstract_state)
    from repro.core import (Emulator, ProfileStore, RuntimeProfiler, TPU_V5E,
                            calibrate, predict, profile_compiled)

    run_fn, meta = tiny_train_workload(steps=2)

    # profile (both watcher families)
    rprof = RuntimeProfiler(sample_rate=20).profile_callable(
        run_fn, command="sys-lm", tags={"steps": "2"},
        flops_per_cpu_s=calibrate().flops_per_s)
    compiled = meta["step"].lower(_abstract_state(meta["model"]),
                                  _abstract_batch(meta)).compile()
    sprof = profile_compiled(compiled, command="sys-lm", tags={"k": "static"})
    assert sprof.totals.flops > 1e8
    assert len(sprof.samples) > 3          # phase-sampled, ordered
    assert [s.index for s in sprof.samples] == list(
        range(len(sprof.samples)))

    # store + statistics over repeats
    store = ProfileStore(str(tmp_path))
    store.add(sprof)
    store.add(sprof)
    stats = store.stats("sys-lm", {"k": "static"})
    assert stats.n == 2 and stats.std["flops"] == 0.0

    # emulate anywhere (here) — consumption totals preserved
    rep = Emulator().emulate(store.latest("sys-lm", {"k": "static"}))
    assert rep.consumed.flops == pytest.approx(sprof.totals.flops, rel=1e-6)
    assert rep.ttc_s > 0

    # predict on hardware we don't have
    pred = predict(sprof, TPU_V5E)
    assert 0 < pred.ttc_max <= pred.ttc_sum
    assert pred.terms.dominant in ("compute", "memory", "collective")


@pytest.mark.slow
@pytest.mark.subproc
def test_dryrun_cell_end_to_end(tmp_path):
    """One real (arch × shape × mesh) cell through the production dry-run."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen2-1.5b",
         "--shape", "decode_32k", "--out", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=560, cwd=ROOT)
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.load(open(tmp_path / "qwen2-1.5b__decode_32k__16x16.json"))
    assert rec["ok"], rec.get("error")
    assert rec["n_devices"] == 256
    assert rec["memory"]["per_device_total"] < 16e9       # fits v5e
    w = rec["walker"]
    assert w["flops"] > 0 and w["collective_total"] > 0
    assert rec["model_flops"] > 0
