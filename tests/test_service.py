"""Live traffic emulation service (PR 8 contracts).

Fast tests pin the service's pure pieces in-process: arrival processes
are bit-deterministic per seed (iterating never mutates, traces
round-trip), the latency sketch honors its ``growth - 1`` error bound
and merges associatively, and ``SLOEngine`` joins offered/completed/
fault streams onto one windowed timeline (with the one-window stretch
past repair).  The executor's open-loop admission mode and per-bundle
``BundleTiming`` are pinned on loopback peers (no subprocesses), as is
``StandingFleet``'s session lifecycle over an injected pool, and the
HTTP layer's parsing/routing runs without sockets (plus one real
``ThreadingHTTPServer`` smoke on port 0).

The acceptance test (marked ``slow`` + ``subproc``) is the PR's
headline contract: a seeded Poisson storm against a 1-worker process
fleet with a seeded ``ChaosPolicy`` kill is reproducible end to end —
identical arrival timeline, identical fault schedule, exact request
totals — and the injected kill's MTTR lands in the faulted windows'
p999.
"""
import json
import multiprocessing as mp
import pickle
import threading
import time
import urllib.request
from random import Random

import pytest

from repro.core import ResourceVector, Sample, SynapseProfile
from repro.core.emulator import EmulationReport, Emulator, ReportFold
from repro.fleet import (BundleTiming, ChaosPolicy, FleetBase, FleetConfig,
                         Peer, ScheduleBundle)
from repro.service import (ARRIVAL_KINDS, Arrival, ConstantArrivals,
                           DiurnalArrivals, LatencySketch, PoissonArrivals,
                           SLO, SLOEngine, StandingFleet, TraceArrivals,
                           arrival_process, run_load)
from repro.service.http import LoadService, make_server

TILE = 64                  # 1 compute iter = 2*64^3  = 524288 flops
BLOCK = 1 << 18            # 1 memory  iter = 2*2^18  = 524288 bytes
FPI = 2.0 * TILE ** 3
BPI = 2.0 * BLOCK


def _rv(flops=0.0, hbm=0.0):
    return ResourceVector(flops=flops, hbm_bytes=hbm)


# ---------------------------------------------------------------------------
# latency sketch
# ---------------------------------------------------------------------------

def _exact_quantile(xs, q):
    s = sorted(xs)
    import math
    return s[max(1, math.ceil(q * len(s))) - 1]


def test_sketch_error_bound_vs_exact():
    """Every quantile read back is within ``growth - 1`` relative error
    of the exact sample quantile (the rank's value lies in the bucket
    the query lands in, and the midpoint is < sqrt(growth) off)."""
    rng = Random(42)
    sk = LatencySketch()
    xs = [rng.expovariate(1.0 / 0.2) + 1e-4 for _ in range(5000)]
    for x in xs:
        sk.add(x)
    assert sk.count == len(xs)
    for q in (0.5, 0.9, 0.99, 0.999):
        exact = _exact_quantile(xs, q)
        rel = abs(sk.quantile(q) - exact) / exact
        assert rel <= sk.growth - 1, f"q={q}: rel error {rel:.4f}"
    # queries clamp to the observed range
    assert min(xs) <= sk.quantile(0.001) <= max(xs)
    assert sk.quantile(1.0) == pytest.approx(max(xs), rel=sk.growth - 1)
    assert sk.mean == pytest.approx(sum(xs) / len(xs))


def test_sketch_merge_associative_and_commutative():
    # dyadic values: float sums are exact, so full equality is fair game
    def mk(ks):
        s = LatencySketch()
        for k in ks:
            s.add(k / 1024.0)
        return s

    a, b, c = mk(range(1, 200)), mk(range(50, 400)), mk(range(300, 320))
    left = a.merge(b).merge(c)
    right = a.merge(b.merge(c))
    swapped = c.merge(a).merge(b)
    for other in (right, swapped):
        assert left.counts == other.counts
        assert left.count == other.count
        assert left.total == other.total
        assert left.min == other.min and left.max == other.max
        assert left.quantile(0.99) == other.quantile(0.99)
    # inputs untouched
    assert a.count == 199 and c.count == 20
    with pytest.raises(ValueError):
        a.merge(LatencySketch(growth=1.1))


def test_sketch_pickle_roundtrip():
    sk = LatencySketch()
    for i in range(1, 500):
        sk.add(i / 100.0)
    back = pickle.loads(pickle.dumps(sk))
    assert back.counts == sk.counts and back.count == sk.count
    assert back.quantile(0.99) == sk.quantile(0.99)
    back.add(7.0)          # still a live sketch, not a frozen snapshot
    sk.add(7.0)
    assert back.quantile(0.999) == sk.quantile(0.999)


def test_sketch_validation_and_bounded_memory():
    with pytest.raises(ValueError):
        LatencySketch(lo=0.0)
    with pytest.raises(ValueError):
        LatencySketch(growth=1.0)
    sk = LatencySketch()
    with pytest.raises(ValueError):
        sk.add(-1.0)
    with pytest.raises(ValueError):
        sk.quantile(0.0)
    assert sk.quantile(0.5) == 0.0           # empty sketch
    n_buckets = len(sk.counts)
    rng = Random(1)
    for _ in range(20000):
        sk.add(rng.random() * 100)
    assert len(sk.counts) == n_buckets       # bounded regardless of stream


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

def test_arrivals_same_seed_identical_timeline():
    def mk(seed):
        return PoissonArrivals(rate_hz=50.0, n_requests=200,
                               scenario="svc", seed=seed)

    assert list(mk(7)) == list(mk(7))        # same seed => same timeline
    p = mk(7)
    assert list(p) == list(p)                # iterating never mutates
    assert [a.t for a in mk(8)] != [a.t for a in mk(7)]
    # gaps strictly positive, times nondecreasing
    ts = [a.t for a in p]
    assert all(b > a for a, b in zip(ts, ts[1:]))
    # the scenario scopes the RNG stream (seeding discipline)
    other = PoissonArrivals(rate_hz=50.0, n_requests=200,
                            scenario="other", seed=7)
    assert [a.t for a in other] != ts


def test_constant_arrivals_exact_times_and_bounds():
    p = ConstantArrivals(rate_hz=4.0, n_requests=9, scenario="svc")
    assert [a.t for a in p] == [i / 4.0 for i in range(9)]
    capped = ConstantArrivals(rate_hz=4.0, n_requests=100, duration_s=1.0,
                              scenario="svc")
    assert [a.t for a in capped] == [i / 4.0 for i in range(5)]  # t <= 1.0
    with pytest.raises(ValueError):
        ConstantArrivals(rate_hz=0.0, n_requests=1)
    with pytest.raises(ValueError):
        ConstantArrivals(rate_hz=1.0)        # unbounded load is a typo


def test_diurnal_arrivals_shape_and_determinism():
    p = DiurnalArrivals(base_hz=2.0, peak_hz=40.0, period_s=10.0,
                        duration_s=10.0, seed=3, scenario="svc")
    assert p.rate_at(0.0) == pytest.approx(2.0)
    assert p.rate_at(5.0) == pytest.approx(40.0)
    ts = [a.t for a in p]
    assert ts == [a.t for a in p]            # deterministic
    assert all(b >= a for a, b in zip(ts, ts[1:]))
    # the mid-period peak carries more arrivals than the edges
    mid = sum(1 for t in ts if 2.5 <= t < 7.5)
    edges = len(ts) - mid
    assert mid > edges


def test_trace_roundtrip_and_validation():
    p = PoissonArrivals(rate_hz=30.0, n_requests=20, scenario="svc",
                        params={"fanout": 3}, seed=5)
    tr = p.trace()
    assert list(tr) == list(p)
    back = TraceArrivals.from_log(tr.to_log())
    assert list(back) == list(p)             # JSON form round-trips
    # bounds still apply on replay
    cut = TraceArrivals(log=tr.log, n_requests=5)
    assert len(list(cut)) == 5
    with pytest.raises(ValueError):
        TraceArrivals(log=(Arrival(t=1.0, scenario="svc"),
                           Arrival(t=0.5, scenario="svc")))
    with pytest.raises(ValueError):
        Arrival(t=-0.1, scenario="svc")


def test_arrival_factory_and_params():
    p = arrival_process("poisson", "svc", seed=1, n_requests=5, rate_hz=30.0)
    assert isinstance(p, PoissonArrivals) and p.rate_hz == 30.0
    with pytest.raises(ValueError):
        arrival_process("wat", "svc", n_requests=5)
    assert set(ARRIVAL_KINDS) == {"constant", "poisson", "diurnal"}
    a = Arrival(t=0.0, scenario="svc", params={"b": 2, "a": 1})
    assert a.params == (("a", 1), ("b", 2))  # frozen sorted form
    assert a.kwargs == {"a": 1, "b": 2}


# ---------------------------------------------------------------------------
# SLO engine
# ---------------------------------------------------------------------------

def test_slo_validation_and_met():
    slo = SLO(target_ms=200.0, percentile=0.99)
    assert slo.met(0.2) and not slo.met(0.2001)
    assert slo.to_dict() == {"target_ms": 200.0, "percentile": 0.99}
    with pytest.raises(ValueError):
        SLO(target_ms=0.0)
    with pytest.raises(ValueError):
        SLO(target_ms=100.0, percentile=1.0)


def test_slo_engine_windows_and_fault_join():
    eng = SLOEngine(SLO(target_ms=200.0), window_s=1.0)
    for t in (0.2, 0.4, 1.2, 3.5):
        eng.offered(t)
    eng.observe(0.3, 0.05)                   # in SLO
    eng.observe(1.5, 0.6)                    # violated (600ms)
    eng.observe(2.1, 0.9, ok=False)          # failed => violated
    eng.fault(0.5, 1.4)                      # MTTR 0.9s
    rep = eng.report()
    assert rep["n_offered"] == 4 and rep["n_completed"] == 3
    assert rep["n_failed"] == 1 and rep["n_violations"] == 2
    assert rep["duration_s"] == 4.0          # last window closes the run
    assert rep["goodput_hz"] == pytest.approx(1 / 4.0)
    assert rep["offered_hz"] == pytest.approx(4 / 4.0)
    wins = {w["t0"]: w for w in rep["windows"]}
    assert set(wins) == {0.0, 1.0, 2.0, 3.0}
    # the fault marks the windows it overlaps PLUS one past repair (the
    # interrupted request completes just after the replacement warms)
    assert wins[0.0]["faults"] == 1
    assert wins[1.0]["faults"] == 1
    assert wins[2.0]["faults"] == 1          # repair 1.4 + window 1.0 >= 2.0
    assert wins[3.0]["faults"] == 0
    assert wins[2.0]["failed"] == 1 and wins[2.0]["completed"] == 1
    assert rep["faults"] == [{"opened": 0.5, "repaired": 1.4,
                              "mttr_s": pytest.approx(0.9)}]
    # tail reflects the slow completion within sketch error
    assert rep["p999"] == pytest.approx(0.9, rel=0.05)
    assert not rep["slo_met"]


# ---------------------------------------------------------------------------
# executor open-loop admission + BundleTiming (loopback peers)
# ---------------------------------------------------------------------------

class _EchoPeer(Peer):
    """Loopback peer: ``dispatch`` writes the reply into its own pipe, so
    the scheduler's wait/collect path runs unchanged with zero
    subprocesses."""

    def __init__(self):
        super().__init__()
        self._r, self._w = mp.Pipe(duplex=False)
        self.ready = True

    @property
    def waitable(self):
        return self._r

    def dispatch(self, epoch, idx, bundle):
        self.tasks.add((epoch, idx))
        if bundle.command.startswith("poison"):
            self._w.send(("err", epoch, idx, "synthetic poison"))
            return
        rep = EmulationReport(command=bundle.command, ttc_s=1e-3,
                              n_samples=bundle.n_profile_samples,
                              consumed=bundle.planned, mode="fused")
        self._w.send(("ok", epoch, idx, rep))

    def recv(self):
        return self._r.recv()

    def close(self):
        self._r.close()
        self._w.close()


class _EchoFleet(FleetBase):
    def __init__(self, n, *, autoscale=False, scale_max=3, min_workers=1):
        super().__init__()
        self._autoscale = autoscale
        self._scale_min = min_workers
        self._scale_max = scale_max
        for _ in range(n):
            self._peers.append(_EchoPeer())

    def _scale_up(self):
        if len(self._peers) >= self._scale_max:
            return False
        self._peers.append(_EchoPeer())
        self.scale_ups += 1
        return True


def _echo_bundle(i, command=None):
    # awkward float amounts on purpose: summation order changes the bits,
    # so identical fold totals really mean identical fold order
    return ScheduleBundle(command=command or f"echo{i}", payload={},
                          n_profile_samples=1,
                          planned=_rv(flops=0.1 * i + 0.3, hbm=0.7 * i))


def test_stream_none_source_open_loop_admission():
    """A source yielding ``None`` means "nothing arrived yet": the
    scheduler keeps turning without marking the stream exhausted, admits
    each bundle when it appears, and the whole run still drains."""
    def source():
        for i in range(4):
            for _ in range(3):
                yield None               # idle polls between arrivals
            yield _echo_bundle(i)

    timings = {}
    with _EchoFleet(1) as fleet:
        done = [idx for idx, _ in
                fleet.stream(source(),
                             record_timing=lambda i, t: timings.update(
                                 {i: t}))]
    assert sorted(done) == [0, 1, 2, 3]
    assert sorted(timings) == [0, 1, 2, 3]
    for t in timings.values():
        assert isinstance(t, BundleTiming) and t.ok
        assert t.attempts == 1
        assert t.dispatched is not None
        assert t.enqueued <= t.dispatched <= t.done
        assert t.queue_s >= 0.0 and t.replay_s >= 0.0


def test_stream_timing_records_skip_as_failure():
    bundles = [_echo_bundle(0), _echo_bundle(1, command="poison"),
               _echo_bundle(2)]
    timings = {}
    with _EchoFleet(1) as fleet:
        out = dict(fleet.stream(iter(bundles), on_failure="skip",
                                record_timing=lambda i, t: timings.update(
                                    {i: t})))
    assert out[0] is not None and out[2] is not None
    assert out[1] is None                    # skipped, not silently lost
    assert timings[1].ok is False and timings[1].replay_s == 0.0
    assert timings[0].ok and timings[2].ok


def test_stream_midstream_scale_down_on_idle():
    """An elastic pool sheds idle capacity *between* load peaks: when
    queue depth stays below the floor for a full ``idle_retire_s``
    window, one ready idle worker retires per elapsed window (never
    below the floor)."""
    def source():
        for i in range(3):                   # a small burst...
            yield _echo_bundle(i)
        t0 = time.monotonic()
        while time.monotonic() - t0 < 0.4:   # ...then a lull
            yield None
        yield _echo_bundle(3)                # traffic resumes

    with _EchoFleet(3, autoscale=True, scale_max=3) as fleet:
        done = [idx for idx, _ in
                fleet.stream(source(), idle_retire_s=0.05)]
        sc = fleet.last_scaling
        assert sorted(done) == [0, 1, 2, 3]
        assert sc["midstream_downs"] >= 1
        assert sc["scale_downs"] >= sc["midstream_downs"]
        # the floor held: the resumed request still found a worker
        assert len(fleet._peers) >= 1


def test_stream_no_midstream_retire_without_opt_in():
    """Neither ``idle_retire_s`` nor ``liveness_timeout`` set: the lull
    does not shrink the pool (existing autoscale behavior preserved)."""
    def source():
        yield _echo_bundle(0)
        t0 = time.monotonic()
        while time.monotonic() - t0 < 0.2:
            yield None

    with _EchoFleet(3, autoscale=True, scale_max=3) as fleet:
        list(fleet.stream(source()))
        assert fleet.last_scaling["midstream_downs"] == 0


# ---------------------------------------------------------------------------
# StandingFleet (injected loopback pool)
# ---------------------------------------------------------------------------

def _fold_of(bundles_by_idx):
    fold = ReportFold(keep_reports=False)
    for i in sorted(bundles_by_idx):
        b = bundles_by_idx[i]
        fold.add(i, EmulationReport(command=b.command, ttc_s=1e-3,
                                    n_samples=1, consumed=b.planned,
                                    mode="fused"))
    return fold


def test_standing_fleet_sessions_on_a_warm_pool():
    cfg = FleetConfig.process(max_workers=1, timeout=30.0)
    with _EchoFleet(1) as pool:
        sf = StandingFleet(None, cfg, fleet=pool)
        seen = []
        unsub = sf.on_complete(
            lambda rec, rep: seen.append((rec.idx, rep is not None)))
        with pytest.raises(RuntimeError):
            sf.drain()                       # no session yet
        with pytest.raises(ValueError):
            sf.submit()                      # exactly one of profile/bundle
        subs = {i: _echo_bundle(i) for i in range(3)}
        for i in range(3):
            assert sf.submit(bundle=subs[i]) == i
        res = sf.drain(timeout=10.0)
        assert [r.idx for r in res.records] == [0, 1, 2]
        assert all(r.ok for r in res.records)
        assert all(isinstance(r.timing, BundleTiming)
                   for r in res.records)
        assert all(r.done is not None and r.done >= r.submitted
                   for r in res.records)
        assert res.n_ok == 3 and res.n_skipped == 0
        # totals fold in index order: bit-identical to the reference fold
        assert res.totals == _fold_of(subs).totals
        assert sorted(seen) == [(0, True), (1, True), (2, True)]
        unsub()
        # second session on the same warm pool; indices restart
        assert sf.submit(bundle=_echo_bundle(5)) == 0
        res2 = sf.drain(timeout=10.0)
        assert [r.idx for r in res2.records] == [0]
        assert len(seen) == 3                # unsubscribed hook stayed quiet
        sf.close()
        with pytest.raises(RuntimeError):
            sf.submit(bundle=_echo_bundle(9))


def test_standing_fleet_skip_accounting():
    cfg = FleetConfig.process(max_workers=1, on_failure="skip", timeout=30.0)
    with _EchoFleet(1) as pool:
        with StandingFleet(None, cfg, fleet=pool) as sf:
            sf.submit(bundle=_echo_bundle(0))
            sf.submit(bundle=_echo_bundle(1, command="poison"))
            res = sf.drain(timeout=10.0)
    assert res.n_ok == 1 and res.n_skipped == 1
    assert res.records[1].ok is False


# ---------------------------------------------------------------------------
# HTTP layer (no sockets, plus one real-server smoke)
# ---------------------------------------------------------------------------

def _service():
    return LoadService(Emulator(compute_tile=TILE, mem_block=BLOCK))


def test_load_service_parse_spec():
    svc = _service()
    spec = svc._parse({"scenario": "serving_traffic", "process": "poisson",
                       "rate_hz": 20.0, "n": 10, "seed": 11,
                       "kill_every": 5, "chaos_seed": 3,
                       "p_fanout": 4, "workers": 1,
                       "slo_ms": 100.0, "slo_pct": 0.999})
    assert spec["scenario"] == "serving_traffic"
    assert spec["params"] == {"fanout": 4}
    assert spec["knobs"] == {"rate_hz": 20.0}
    cfg = spec["config"]
    assert isinstance(cfg.chaos, ChaosPolicy)
    assert cfg.chaos.kill_every == 5 and cfg.chaos.seed == 3
    assert cfg.liveness_timeout == 5.0       # chaos arms liveness
    assert cfg.on_failure == "skip"          # poison can't kill the service
    assert spec["slo"] == SLO(target_ms=100.0, percentile=0.999)
    # no fault knob => no chaos, no implied liveness
    calm = svc._parse({"n": 5})
    assert calm["config"].chaos is None
    assert calm["config"].liveness_timeout is None
    assert calm["n_requests"] == 5
    assert svc._parse({})["n_requests"] == 50    # bounded by default
    with pytest.raises(ValueError):
        svc._parse({"process": "wat"})


def test_load_service_routes_without_sockets():
    svc = _service()
    assert svc.route("/healthz") == {"ok": True}
    out = svc.route("/scenarios")
    assert "serving_traffic" in out["scenarios"]
    assert out["processes"] == sorted(ARRIVAL_KINDS)
    assert svc.route("/runs") == {"runs": []}
    with pytest.raises(KeyError):
        svc.route("/nope")
    with pytest.raises(KeyError):
        svc.route("/status?id=99")
    # a bad spec fails in parsing, before any pool is spawned
    with pytest.raises(ValueError):
        svc.route("/run?process=wat")


def test_http_server_smoke_port_zero():
    server = make_server(port=0)
    t = threading.Thread(target=server.serve_forever, daemon=True)
    t.start()
    try:
        host, port = server.server_address[:2]
        with urllib.request.urlopen(
                f"http://{host}:{port}/healthz", timeout=10) as r:
            assert json.loads(r.read()) == {"ok": True}
        with urllib.request.urlopen(
                f"http://{host}:{port}/scenarios", timeout=10) as r:
            assert "poisson" in json.loads(r.read())["processes"]
    finally:
        server.shutdown()
        t.join(10)
        server.service.shutdown()
        server.server_close()
    assert not t.is_alive()                  # clean shutdown


# ---------------------------------------------------------------------------
# acceptance: seeded chaos-under-load is reproducible end to end
# ---------------------------------------------------------------------------

def _probe_profile(units=4):
    return SynapseProfile(
        command="svc-probe",
        samples=[Sample(index=i, resources=_rv(flops=FPI, hbm=BPI))
                 for i in range(units)])


def _chaos_load_run():
    em = Emulator(compute_tile=TILE, mem_block=BLOCK)
    arrivals = PoissonArrivals(rate_hz=20.0, n_requests=12,
                               scenario="svc_probe", seed=11)
    config = FleetConfig.process(
        max_workers=1,
        chaos=ChaosPolicy(seed=3, kill_every=5, max_faults=1),
        liveness_timeout=5.0, max_respawns=6, timeout=300.0)
    return run_load(em, arrivals, config=config,
                    slo=SLO(target_ms=100.0, percentile=0.999),
                    window_s=0.5)


@pytest.mark.slow
@pytest.mark.subproc
def test_seeded_chaos_storm_reproducible_and_mttr_lands_in_p999():
    """The PR 8 acceptance contract: same (arrival seed, chaos seed) =>
    identical arrival timeline and fault schedule run to run, exact
    request totals, and the kill's MTTR visible in the faulted windows'
    p999 — asserted, not printed."""
    from repro.scenarios import register
    from repro.scenarios.base import _REGISTRY
    register("svc_probe", "exact-amount service probe", units=4)(
        _probe_profile)
    try:
        # the arrival timeline is a pure function of the seed
        mk = lambda: PoissonArrivals(rate_hz=20.0, n_requests=12,
                                     scenario="svc_probe", seed=11)
        assert [a.t for a in mk()] == [a.t for a in mk()]

        r1 = _chaos_load_run()
        r2 = _chaos_load_run()
        for rep in (r1, r2):
            assert rep.n_arrivals == 12
            assert rep.serve.n_ok == 12 and rep.serve.n_skipped == 0
            # exact totals: 12 requests x 4 samples, nothing lost to chaos
            assert rep.serve.totals.flops == 12 * 4 * FPI
            assert rep.serve.totals.hbm_bytes == 12 * 4 * BPI
            rec = rep.serve.recovery
            assert rec["worker_deaths"] >= 1      # the kill fired
            assert rec["mttr_s"] and rec["mttr_s"] > 0
            assert rep.slo["n_completed"] == 12
            faulted = [w for w in rep.slo["windows"] if w["faults"]]
            assert faulted, "the kill must mark SLO windows"
            # the interrupted request waited out the respawn: the faulted
            # windows' tail carries a meaningful fraction of the MTTR
            assert max(w["p999"] for w in faulted) >= 0.5 * rec["mttr_s"]
            assert len(rep.slo["faults"]) == rec["worker_deaths"]
        # and the fault schedule itself replays exactly
        assert (r1.serve.recovery["worker_deaths"]
                == r2.serve.recovery["worker_deaths"])
        assert len(r1.slo["faults"]) == len(r2.slo["faults"])
    finally:
        _REGISTRY.pop("svc_probe", None)
