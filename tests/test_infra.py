"""Infrastructure units: checkpoint atomicity/elastic restore, data pipeline
determinism, HLO walker parsing, layer plan, input specs."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import SHAPES, get_config, list_archs, cell_is_runnable
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.core.hlo_analysis import (ModuleCost, analyze_hlo, parse_module,
                                     shape_bytes, shape_numel)


# ---------------------------------------------------------------------------
# checkpoints
# ---------------------------------------------------------------------------

def _state(seed=0):
    k = jax.random.key(seed)
    return {"params": {"w": jax.random.normal(k, (8, 16)),
                       "b": jnp.zeros((16,))},
            "opt": {"mu": {"w": jnp.ones((8, 16)), "b": jnp.zeros((16,))},
                    "step": jnp.int32(7)}}


def test_checkpoint_roundtrip_and_gc(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for step in (10, 20, 30):
        cm.save(step, _state(step))
    assert cm.all_steps() == [20, 30]            # gc keeps 2
    got, extra = cm.restore(20)
    np.testing.assert_allclose(np.asarray(got["params"]["w"]),
                               np.asarray(_state(20)["params"]["w"]))
    assert int(got["opt"]["step"]) == 7


def test_checkpoint_uncommitted_invisible(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(5, _state())
    # simulate a crash mid-write: drop the COMMIT marker
    os.remove(os.path.join(str(tmp_path), "step_00000005", "COMMIT"))
    assert cm.latest_step() is None


def test_checkpoint_corruption_detected(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, _state())
    d = os.path.join(str(tmp_path), "step_00000001")
    victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(d, victim))
    np.save(os.path.join(d, victim), arr + 1)
    with pytest.raises(IOError, match="corruption"):
        cm.restore(1)


def test_checkpoint_async(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save_async(3, _state())
    cm.wait()
    assert cm.latest_step() == 3


def test_checkpoint_elastic_restore_reshards(tmp_path):
    """Restore places leaves with provided shardings (elastic re-layout)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, _state())
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"params": {"w": NamedSharding(mesh, P("data")),
                     "b": NamedSharding(mesh, P())},
          "opt": {"mu": {"w": NamedSharding(mesh, P()),
                         "b": NamedSharding(mesh, P())},
                  "step": NamedSharding(mesh, P())}}
    got, _ = cm.restore(1, shardings=sh)
    assert got["params"]["w"].sharding.is_equivalent_to(
        sh["params"]["w"], 2)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=97, seq_len=32, global_batch=8, seed=5)
    d1, d2 = SyntheticLM(cfg), SyntheticLM(cfg)
    b1 = d1.batch_at(42)
    b2 = d2.batch_at(42)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # shifted-target invariant
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["targets"][:, :-1]))


def test_data_shards_are_disjoint_slices():
    cfg = DataConfig(vocab_size=97, seq_len=16, global_batch=8, seed=1)
    d = SyntheticLM(cfg)
    s0 = d.batch_at(3, shard_index=0, num_shards=2)
    s1 = d.batch_at(3, shard_index=1, num_shards=2)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(s0["tokens"]),
                              np.asarray(s1["tokens"]))


@given(step=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_data_structure_learnable(step):
    cfg = DataConfig(vocab_size=64, seq_len=64, global_batch=2, seed=0,
                     structure=1.0)
    b = SyntheticLM(cfg).batch_at(step)
    t = np.asarray(b["tokens"])
    # fully structured: next = (31*t + 17) % V
    np.testing.assert_array_equal((31 * t[:, :-1] + 17) % 64, t[:, 1:])


# ---------------------------------------------------------------------------
# HLO walker units
# ---------------------------------------------------------------------------

def test_shape_parsing():
    assert shape_bytes("f32[512,1024]{1,0}") == 512 * 1024 * 4
    assert shape_bytes("bf16[8]") == 16
    assert shape_bytes("(f32[2,2]{1,0}, s32[3])") == 16 + 12
    assert shape_bytes("pred[]") == 1
    assert shape_numel("f32[3,5]") == 15


SYNTH_HLO = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ip, %d)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%z, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
  %ar = f32[8,8] all-reduce(%a), channel_id=1, replica_groups={{0,1,2,3}}, to_apply=%body
  ROOT %r = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_walker_trip_count_and_collectives_synthetic():
    cost = analyze_hlo(SYNTH_HLO)
    # 5 iterations of an 8x8x8 matmul
    assert cost.flops >= 5 * 2 * 8 ** 3
    assert cost.flops < 5 * 2 * 8 ** 3 + 100     # + add ops
    coll = cost.collective_bytes()
    # ring all-reduce of 256B over 4 devices: 2*256*3/4
    assert coll["all-reduce"] == pytest.approx(2 * 256 * 3 / 4)


# ---------------------------------------------------------------------------
# layer plan + cell gating
# ---------------------------------------------------------------------------

def test_layer_plan_shapes():
    from repro.models.transformer import layer_plan
    plans = {a: layer_plan(get_config(a)) for a in list_archs()}
    assert plans["qwen2-7b"] == [("scan", 0, 28, False)]
    assert plans["gemma2-2b"] == [("pair_scan", 13)]
    hy = plans["hymba-1.5b"]
    kinds = [g[0] for g in hy]
    assert kinds == ["single", "scan", "single", "scan", "single"]
    total = sum(1 if g[0] == "single" else g[2] for g in hy)
    assert total == 32


def test_cell_gating_counts():
    runnable = skipped = 0
    for a in list_archs():
        for s in SHAPES.values():
            ok, why = cell_is_runnable(get_config(a), s)
            runnable += ok
            skipped += not ok
            if not ok:
                assert why
    assert runnable == 32 and skipped == 8
