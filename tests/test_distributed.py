"""Distribution correctness on 8 forced host devices (subprocess: XLA fixes
the device count at first init, so these tests re-exec python with
XLA_FLAGS).  Verifies:

  * sharded train step == single-device train step (numerics)
  * decode on a mesh == decode on one device
  * collective atom moves the planned bytes (walker cross-check)
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


@pytest.mark.subproc
def test_sharded_train_step_matches_single_device():
    _run("""
    import jax, numpy as np
    import jax.numpy as jnp
    from repro.configs.base import ModelConfig
    from repro.configs.run import RunConfig
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models.model_zoo import build_model
    from repro.optim.adamw import OptConfig
    from repro.parallel.sharding import TRAIN_RULES, make_rules
    from repro.train.step import (init_train_state, make_train_step,
                                  train_state_specs)
    from jax.sharding import NamedSharding

    cfg = ModelConfig(name="d", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=256, tie_embeddings=True)
    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    remat="none", loss_chunk=0)
    model = build_model(cfg, run)
    data = SyntheticLM(DataConfig(vocab_size=256, seq_len=64, global_batch=8))
    batch = data.batch_at(0)
    opt = OptConfig(lr=1e-2, warmup_steps=1, decay_steps=100,
                    weight_decay=0.0)

    # single device
    state0 = init_train_state(model, jax.random.key(0))
    step0 = jax.jit(make_train_step(model, opt))
    s0, m0 = step0(state0, batch)

    # 2x4 mesh
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = make_rules(mesh, TRAIN_RULES)
    specs = train_state_specs(model, mesh, rules)
    state1 = init_train_state(model, jax.random.key(0))
    state1 = jax.tree.map(
        lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)),
        state1, specs)
    step1 = jax.jit(make_train_step(model, opt, mesh))
    s1, m1 = step1(state1, batch)

    np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]),
                               rtol=1e-4)
    for a, b in zip(jax.tree.leaves(s0["params"]),
                    jax.tree.leaves(s1["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-3)
    print("OK sharded==single")
    """)


@pytest.mark.subproc
def test_sharded_decode_matches_single_device():
    _run("""
    import jax, numpy as np
    import jax.numpy as jnp
    from repro.configs import get_config, reduced_config
    from repro.configs.run import RunConfig
    from repro.models.model_zoo import build_model
    from repro.serve.step import make_decode_step, make_prefill_step

    cfg = reduced_config(get_config("gemma2-2b"))
    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    cache_dtype="float32", remat="none")
    model = build_model(cfg, run)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (8, 8), 0, cfg.vocab_size)

    pre0 = jax.jit(make_prefill_step(model, max_len=16))
    dec0 = jax.jit(make_decode_step(model))
    t0, c0 = pre0(params, {"tokens": toks})
    outs0 = [int(x) for x in np.asarray(t0[:, 0])]
    for _ in range(4):
        t0, c0 = dec0(params, t0, c0)
        outs0.extend(int(x) for x in np.asarray(t0[:, 0]))

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    pre1 = jax.jit(make_prefill_step(model, max_len=16, mesh=mesh))
    dec1 = jax.jit(make_decode_step(model, mesh=mesh))
    t1, c1 = pre1(params, {"tokens": toks})
    outs1 = [int(x) for x in np.asarray(t1[:, 0])]
    for _ in range(4):
        t1, c1 = dec1(params, t1, c1)
        outs1.extend(int(x) for x in np.asarray(t1[:, 0]))
    assert outs0 == outs1, (outs0, outs1)
    print("OK decode sharded==single")
    """)


@pytest.mark.subproc
def test_collective_atom_and_walker_agree():
    _run("""
    import jax, numpy as np
    from repro.core.atoms import CollectiveAtom
    from repro.core.hlo_analysis import analyze_hlo

    mesh = jax.make_mesh((8,), ("model",))
    atom = CollectiveAtom(mesh, axis="model", kind="all-reduce")
    wire = 8 * 1024 * 1024.0
    thunk = atom.plan(wire)
    got = thunk()
    # the plan reports the QUANTIZED amount it emulates (whole elements
    # per shard), within one element-row of the requested wire bytes
    assert abs(got - wire) / wire < 1e-3
    n_elems = list(atom._fns.keys())[0]
    assert got == atom.quantized_wire_bytes(n_elems)
    # cross-check with the walker on the same program
    fn = atom._coll_fn(list(atom._fns.keys())[0])
    n = list(atom._fns.keys())[0]
    lowered = fn.lower(jax.ShapeDtypeStruct((n,), np.float32))
    cost = analyze_hlo(lowered.compile().as_text())
    total = cost.collective_total
    assert abs(total - wire) / wire < 0.05, (total, wire)
    print("OK atom bytes == walker bytes")
    """)
