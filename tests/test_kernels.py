"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps
plus hypothesis property tests (assignment deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.compute_atom import kernel as ck, ops as cops, ref as cref
from repro.kernels.flash_attention import (kernel as fk, ops as fops,
                                           ref as fref)
from repro.kernels.memory_atom import kernel as mk, ops as mops, ref as mref


# ---------------------------------------------------------------------------
# compute atom
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tile", [8, 64, 128])
@pytest.mark.parametrize("iters", [1, 3, 17])
def test_compute_atom_matches_ref(tile, iters):
    x = jax.random.normal(jax.random.key(0), (tile, tile)) * 0.1
    got = ck.burn_tile(x, iters=iters, interpret=True)
    want = cref.burn_tile(x, iters=iters)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_compute_atom_ops_flops_accounting():
    out = cops.burn(iters=4, tile=64)
    assert out.shape == (64, 64)
    assert np.isfinite(np.asarray(out)).all()
    assert cref.flops(64, 4) == 2 * 64 ** 3 * 4


# ---------------------------------------------------------------------------
# memory atom
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,block", [(256, 64), (1024, 1024), (4096, 512)])
def test_memory_atom_matches_ref(n, block, dtype):
    x = jnp.arange(n, dtype=dtype)
    got = mk.stream_pass(x, block=block, interpret=True)
    want = mref.stream_pass(x)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=1e-2)


def test_memory_atom_multi_pass():
    x = jnp.ones((2048,), jnp.float32)
    out = mops.stream(x, iters=5, block=256)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(x) * 1.0000001 ** 5, rtol=1e-5)
    assert mref.bytes_moved(2048 * 4, 5) == 2 * 2048 * 4 * 5


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

SWEEP = [
    # (BH, BKV, S, hd, bq, bkv, causal, window, softcap)
    (2, 2, 64, 16, 16, 16, True, None, None),
    (2, 2, 64, 16, 32, 16, True, 9, None),
    (2, 2, 64, 16, 16, 32, True, None, 30.0),
    (4, 2, 32, 8, 8, 8, True, None, None),     # GQA group=2
    (3, 1, 48, 32, 16, 16, False, None, None),  # cross-attn-like, group=3
    (2, 2, 128, 64, 64, 32, True, 40, 25.0),
]


@pytest.mark.parametrize("case", SWEEP)
def test_flash_attention_matches_ref(case):
    BH, BKV, S, hd, bq, bkv, causal, window, softcap = case
    group = BH // BKV
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (BH, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (BKV, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (BKV, S, hd), jnp.float32)
    got = fk.flash_attention(q, k, v, causal=causal, window=window,
                             softcap=softcap, block_q=bq, block_kv=bkv,
                             group=group, interpret=True)
    want = fref.flash_attention(q, k, v, causal=causal, window=window,
                                softcap=softcap, group=group)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, atol):
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (2, 64, 32), dtype)
    k = jax.random.normal(ks[1], (2, 64, 32), dtype)
    v = jax.random.normal(ks[2], (2, 64, 32), dtype)
    got = fk.flash_attention(q, k, v, causal=True, block_q=16, block_kv=16,
                             interpret=True)
    want = fref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol,
                               rtol=atol)


def test_flash_attention_grouped_layout_matches_model_layer():
    from repro.models.layers import attend_full
    B, S, Hk, G, hd = 2, 32, 2, 3, 16
    ks = jax.random.split(jax.random.key(3), 3)
    qg = jax.random.normal(ks[0], (B, S, Hk, G, hd))
    k = jax.random.normal(ks[1], (B, S, Hk, hd))
    v = jax.random.normal(ks[2], (B, S, Hk, hd))
    got = fops.flash_attention_grouped(qg, k, v, causal=True, block_q=8,
                                       block_kv=8)
    pos = jnp.arange(S)
    want = attend_full(qg, k, v, q_pos=pos, k_pos=pos, causal=True,
                       window=None, softcap=None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@given(
    s_blocks=st.integers(1, 4), bq=st.sampled_from([8, 16]),
    bkv=st.sampled_from([8, 16]), hd=st.sampled_from([8, 16]),
    causal=st.booleans(),
    window=st.one_of(st.none(), st.integers(1, 40)),
    seed=st.integers(0, 2**30),
)
@settings(max_examples=25, deadline=None)
def test_flash_attention_property(s_blocks, bq, bkv, hd, causal, window,
                                  seed):
    S = 16 * s_blocks
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (1, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (1, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (1, S, hd), jnp.float32)
    got = fk.flash_attention(q, k, v, causal=causal, window=window,
                             block_q=min(bq, S), block_kv=min(bkv, S),
                             interpret=True)
    want = fref.flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)
