"""Chaos harness + liveness layer (ISSUE 7 contracts).

Fast tests pin the deterministic fault engine in-process: identically
seeded ``ChaosPolicy`` actors replay identical fault traces (across
pickling, the way a policy actually travels to workers), ordinal streams
stay aligned when fault kinds are toggled, and ``corrupt_bytes`` turns a
well-framed payload into a loud ``FramingError`` end to end.  The
scheduler hardening is pinned on in-process loopback peers: hung-peer
liveness reaps a silent-but-connected peer and requeues its work, the
per-bundle attempt budget is configurable (``max_attempts``),
``on_failure="skip"`` completes a stream degraded with the holes folded
past in index order, speculation re-dispatches stragglers with
first-result-wins, a peer dying during ``warmup()`` is reaped without
touching pending work, and agent-style ``retry`` replies keep the
attempt/poison accounting exact under autoscale.

Subprocess tests (``slow`` + ``subproc``) pin the engine on real
workers: a seeded kill schedule reproduces the same death/requeue counts
run after run with totals bit-identical to a fault-free replay, a hung
worker (heartbeats paused, pipe open) is reaped within the liveness
window instead of the 600s run deadline, a spec that can never
initialize trips ``CrashLoopError`` instead of burning the respawn
budget, and the same policy drives the same fault schedule through a
remote agent on loopback TCP.
"""
import multiprocessing as mp
import pickle
import socket
import time

import pytest

from repro.core import Emulator, ResourceVector, Sample, SynapseProfile
from repro.core.emulator import EmulationReport, ReportFold
from repro.fleet import (ChaosPolicy, CrashLoopError, FleetBase,
                         FleetConfig, MeshSpec, Peer, PeerGone,
                         ProcessFleet, ScheduleBundle, WorkerSpec)
from repro.fleet.transport import framing

TILE = 64
BLOCK = 1 << 18
FPI = 2.0 * TILE ** 3
BPI = 2.0 * BLOCK


def _em(**kw):
    return Emulator(compute_tile=TILE, mem_block=BLOCK, **kw)


def _rv(flops=0.0, hbm=0.0):
    return ResourceVector(flops=flops, hbm_bytes=hbm)


def _profile(rvs, command="chaos-test"):
    return SynapseProfile(command=command,
                          samples=[Sample(index=i, resources=r)
                                   for i, r in enumerate(rvs)])


# ---------------------------------------------------------------------------
# policy + actor determinism (fast, pure)
# ---------------------------------------------------------------------------

def test_chaos_policy_validates():
    with pytest.raises(ValueError, match="kill_every"):
        ChaosPolicy(kill_every=0)
    with pytest.raises(ValueError, match="kill_prob"):
        ChaosPolicy(kill_prob=1.5)
    with pytest.raises(ValueError, match="fail_nth"):
        ChaosPolicy(fail_nth=-1)
    with pytest.raises(ValueError, match="delay_s"):
        ChaosPolicy(delay_s=-0.1)
    with pytest.raises(ValueError, match="max_faults"):
        ChaosPolicy(max_faults=-1)
    assert not ChaosPolicy().active
    assert ChaosPolicy(kill_every=3).active


def test_chaos_actor_deterministic_across_pickle():
    """The determinism contract: an actor's decision at ordinal n is a
    pure function of (policy, scope, n) — including after the policy
    rode a pickle to another process, and NOT keyed on Python's salted
    hash()."""
    pol = ChaosPolicy(seed=11, kill_prob=0.3, delay_every=7, delay_s=0.5,
                      max_faults=5)
    twin = pickle.loads(pickle.dumps(pol))
    a, b = pol.actor("worker:2"), twin.actor("worker:2")
    ta = [a.on_dispatch() for _ in range(50)]
    tb = [b.on_dispatch() for _ in range(50)]
    assert ta == tb
    assert a.trace == b.trace and len(a.trace) == 5   # max_faults cap
    # different scopes draw different streams
    c = pol.actor("worker:3")
    assert [c.on_dispatch() for _ in range(50)] != ta
    # the coordinator-side RNG is scope-stable too
    assert pol.rng("coordinator").random() == \
        twin.rng("coordinator").random()


def test_chaos_ordinal_streams_stay_aligned():
    """Enabling one fault kind must not shift another's ordinals: the
    kill_prob deaths of a policy land on the same dispatches whether or
    not delays are also scheduled."""
    base = ChaosPolicy(seed=4, kill_prob=0.2)
    plus = ChaosPolicy(seed=4, kill_prob=0.2, delay_every=3, delay_s=0.01)
    kills = lambda p: [n for n, act in enumerate(
        (p.actor("worker:0").on_dispatch() for _ in range(80)), start=1)
        if act == "kill"]
    assert kills(base) == kills(plus)
    # interval kills are exact ordinals
    acts = [ChaosPolicy(seed=0, kill_every=3).actor("w").on_dispatch()
            for _ in range(1)]  # fresh actor each call: ordinal 1 -> None
    assert acts == [None]
    actor = ChaosPolicy(seed=0, kill_every=3, max_faults=1).actor("w")
    seq = [actor.on_dispatch() for _ in range(9)]
    assert seq == [None, None, "kill"] + [None] * 6   # budget spent at 3


def test_chaos_corrupt_bytes_surfaces_as_framing_error():
    """corrupt_frame end to end: the mangled payload is well-framed but
    unpicklable, and recv_frame raises FramingError (-> PeerGone at the
    scheduler) instead of leaking pickle internals."""
    pol = ChaosPolicy()
    payload = pickle.dumps(("ok", 1, 2, {"x": list(range(50))}))
    bad = pol.corrupt_bytes(payload)
    assert len(bad) == len(payload) and bad != payload
    a, b = socket.socketpair()
    a.settimeout(5.0)
    b.settimeout(5.0)
    try:
        framing.send_frame(a, ("ok", 1, 2, {"x": list(range(50))}),
                           _mangle=pol.corrupt_bytes)
        with pytest.raises(framing.FramingError, match="unpickle"):
            framing.recv_frame(b)
    finally:
        a.close()
        b.close()
    # agent-side reply schedule: drop fires after N, corrupt exactly at N
    actor = ChaosPolicy(drop_agent_after=2).actor("agent")
    assert [actor.on_reply() for _ in range(4)] == \
        [None, None, "drop", "drop"]
    actor = ChaosPolicy(corrupt_frame_nth=2).actor("agent")
    assert [actor.on_reply() for _ in range(3)] == [None, "corrupt", None]


# ---------------------------------------------------------------------------
# scheduler hardening (fast, in-process loopback peers)
# ---------------------------------------------------------------------------

class _EchoPeer(Peer):
    """Loopback peer: dispatch writes the ok reply into its own pipe."""

    def __init__(self):
        super().__init__()
        self._r, self._w = mp.Pipe(duplex=False)
        self.ready = True

    @property
    def waitable(self):
        return self._r

    def dispatch(self, epoch, idx, bundle):
        self.tasks.add((epoch, idx))
        self._w.send(("ok", epoch, idx, self._report(bundle)))

    @staticmethod
    def _report(bundle):
        return EmulationReport(command=bundle.command, ttc_s=1e-3,
                               n_samples=bundle.n_profile_samples,
                               consumed=bundle.planned, mode="fused")

    def recv(self):
        return self._r.recv()

    def close(self):
        self._r.close()
        self._w.close()


class _BlackholePeer(_EchoPeer):
    """Accepts dispatches and never replies — the hung-peer vector: the
    pipe stays open, so only the liveness watermark can reap it."""

    def __init__(self):
        super().__init__()
        self.destroyed = False
        self.swallowed = []

    def dispatch(self, epoch, idx, bundle):
        self.tasks.add((epoch, idx))
        self.swallowed.append(idx)

    def destroy(self):
        self.destroyed = True
        super().close()


class _RetryPeer(_EchoPeer):
    """Always replies ("retry", ...) — a peer whose local worker dies on
    every dispatch, the attempt-budget vector."""

    def __init__(self):
        super().__init__()
        self.dispatches = 0

    def dispatch(self, epoch, idx, bundle):
        self.dispatches += 1
        self.tasks.add((epoch, idx))
        self._w.send(("retry", epoch, idx, "test: local worker died"))


class _FlakyPeer(_EchoPeer):
    """Replies ("retry", ...) on the FIRST dispatch of each idx, serves
    re-dispatches normally — agent-style transient worker loss."""

    def __init__(self):
        super().__init__()
        self._seen = set()

    def dispatch(self, epoch, idx, bundle):
        self.tasks.add((epoch, idx))
        if idx not in self._seen:
            self._seen.add(idx)
            self._w.send(("retry", epoch, idx, "test: flaky"))
        else:
            self._w.send(("ok", epoch, idx, self._report(bundle)))


class _FailPeer(_EchoPeer):
    """Replies ("err", ...) for the indices in ``bad`` — the degraded-
    completion vector."""

    def __init__(self, bad):
        super().__init__()
        self.bad = set(bad)

    def dispatch(self, epoch, idx, bundle):
        self.tasks.add((epoch, idx))
        if idx in self.bad:
            self._w.send(("err", epoch, idx, "test: injected failure"))
        else:
            self._w.send(("ok", epoch, idx, self._report(bundle)))


class _DyingPeer(_EchoPeer):
    """Raises PeerGone on its first recv — a peer that dies while the
    pool warms up."""

    def __init__(self):
        super().__init__()
        self.ready = False
        self._w.send(("ready", {}))     # make the waitable fire

    def recv(self):
        raise PeerGone("test: died during warmup")


class _LoopFleet(FleetBase):
    def __init__(self, peers, *, autoscale=False, scale_max=3,
                 min_workers=1):
        super().__init__()
        self._autoscale = autoscale
        self._scale_min = min_workers
        self._scale_max = scale_max
        self._peers.extend(peers)

    def _scale_up(self):
        if len(self._peers) >= self._scale_max:
            return False
        self._peers.append(_EchoPeer())
        self.scale_ups += 1
        return True


def _bundle(i):
    # awkward float amounts: identical fold totals mean identical order
    return ScheduleBundle(command=f"b{i}", payload={}, n_profile_samples=1,
                          planned=_rv(flops=0.1 * i + 0.3, hbm=0.7 * i))


def _fold(fleet, bundles, **kw):
    fold = ReportFold()
    for idx, rep in fleet.stream(bundles, **kw):
        if rep is None:
            fold.skip(idx)
        else:
            fold.add(idx, rep)
    return fold


def test_stream_liveness_reaps_hung_peer():
    """A ready peer holding in-flight work but silent past
    liveness_timeout is destroyed (no grace) and its bundles requeue
    onto the survivor — the run completes in ~liveness time, not the
    run deadline."""
    hole, echo = _BlackholePeer(), _EchoPeer()
    bundles = [_bundle(i) for i in range(4)]
    t0 = time.monotonic()
    with _LoopFleet([hole, echo]) as fleet:
        fold = _fold(fleet, list(bundles), timeout=60.0,
                     liveness_timeout=0.6)
    elapsed = time.monotonic() - t0
    assert fold.n_done == 4                      # nothing lost
    assert hole.swallowed and hole.destroyed     # it really ate work
    assert fleet.hung_reaped == 1
    rec = fleet.last_recovery
    assert rec["hung_reaped"] == 1
    assert rec["requeued"] >= 1
    assert rec["lost_replay_s"] > 0.0
    assert elapsed < 30.0                        # liveness, not deadline
    # totals match an all-healthy fleet bit for bit
    with _LoopFleet([_EchoPeer()]) as clean:
        ref = _fold(clean, list(bundles))
    assert fold.totals == ref.totals


def test_stream_max_attempts_is_configurable():
    """Satellite: the attempt budget is a knob, not a constant.  A peer
    whose worker dies on every dispatch exhausts exactly max_attempts
    dispatches before the bundle is declared poison."""
    peer = _RetryPeer()
    with _LoopFleet([peer]) as fleet:
        with pytest.raises(RuntimeError, match="poison"):
            _fold(fleet, [_bundle(0)], timeout=30.0, max_attempts=2)
    assert peer.dispatches == 2                  # budget exactly honored
    peer2 = _RetryPeer()
    with _LoopFleet([peer2]) as fleet:
        with pytest.raises(RuntimeError, match="poison"):
            _fold(fleet, [_bundle(0)], timeout=30.0, max_attempts=1)
    assert peer2.dispatches == 1
    with pytest.raises(ValueError, match="max_attempts"):
        with _LoopFleet([_EchoPeer()]) as fleet:
            list(fleet.stream([_bundle(0)], max_attempts=0))


def test_stream_on_failure_skip_completes_degraded():
    """on_failure='skip': failing bundles become holes, the rest of the
    stream drains, the fold advances past the holes in index order, and
    the skip list lands in last_recovery."""
    peer = _FailPeer(bad={1, 3})
    bundles = [_bundle(i) for i in range(6)]
    with _LoopFleet([peer]) as fleet:
        fold = _fold(fleet, list(bundles), on_failure="skip")
    assert fold.n_done == 4 and fold.n_skipped == 2
    assert [r.command for r in fold.reports] == ["b0", "b2", "b4", "b5"]
    assert fleet.last_recovery["skipped"] == [1, 3]
    # bit-identical to folding only the surviving bundles in order
    ref = ReportFold()
    for i in (0, 2, 4, 5):
        ref.add(i, _EchoPeer._report(bundles[i]))
        ref.skip(i + 1) if i in (0, 2) else None
    assert fold.totals == ref.totals
    # exhausted attempt budgets skip the same way (retry-forever peer)
    retry = _RetryPeer()
    with _LoopFleet([retry, _EchoPeer()]) as fleet:
        fold2 = _fold(fleet, [_bundle(9)], timeout=30.0, max_attempts=1,
                      on_failure="skip")
    assert fold2.n_done + fold2.n_skipped == 1
    # the same failure under the default raises
    with _LoopFleet([_FailPeer(bad={0})]) as fleet:
        with pytest.raises(RuntimeError, match="injected failure"):
            _fold(fleet, [_bundle(0)])


def test_stream_speculation_first_result_wins():
    """speculate: with the queue drained and a median established, a
    straggling bundle is re-dispatched to a free peer; the twin's result
    completes it and accounting records the speculative win."""
    hole, echo = _BlackholePeer(), _EchoPeer()
    bundles = [_bundle(i) for i in range(6)]
    with _LoopFleet([hole, echo]) as fleet:
        fold = _fold(fleet, list(bundles), timeout=30.0, speculate=1.5)
    assert fold.n_done == 6                      # the straggler completed
    assert hole.swallowed                        # it really held bundles
    rec = fleet.last_recovery
    assert rec["speculative_dispatches"] >= 1
    assert rec["speculative_wins"] >= 1
    with _LoopFleet([_EchoPeer()]) as clean:
        ref = _fold(clean, list(bundles))
    assert fold.totals == ref.totals             # bit-identical
    with pytest.raises(ValueError, match="speculate"):
        with _LoopFleet([_EchoPeer()]) as fleet:
            list(fleet.stream([_bundle(0)], speculate=0.5))


def test_warmup_death_is_reaped_without_touching_pending():
    """Satellite: a peer dying during warmup() is reaped cleanly — the
    pool keeps its survivors, no pending work is fabricated or lost, and
    the next stream serves normally."""
    dying, echo = _DyingPeer(), _EchoPeer()
    with _LoopFleet([dying, echo]) as fleet:
        fleet.warmup(timeout=10.0)
        assert fleet.worker_deaths == 1
        assert fleet._peers == [echo]
        fold = _fold(fleet, [_bundle(i) for i in range(3)])
    assert fold.n_done == 3
    assert fleet.last_recovery["worker_deaths"] == 0   # none mid-stream


def test_retry_accounting_exact_under_autoscale():
    """Satellite: agent-style ('retry', ...) replies requeue without
    double-charging — under an autoscaling pool every bundle still
    completes exactly once, the requeue count matches the retry count,
    and totals stay bit-identical to a healthy fixed pool."""
    bundles = [_bundle(i) for i in range(10)]
    flaky = _FlakyPeer()
    with _LoopFleet([flaky], autoscale=True, scale_max=3) as fleet:
        fold = _fold(fleet, iter(bundles), timeout=30.0, window=4)
        assert fleet.scale_ups >= 1              # it really grew
    assert fold.n_done == 10
    rec = fleet.last_recovery
    assert rec["requeued"] == len(flaky._seen)   # one requeue per retry
    assert rec["skipped"] == []
    assert rec["requeue_latency_s"] >= 0.0
    with _LoopFleet([_EchoPeer(), _EchoPeer(), _EchoPeer()]) as clean:
        ref = _fold(clean, list(bundles))
    assert fold.totals == ref.totals             # bit-identical
    # a retry keeps its attempt charged: with max_attempts=1 the same
    # flake is poison on the re-dispatch check
    with _LoopFleet([_FlakyPeer()]) as fleet:
        with pytest.raises(RuntimeError, match="poison"):
            _fold(fleet, [_bundle(0)], timeout=30.0, max_attempts=1)


def test_report_fold_skip_advances_past_holes():
    fold = ReportFold()
    rep = _EchoPeer._report(_bundle(1))
    fold.skip(0)
    fold.add(1, rep)
    fold.add(3, _EchoPeer._report(_bundle(3)))
    assert fold.n_done == 1                      # 3 buffered behind hole 2
    fold.skip(2)
    assert fold.n_done == 2 and fold.n_skipped == 2
    assert [r.command for r in fold.reports] == ["b1", "b3"]


def test_fleet_config_robustness_knobs_validate_and_pickle():
    pol = ChaosPolicy(seed=3, kill_every=5, max_faults=1)
    cfg = FleetConfig.process(max_workers=2, chaos=pol,
                              liveness_timeout=2.0, speculate=2.0,
                              on_failure="skip", max_attempts=5,
                              max_respawns=8)
    assert pickle.loads(pickle.dumps(cfg)) == cfg
    assert cfg.chaos == pol and cfg.max_attempts == 5
    rcfg = FleetConfig.remote(["h:1"], chaos=pol, liveness_timeout=1.0)
    assert rcfg.chaos == pol
    with pytest.raises(ValueError, match="max_attempts"):
        FleetConfig.thread(max_attempts=0)
    with pytest.raises(ValueError, match="on_failure"):
        FleetConfig.thread(on_failure="shrug")
    with pytest.raises(ValueError, match="liveness_timeout"):
        FleetConfig.process(liveness_timeout=0.0)
    with pytest.raises(ValueError, match="speculate"):
        FleetConfig.process(speculate=0.9)
    # thread workers have no peer to kill/heartbeat/re-dispatch against
    for bad in (dict(chaos=pol), dict(liveness_timeout=1.0),
                dict(speculate=2.0)):
        with pytest.raises(ValueError, match="process"):
            FleetConfig(executor="thread", **bad)
    # respawn budgets are a local-pool concept
    with pytest.raises(ValueError, match="max_respawns"):
        FleetConfig.remote(["h:1"]).__class__(
            executor="remote", hosts=("h:1",), max_respawns=2)


def test_thread_executor_on_failure_skip():
    """Degraded completion on the thread path: a profile that raises
    mid-replay becomes a recovery['skipped'] hole, not a failed run."""
    em = _em()
    good = [_profile([_rv(flops=FPI * (i + 1))], command=f"t{i}")
            for i in range(4)]
    # fails inside the pool thread (resources=None breaks compile), not in
    # the admission loop — that is the hole skip-mode must tolerate
    bad = SynapseProfile(command="boom",
                         samples=[Sample(index=0, resources=None)])
    out = em.emulate_many(good[:2] + [bad] + good[2:],
                          config=FleetConfig.thread(max_workers=2,
                                                    on_failure="skip"))
    assert out.n_replayed == 4
    assert out.recovery["skipped"] == [2]
    ref = em.emulate_many(good, config=FleetConfig.thread(max_workers=1))
    assert out.totals == ref.totals              # holes don't change bits
    with pytest.raises(Exception):
        em.emulate_many(good[:1] + [bad],
                        config=FleetConfig.thread(max_workers=1))


# ---------------------------------------------------------------------------
# real workers (spawns subprocesses)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.subproc
def test_chaos_kill_schedule_reproducible_on_process_fleet():
    """The tentpole acceptance contract: a seeded kill schedule produces
    the same deaths/requeues run after run, and fault-injected streamed
    totals stay bit-identical to a fault-free replay."""
    from repro.fleet.executor import run_process_fleet
    em = _em()
    profs = [_profile([_rv(flops=FPI * (i + 1), hbm=BPI)],
                      command=f"chaos{i}") for i in range(6)]
    clean = em.emulate_many(profs, config=FleetConfig.process(max_workers=1),
                            collect="totals")
    # one worker => a deterministic dispatch order => exact fault ordinals:
    # worker:0 dies on its 3rd dispatch, its replacement worker:1 dies on
    # ITS 3rd, worker:2 drains the rest.  Same schedule every run.
    pol = ChaosPolicy(seed=5, kill_every=3, max_faults=1)
    outs = []
    for _ in range(2):
        out = run_process_fleet(em, profs, max_workers=1, chaos=pol,
                                max_respawns=4, collect="totals",
                                timeout=300.0)
        outs.append(out)
    for out in outs:
        assert out.n_replayed == 6
        assert out.totals == clean.totals        # bit-identical under chaos
        assert out.recovery["worker_deaths"] == 2
        assert out.recovery["requeued"] == 2
        assert out.recovery["skipped"] == []
        assert out.recovery["lost_replay_s"] > 0.0
        assert out.recovery["mttr_s"] is not None   # refills were measured
        assert out.cache_stats["respawns"] == 2
    assert outs[0].recovery["worker_deaths"] == \
        outs[1].recovery["worker_deaths"]


@pytest.mark.slow
@pytest.mark.subproc
def test_chaos_hung_worker_reaped_by_liveness():
    """A worker that goes silent with its pipe open (heartbeats paused)
    is reaped within ~liveness_timeout and its bundle requeued — the run
    completes far inside the 600s deadline instead of stalling on the
    hang."""
    em = _em()
    profs = [_profile([_rv(flops=FPI, hbm=BPI)], command=f"hang{i}")
             for i in range(4)]
    clean = em.emulate_many(profs, config=FleetConfig.process(max_workers=2),
                            collect="totals")
    pol = ChaosPolicy(seed=9, hang_nth=2, max_faults=1)   # hang_s: 1 hour
    t0 = time.monotonic()
    out = em.emulate_many(
        profs, config=FleetConfig.process(max_workers=2, chaos=pol,
                                          liveness_timeout=2.0),
        collect="totals")
    elapsed = time.monotonic() - t0
    assert out.n_replayed == 4
    assert out.totals == clean.totals            # bit-identical under chaos
    assert out.recovery["hung_reaped"] >= 1      # liveness saw the hang
    assert out.recovery["requeued"] >= 1
    assert out.recovery["heartbeats"] > 0        # pings really flowed
    assert elapsed < 300.0                       # nowhere near hang_s/deadline


@pytest.mark.slow
@pytest.mark.subproc
def test_crash_loop_breaker_trips_instead_of_burning_budget():
    """A spec that dies before initialization trips CrashLoopError after
    crash_loop deaths — the remaining respawn budget is preserved, not
    silently burned."""
    em = _em()
    spec = WorkerSpec(emulator=em.spec(),
                      chaos=ChaosPolicy(kill_on_init=True))
    fleet = ProcessFleet(1, spec, max_respawns=20,
                         respawn_backoff=(0.05, 0.2), crash_loop=(3, 30.0))
    try:
        with pytest.raises(CrashLoopError, match="crash-looping"):
            fleet.warmup(timeout=120.0)
        assert fleet.respawns < 20               # budget NOT exhausted
        assert fleet.worker_deaths == 3          # breaker limit exactly
    finally:
        fleet.close()


@pytest.mark.slow
@pytest.mark.subproc
def test_chaos_schedule_reproduces_over_remote_loopback():
    """Transport parity: the same seeded policy drives the same worker
    fault schedule through a TCP agent — the agent's local worker dies
    on schedule, the bundle comes back as a retry, and totals stay
    bit-identical to a clean replay."""
    import os
    import subprocess
    import sys

    from repro.fleet import RemoteFleet
    from repro.fleet.transport.remote import run_remote_fleet

    em = _em()
    profs = [_profile([_rv(flops=FPI * (i + 1), hbm=BPI)],
                      command=f"rchaos{i}") for i in range(6)]
    refs = [em.emulate(p, fused=True) for p in profs]
    em.storage.cleanup()
    # 1 agent x 1 worker: worker:0 serves 3 bundles and dies on its 4th
    # dispatch; the agent respawns worker:1 (inside its default budget),
    # which drains the remaining 3.  One death, one requeue — exactly.
    pol = ChaosPolicy(seed=2, kill_every=4, max_faults=1)
    fleet = RemoteFleet(WorkerSpec(emulator=em.spec(), chaos=pol),
                        listen="127.0.0.1:0", agents=1)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.fleet.agent",
         "--connect", f"127.0.0.1:{fleet.bound_addr[1]}", "--workers", "1"],
        env=env)
    try:
        out = run_remote_fleet(em, profs, fleet=fleet, timeout=300.0)
    finally:
        fleet.close()
        try:
            proc.wait(timeout=30.0)
        except subprocess.TimeoutExpired:
            proc.kill()
            proc.wait(timeout=10.0)
    assert out.n_replayed == 6
    for ref, rep in zip(refs, out.reports):
        assert rep.consumed == ref.consumed      # bit-identical under chaos
    assert out.recovery["requeued"] == 1         # the scheduled death
    assert out.recovery["worker_deaths"] == 0    # the AGENT never died
    assert out.recovery["skipped"] == []
