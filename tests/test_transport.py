"""Multi-host fleet transport (ISSUE 4 contracts).

Fast tests pin the framing layer on socketpairs: versioned hellos reject
strangers and version skew, frames round-trip arbitrary objects, and
every corruption mode — truncated header, truncated payload, oversized
length header, mid-frame disconnect — fails loudly with a typed error
instead of hanging or feeding garbage to pickle.

Subprocess tests (marked ``slow`` + ``subproc``) pin the remote
executor against real ``python -m repro.fleet.agent`` processes on
localhost: a two-agent fleet reports consumed totals bit-identical to
in-process fused replay (collective legs included, executing on each
agent's per-worker mesh), and SIGKILLing an agent leaves a fleet that
completes every bundle on the survivor via requeue.
"""
import os
import signal
import socket
import struct
import subprocess
import sys
import threading
import time

import pytest

from repro.core import Emulator, ResourceVector, Sample, SynapseProfile
from repro.fleet import MeshSpec, RemoteFleet, WorkerSpec, bundle_profile
from repro.fleet.transport import framing
from repro.fleet.transport.remote import parse_addr
from repro.scenarios import generate, run_fleet

TILE = 64
BLOCK = 1 << 18
FPI = 2.0 * TILE ** 3
BPI = 2.0 * BLOCK

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


def _em(**kw):
    return Emulator(compute_tile=TILE, mem_block=BLOCK, **kw)


def _rv(flops=0.0, hbm=0.0, sw=0.0, sr=0.0, ici=0.0):
    return ResourceVector(flops=flops, hbm_bytes=hbm,
                          storage_write_bytes=sw, storage_read_bytes=sr,
                          ici_bytes={"all-reduce": ici} if ici else {})


def _profile(rvs, command="transport-test"):
    return SynapseProfile(command=command,
                          samples=[Sample(index=i, resources=r)
                                   for i, r in enumerate(rvs)])


# ---------------------------------------------------------------------------
# framing layer (fast, socketpairs)
# ---------------------------------------------------------------------------

def _pair():
    a, b = socket.socketpair()
    a.settimeout(5.0)
    b.settimeout(5.0)
    return a, b


def test_hello_and_frame_roundtrip():
    a, b = _pair()
    t = threading.Thread(target=framing.handshake, args=(a,))
    t.start()
    framing.handshake(b)
    t.join()
    msg = ("run", 3, 7, {"payload": list(range(100))})
    framing.send_frame(a, msg)
    assert framing.recv_frame(b) == msg
    a.close()
    b.close()


def test_hello_rejects_wrong_magic_and_version():
    a, b = _pair()
    a.sendall(b"HTTP/1.1 200 OK\r\n")           # not a fleet endpoint
    with pytest.raises(framing.FramingError, match="magic"):
        framing.recv_hello(b)
    c, d = _pair()
    c.sendall(struct.pack(">4sHH", framing.MAGIC, framing.VERSION + 9, 0))
    with pytest.raises(framing.VersionMismatch, match="v10"):
        framing.recv_hello(d)
    for s in (a, b, c, d):
        s.close()


def test_truncated_header_fails_loudly():
    a, b = _pair()
    a.sendall(b"\x00\x00")                      # 2 of 4 header bytes
    a.close()
    with pytest.raises(framing.FramingError, match="mid-frame header"):
        framing.recv_frame(b)
    b.close()


def test_truncated_payload_fails_loudly():
    a, b = _pair()
    a.sendall(struct.pack(">I", 1000) + b"x" * 10)   # announce 1000, send 10
    a.close()
    with pytest.raises(framing.FramingError, match="10 of 1000"):
        framing.recv_frame(b)
    b.close()


def test_oversized_length_header_rejected_before_allocation():
    a, b = _pair()
    a.sendall(struct.pack(">I", framing.MAX_FRAME_BYTES + 1))
    with pytest.raises(framing.FramingError, match="corrupt stream"):
        framing.recv_frame(b)
    a.close()
    b.close()


def test_mid_run_disconnect_is_typed_not_a_hang():
    a, b = _pair()
    # clean EOF between frames: the peer is gone
    a.close()
    with pytest.raises(framing.TransportClosed):
        framing.recv_frame(b)
    b.close()
    # disconnect while the receiver is mid-frame (reader already blocked)
    c, d = _pair()
    c.sendall(struct.pack(">I", 1 << 20))       # header only, then vanish
    errs = []

    def reader():
        try:
            framing.recv_frame(d)
        except framing.TransportError as e:
            errs.append(e)

    t = threading.Thread(target=reader)
    t.start()
    time.sleep(0.1)
    c.close()
    t.join(timeout=5.0)
    assert not t.is_alive(), "recv_frame hung on a dead peer"
    assert len(errs) == 1 and isinstance(errs[0], framing.FramingError)
    d.close()


def test_oversized_send_refused():
    a, b = _pair()
    with pytest.raises(framing.FramingError, match="refusing to send"):
        framing.send_frame(a, b"x" * (framing.MAX_FRAME_BYTES + 1))
    a.close()
    b.close()


def test_parse_addr():
    assert parse_addr("10.0.0.1:9000") == ("10.0.0.1", 9000)
    assert parse_addr("9000") == ("127.0.0.1", 9000)
    with pytest.raises(ValueError):
        parse_addr("no-port")


# ---------------------------------------------------------------------------
# coordinator-side guard rails (fast, no sockets beyond loopback binds)
# ---------------------------------------------------------------------------

def test_remote_fleet_rejects_agentless_config():
    spec = WorkerSpec(emulator=_em().spec())
    with pytest.raises(ValueError, match="hosts"):
        RemoteFleet(spec)
    with pytest.raises(ValueError, match="listen"):
        RemoteFleet(spec, agents=2)


def test_unknown_executor_lists_choices():
    em = _em()
    prof = _profile([_rv(flops=FPI)])
    with pytest.raises(ValueError, match="'thread', 'process', 'remote'"):
        em.emulate_many([prof], executor="carrier-pigeon")
    with pytest.raises(ValueError, match="'thread', 'process', 'remote'"):
        run_fleet([("mixed_fleet", {"total_samples": 4})],
                  executor="carrier-pigeon")
    # remote-only knobs are refused on other executors, not ignored —
    # including 'process', which would otherwise run locally while the
    # caller believes remote hosts participated
    with pytest.raises(ValueError, match="remote"):
        em.emulate_many([prof], executor="thread", hosts=["h:1"])
    with pytest.raises(ValueError, match="remote"):
        em.emulate_many([prof], executor="process", listen="127.0.0.1:0")
    with pytest.raises(ValueError, match="jobs and/or profiles"):
        run_fleet([])


# ---------------------------------------------------------------------------
# remote executor against real agents (spawns subprocesses)
# ---------------------------------------------------------------------------

def _agent_env():
    env = dict(os.environ)
    old = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = SRC + (os.pathsep + old if old else "")
    return env


def _spawn_agents(port, n, workers=1):
    return [subprocess.Popen(
        [sys.executable, "-m", "repro.fleet.agent",
         "--connect", f"127.0.0.1:{port}", "--workers", str(workers)],
        env=_agent_env()) for _ in range(n)]


def _drain(procs, timeout=30.0):
    for p in procs:
        try:
            p.wait(timeout=timeout)
        except subprocess.TimeoutExpired:
            p.kill()
            p.wait(timeout=10.0)


@pytest.mark.slow
@pytest.mark.subproc
def test_remote_fleet_bit_identical_including_collectives():
    """The ISSUE 4 acceptance contract: two localhost agents replay a
    fleet (mixed_fleet with collective legs included) with consumed
    totals bit-identical to in-process fused replay, collectives
    executing on each agent's per-worker mesh."""
    em = _em()
    profiles = [generate("mixed_fleet", total_samples=6, seed=1),
                generate("mixed_fleet", total_samples=6, seed=2),
                generate("training_scan", n_steps=4, ckpt_every=2,
                         flops_per_step=4e7, hbm_per_step=2e6,
                         ckpt_bytes=2 << 20),
                _profile([_rv(flops=FPI), _rv(flops=FPI, ici=4e6),
                          _rv(hbm=BPI)], command="transport-test:coll")]
    refs = [em.emulate(p, fused=True) for p in profiles]
    em.storage.cleanup()

    fleet = RemoteFleet(WorkerSpec(emulator=em.spec(),
                                   mesh=MeshSpec(shape=(2,),
                                                 axes=("model",))),
                        listen="127.0.0.1:0", agents=2)
    procs = _spawn_agents(fleet.bound_addr[1], 2)
    try:
        # the one-call surface, reusing the pre-bound listener via fleet=
        from repro.fleet.transport.remote import run_remote_fleet
        out = run_remote_fleet(em, profiles, mesh_spec=MeshSpec(
            shape=(2,), axes=("model",)), fleet=fleet)
    finally:
        fleet.close()
        _drain(procs)
    assert out.n_profiles == len(profiles)
    assert out.cache_stats["agents"] == 2
    assert out.cache_stats["workers"] == 2
    assert out.cache_stats["worker_deaths"] == 0
    for ref, rep in zip(refs, out.reports):
        assert rep.mode == "fused"
        assert rep.consumed == ref.consumed          # bit-identical
        assert rep.n_samples == ref.n_samples
    coll = out.reports[-1]
    assert coll.consumed.ici_total == 4e6
    assert coll.n_collective_dispatches > 0          # it really executed


@pytest.mark.slow
@pytest.mark.subproc
def test_remote_fleet_survives_agent_kill_with_requeue():
    """Killing one agent leaves its socket EOF'd: the scheduler reaps it
    like a dead process, requeues its in-flight bundles, and the run
    completes on the survivor."""
    em = _em()
    bundles = [bundle_profile(em, _profile(
        [_rv(flops=FPI, hbm=BPI), _rv(flops=2 * FPI), _rv(hbm=2 * BPI)],
        command=f"transport-test:{i}")) for i in range(8)]
    ref = em.emulate(_profile(
        [_rv(flops=FPI, hbm=BPI), _rv(flops=2 * FPI), _rv(hbm=2 * BPI)]),
        fused=True)
    em.storage.cleanup()

    fleet = RemoteFleet(WorkerSpec(emulator=em.spec()),
                        listen="127.0.0.1:0", agents=2)
    procs = _spawn_agents(fleet.bound_addr[1], 2)
    try:
        fleet.warmup(timeout=180.0)
        assert fleet.n_agents == 2 and fleet.n_workers == 2
        os.kill(procs[0].pid, signal.SIGKILL)        # one agent dies
        reports = fleet.run(bundles, timeout=120.0)
        assert len(reports) == len(bundles)          # nothing lost
        assert fleet.worker_deaths >= 1
        assert fleet.n_agents == 1                   # survivor drained it
        assert all(r.consumed == ref.consumed for r in reports)
        assert [r.command for r in reports] == \
            [b.command for b in bundles]
        # the surviving fleet keeps serving
        again = fleet.run(bundles[:2], timeout=120.0)
        assert [r.consumed for r in again] == \
            [r.consumed for r in reports[:2]]
    finally:
        fleet.close()
        _drain(procs)


@pytest.mark.slow
@pytest.mark.subproc
def test_remote_fleet_dial_mode_through_emulate_many():
    """The other join topology: agents listen, the coordinator dials
    ``hosts=[...]`` straight through ``Emulator.emulate_many`` — and a
    plain TCP consumer of the agent port is refused by the handshake."""
    agent = subprocess.Popen(
        [sys.executable, "-m", "repro.fleet.agent",
         "--listen", "127.0.0.1:0", "--workers", "1"],
        env=_agent_env(), stdout=subprocess.PIPE, text=True)
    try:
        line = agent.stdout.readline()
        assert "listening on" in line, line
        addr = line.strip().rsplit(" ", 1)[-1]
        em = _em()
        profiles = [generate("fanout_straggler", n_workers=3,
                             work_flops=5e7, work_hbm=4e7, jitter=0.0,
                             seed=7) for _ in range(3)]
        refs = [em.emulate(p, fused=True) for p in profiles]
        em.storage.cleanup()
        out = em.emulate_many(profiles, executor="remote", hosts=[addr])
        assert out.cache_stats["agents"] == 1
        for ref, rep in zip(refs, out.reports):
            assert rep.consumed == ref.consumed
    finally:
        _drain([agent])
    assert agent.returncode == 0                     # polite stop, not kill
