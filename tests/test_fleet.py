"""Process-level fleet executor (ISSUE 3 contracts).

Fast tests pin the serialization layer in-process: detach/rehydrate and
``ScheduleBundle`` pickling are bit-identical round-trips, emulator/atom
specs rebuild equivalent emulators, and ``keep_collectives`` controls
whether wire-byte runs lower to executable barrier steps.

Process tests (marked ``slow`` + ``subproc`` — deselect with
``-m "not slow"`` while iterating) pin the executor: a process fleet
reports consumed totals bit-identical to in-process fused replay for every
profile, collective legs execute on per-worker meshes (nonzero collective
dispatches — the first fleet mode where they do), worker death mid-run is
survived with every bundle still reported, and a poison bundle fails the
run instead of hanging it.
"""
import os
import pickle
import signal

import numpy as np
import pytest

from repro.core import (BarrierStep, Emulator, FusedSegment, ResourceVector,
                        Sample, SynapseProfile, rehydrate_schedule)
from repro.fleet import (MeshSpec, ProcessFleet, ScheduleBundle, WorkerSpec,
                         bundle_profile)
from repro.scenarios import generate

TILE = 64                  # 1 compute iter = 2*64^3  = 524288 flops
BLOCK = 1 << 18            # 1 memory  iter = 2*2^18  = 524288 bytes
FPI = 2.0 * TILE ** 3
BPI = 2.0 * BLOCK


def _em(**kw):
    return Emulator(compute_tile=TILE, mem_block=BLOCK, **kw)


def _rv(flops=0.0, hbm=0.0, sw=0.0, sr=0.0, ici=0.0):
    return ResourceVector(flops=flops, hbm_bytes=hbm,
                          storage_write_bytes=sw, storage_read_bytes=sr,
                          ici_bytes={"all-reduce": ici} if ici else {})


def _profile(rvs, command="fleet-test"):
    return SynapseProfile(command=command,
                          samples=[Sample(index=i, resources=r)
                                   for i, r in enumerate(rvs)])


def _mixed(tag, ici=0.0):
    """Compute/memory runs split by a storage barrier (and an ici leg)."""
    return _profile([_rv(flops=FPI, hbm=BPI), _rv(flops=2 * FPI),
                     _rv(flops=FPI, sw=2 << 20, sr=1 << 20),
                     _rv(flops=FPI, ici=ici),
                     _rv(hbm=2 * BPI)], command=f"fleet-test:{tag}")


# ---------------------------------------------------------------------------
# serialization layer (fast, in-process)
# ---------------------------------------------------------------------------

def test_schedule_detach_rehydrate_pickle_roundtrip():
    em = _em()
    sched = em.compile(_mixed("rt", ici=4e6), keep_collectives=True)
    back = rehydrate_schedule(pickle.loads(pickle.dumps(sched.detach())))
    assert [type(s) for s in back.steps] == [type(s) for s in sched.steps]
    # barrier around the storage leg AND the collective leg
    assert sum(isinstance(s, BarrierStep) for s in back.steps) == 2
    for a, b in zip(sched.steps, back.steps):
        if isinstance(a, FusedSegment):
            np.testing.assert_array_equal(a.table, b.table)
            assert a.rows == b.rows                    # bit-identical floats
        else:
            assert a.resources == b.resources and a.count == b.count


def test_bundle_profile_pickles_and_replays_identically(tmp_path):
    em = _em()
    em.storage.dir = str(tmp_path)
    prof = _mixed("bundle")
    try:
        ref = em.emulate(prof, fused=True)
        bundle = pickle.loads(pickle.dumps(bundle_profile(em, prof)))
        assert bundle.command == prof.command
        assert bundle.n_profile_samples == len(prof.samples)
        assert bundle.planned == prof.totals
        rep = em.replay(bundle.rehydrate(), command=bundle.command)
    finally:
        em.storage.cleanup()
    assert rep.consumed == ref.consumed == prof.totals
    assert rep.n_samples == ref.n_samples


def test_rehydrate_rejects_bad_payloads():
    with pytest.raises(ValueError):
        rehydrate_schedule({"version": 99, "steps": []})
    with pytest.raises(ValueError):
        rehydrate_schedule("not a payload")
    with pytest.raises(ValueError):
        rehydrate_schedule({"version": 1, "steps": [{"kind": "wat"}]})


def test_emulator_spec_roundtrips_through_pickle(tmp_path):
    em = _em(efficiency=0.5, speed=2.0)
    spec = pickle.loads(pickle.dumps(em.spec()))
    em2 = spec.build()
    assert em2.compute.tile == TILE and em2.compute.efficiency == 0.5
    assert em2.memory.block_bytes == BLOCK and em2.speed == 2.0
    assert em2.calib == em.calib                 # no re-calibration drift
    assert em2.collective is None
    prof = _profile([_rv(flops=4 * FPI, hbm=2 * BPI), _rv(flops=2 * FPI)])
    assert em2.emulate(prof).consumed == em.emulate(prof).consumed


def test_keep_collectives_lowers_wire_runs_to_barriers():
    em = _em()                                   # no mesh in this process
    prof = _profile([_rv(flops=FPI), _rv(flops=FPI, ici=4e6), _rv(hbm=BPI)])
    folded = em.compile(prof)                    # default: nothing executes
    assert [type(s) for s in folded.steps] == [FusedSegment]
    kept = em.compile(prof, keep_collectives=True)
    assert [type(s) for s in kept.steps] == \
        [FusedSegment, BarrierStep, FusedSegment]
    # both account the same totals
    assert em.replay(folded, command="f").consumed == \
        em.replay(kept, command="k").consumed == prof.totals


def test_mesh_spec_validates_and_counts_devices():
    assert MeshSpec(shape=(2, 4), axes=("data", "model")).device_count == 8
    with pytest.raises(ValueError):
        MeshSpec(shape=(2, 4), axes=("model",))
    with pytest.raises(ValueError):
        MeshSpec(shape=(), axes=())


def test_process_executor_rejects_per_sample_path():
    em = _em()
    with pytest.raises(ValueError):
        em.emulate_many([_mixed("x")], executor="process", fused=False)
    with pytest.raises(ValueError):
        em.emulate_many([_mixed("x")], executor="carrier-pigeon")
    # a mesh on the thread executor would be silently dropped — refuse it
    with pytest.raises(ValueError, match="process"):
        em.emulate_many([_mixed("x")], executor="thread",
                        mesh_spec=MeshSpec(shape=(2,), axes=("model",)))


# ---------------------------------------------------------------------------
# process executor (spawns real workers)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.subproc
def test_process_fleet_bit_identical_and_collectives_execute():
    """The ISSUE 3 acceptance contract: a mixed_fleet job set replayed by
    the process executor consumes bit-identical totals per profile, and a
    profile with a collective leg issues collective dispatches on the
    workers' own meshes."""
    em = _em()
    profiles = [generate("mixed_fleet", total_samples=6, seed=1),
                generate("mixed_fleet", total_samples=6, seed=2),
                generate("training_scan", n_steps=4, ckpt_every=2,
                         flops_per_step=4e7, hbm_per_step=2e6,
                         ckpt_bytes=2 << 20),
                _mixed("coll", ici=4e6)]
    refs = [em.emulate(p, fused=True) for p in profiles]
    em.storage.cleanup()
    fleet = em.emulate_many(profiles, max_workers=2, executor="process",
                            mesh_spec=MeshSpec(shape=(2,), axes=("model",)))
    assert fleet.n_profiles == len(profiles)
    assert fleet.max_workers == 2
    assert fleet.cache_stats["worker_deaths"] == 0
    for ref, rep in zip(refs, fleet.reports):
        assert rep.mode == "fused"
        assert rep.consumed == ref.consumed          # bit-identical
        assert rep.n_samples == ref.n_samples
    coll = fleet.reports[-1]
    assert coll.consumed.ici_total == 4e6
    assert coll.n_collective_dispatches > 0          # it really executed
    # fleet summary surfaces the new I/O fields
    s = coll.summary()
    assert s["ici_bytes"] == 4e6 and "storage_read_bytes" in s


@pytest.mark.slow
@pytest.mark.subproc
def test_process_fleet_survives_worker_death_and_reports_errors():
    em = _em()
    bundles = [bundle_profile(em, _mixed(i)) for i in range(6)]
    with ProcessFleet(2, WorkerSpec(emulator=em.spec())) as pf:
        pf.warmup()
        os.kill(pf.pids[0], signal.SIGKILL)          # one worker dies
        reports = pf.run(bundles)
        assert len(reports) == len(bundles)          # nothing lost
        assert pf.worker_deaths >= 1
        ref = em.emulate(_mixed(0), fused=True)
        em.storage.cleanup()
        assert all(r.consumed == ref.consumed for r in reports)
        # a malformed bundle is a loud failure, not a hang — and the
        # worker survives it.  Good bundles are in flight when the run
        # raises, so the follow-up run also proves a raised run's
        # stragglers neither leak into the next run's results nor
        # permanently occupy their workers.
        bad = ScheduleBundle(command="bad", payload={"version": 99})
        with pytest.raises(RuntimeError, match="bad"):
            pf.run([bad] + bundles)
        again = pf.run(bundles[:2])                  # pool still serves
        assert [r.command for r in again] == \
            [b.command for b in bundles[:2]]
        assert [r.consumed for r in again] == \
            [r.consumed for r in reports[:2]]
