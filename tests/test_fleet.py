"""Process-level fleet executor (ISSUE 3 + ISSUE 6 contracts).

Fast tests pin the serialization layer in-process: detach/rehydrate and
``ScheduleBundle`` pickling are bit-identical round-trips, emulator/atom
specs rebuild equivalent emulators, and ``keep_collectives`` controls
whether wire-byte runs lower to executable barrier steps.  The streaming
scheduler contracts (ISSUE 6) are pinned on an in-process loopback fleet
(``_EchoFleet``): the compile-ahead window never exceeds ``window``
pulled-but-unfinished bundles, autoscale up/down preserves bit-identical
index-order-folded totals vs a fixed-size pool, and ``FleetConfig``
round-trips pickle and folds legacy kwargs equivalently (with the
deprecation warning).

Process tests (marked ``slow`` + ``subproc`` — deselect with
``-m "not slow"`` while iterating) pin the executor: a process fleet
reports consumed totals bit-identical to in-process fused replay for every
profile, collective legs execute on per-worker meshes (nonzero collective
dispatches — the first fleet mode where they do), worker death mid-run is
survived with every bundle still reported, a poison bundle fails the run
instead of hanging it, and a streamed autoscaled fleet's totals match a
fixed-size fleet's bit-for-bit.
"""
import multiprocessing as mp
import os
import pickle
import signal
import warnings

import numpy as np
import pytest

from repro.core import (BarrierStep, Emulator, FusedSegment, ResourceVector,
                        Sample, SynapseProfile, rehydrate_schedule)
from repro.core.emulator import EmulationReport, ReportFold
from repro.fleet import (FleetBase, FleetConfig, MeshSpec, Peer,
                         ProcessFleet, ScheduleBundle, WorkerSpec,
                         bundle_profile)
from repro.scenarios import generate

TILE = 64                  # 1 compute iter = 2*64^3  = 524288 flops
BLOCK = 1 << 18            # 1 memory  iter = 2*2^18  = 524288 bytes
FPI = 2.0 * TILE ** 3
BPI = 2.0 * BLOCK


def _em(**kw):
    return Emulator(compute_tile=TILE, mem_block=BLOCK, **kw)


def _rv(flops=0.0, hbm=0.0, sw=0.0, sr=0.0, ici=0.0):
    return ResourceVector(flops=flops, hbm_bytes=hbm,
                          storage_write_bytes=sw, storage_read_bytes=sr,
                          ici_bytes={"all-reduce": ici} if ici else {})


def _profile(rvs, command="fleet-test"):
    return SynapseProfile(command=command,
                          samples=[Sample(index=i, resources=r)
                                   for i, r in enumerate(rvs)])


def _mixed(tag, ici=0.0):
    """Compute/memory runs split by a storage barrier (and an ici leg)."""
    return _profile([_rv(flops=FPI, hbm=BPI), _rv(flops=2 * FPI),
                     _rv(flops=FPI, sw=2 << 20, sr=1 << 20),
                     _rv(flops=FPI, ici=ici),
                     _rv(hbm=2 * BPI)], command=f"fleet-test:{tag}")


# ---------------------------------------------------------------------------
# serialization layer (fast, in-process)
# ---------------------------------------------------------------------------

def test_schedule_detach_rehydrate_pickle_roundtrip():
    em = _em()
    sched = em.compile(_mixed("rt", ici=4e6), keep_collectives=True)
    back = rehydrate_schedule(pickle.loads(pickle.dumps(sched.detach())))
    assert [type(s) for s in back.steps] == [type(s) for s in sched.steps]
    # barrier around the storage leg AND the collective leg
    assert sum(isinstance(s, BarrierStep) for s in back.steps) == 2
    for a, b in zip(sched.steps, back.steps):
        if isinstance(a, FusedSegment):
            np.testing.assert_array_equal(a.table, b.table)
            assert a.rows == b.rows                    # bit-identical floats
        else:
            assert a.resources == b.resources and a.count == b.count


def test_bundle_profile_pickles_and_replays_identically(tmp_path):
    em = _em()
    em.storage.dir = str(tmp_path)
    prof = _mixed("bundle")
    try:
        ref = em.emulate(prof, fused=True)
        bundle = pickle.loads(pickle.dumps(bundle_profile(em, prof)))
        assert bundle.command == prof.command
        assert bundle.n_profile_samples == len(prof.samples)
        assert bundle.planned == prof.totals
        rep = em.replay(bundle.rehydrate(), command=bundle.command)
    finally:
        em.storage.cleanup()
    assert rep.consumed == ref.consumed == prof.totals
    assert rep.n_samples == ref.n_samples


def test_rehydrate_rejects_bad_payloads():
    with pytest.raises(ValueError):
        rehydrate_schedule({"version": 99, "steps": []})
    with pytest.raises(ValueError):
        rehydrate_schedule("not a payload")
    with pytest.raises(ValueError):
        rehydrate_schedule({"version": 1, "steps": [{"kind": "wat"}]})


def test_emulator_spec_roundtrips_through_pickle(tmp_path):
    em = _em(efficiency=0.5, speed=2.0)
    spec = pickle.loads(pickle.dumps(em.spec()))
    em2 = spec.build()
    assert em2.compute.tile == TILE and em2.compute.efficiency == 0.5
    assert em2.memory.block_bytes == BLOCK and em2.speed == 2.0
    assert em2.calib == em.calib                 # no re-calibration drift
    assert em2.collective is None
    prof = _profile([_rv(flops=4 * FPI, hbm=2 * BPI), _rv(flops=2 * FPI)])
    assert em2.emulate(prof).consumed == em.emulate(prof).consumed


def test_keep_collectives_lowers_wire_runs_to_barriers():
    em = _em()                                   # no mesh in this process
    prof = _profile([_rv(flops=FPI), _rv(flops=FPI, ici=4e6), _rv(hbm=BPI)])
    folded = em.compile(prof)                    # default: nothing executes
    assert [type(s) for s in folded.steps] == [FusedSegment]
    kept = em.compile(prof, keep_collectives=True)
    assert [type(s) for s in kept.steps] == \
        [FusedSegment, BarrierStep, FusedSegment]
    # both account the same totals
    assert em.replay(folded, command="f").consumed == \
        em.replay(kept, command="k").consumed == prof.totals


def test_mesh_spec_validates_and_counts_devices():
    assert MeshSpec(shape=(2, 4), axes=("data", "model")).device_count == 8
    with pytest.raises(ValueError):
        MeshSpec(shape=(2, 4), axes=("model",))
    with pytest.raises(ValueError):
        MeshSpec(shape=(), axes=())


def test_process_executor_rejects_per_sample_path():
    em = _em()
    with pytest.raises(ValueError):
        em.emulate_many([_mixed("x")], executor="process", fused=False)
    with pytest.raises(ValueError):
        em.emulate_many([_mixed("x")], executor="carrier-pigeon")
    # a mesh on the thread executor would be silently dropped — refuse it
    with pytest.raises(ValueError, match="process"):
        em.emulate_many([_mixed("x")], executor="thread",
                        mesh_spec=MeshSpec(shape=(2,), axes=("model",)))


# ---------------------------------------------------------------------------
# streaming scheduler + FleetConfig (fast, in-process loopback peers)
# ---------------------------------------------------------------------------

class _EchoPeer(Peer):
    """Loopback peer: ``dispatch`` writes the reply into its own pipe, so
    the scheduler's wait/collect path runs unchanged with zero
    subprocesses.  The 'replay' consumes exactly the bundle's planned
    totals, so folded aggregates are deterministic."""

    def __init__(self):
        super().__init__()
        self._r, self._w = mp.Pipe(duplex=False)
        self.ready = True

    @property
    def waitable(self):
        return self._r

    def dispatch(self, epoch, idx, bundle):
        self.tasks.add((epoch, idx))
        rep = EmulationReport(command=bundle.command, ttc_s=1e-3,
                              n_samples=bundle.n_profile_samples,
                              consumed=bundle.planned, mode="fused")
        self._w.send(("ok", epoch, idx, rep))

    def recv(self):
        return self._r.recv()

    def close(self):
        self._r.close()
        self._w.close()


class _EchoFleet(FleetBase):
    def __init__(self, n, *, autoscale=False, scale_max=3, min_workers=1):
        super().__init__()
        self._autoscale = autoscale
        self._scale_min = min_workers
        self._scale_max = scale_max
        for _ in range(n):
            self._peers.append(_EchoPeer())

    def _scale_up(self):
        if len(self._peers) >= self._scale_max:
            return False
        self._peers.append(_EchoPeer())
        self.scale_ups += 1
        return True


def _echo_bundle(i):
    # awkward float amounts on purpose: summation order changes the bits,
    # so identical fold totals really mean identical fold order
    return ScheduleBundle(command=f"echo{i}", payload={},
                          n_profile_samples=1,
                          planned=_rv(flops=0.1 * i + 0.3, hbm=0.7 * i))


def _fold_stream(fleet, bundles, **kw):
    fold = ReportFold()
    for idx, rep in fleet.stream(bundles, **kw):
        fold.add(idx, rep)
    return fold


def test_stream_window_bounds_compile_ahead():
    """The backpressure contract: a probe source counting outstanding
    pulls (pulled but not yet yielded back) never sees more than
    ``window`` in flight."""
    n, window = 24, 4
    state = {"pulled": 0, "done": 0, "peak": 0}

    def source():
        for i in range(n):
            out = state["pulled"] - state["done"]
            state["peak"] = max(state["peak"], out + 1)   # incl. this pull
            state["pulled"] += 1
            yield _echo_bundle(i)

    with _EchoFleet(1) as fleet:
        fold = ReportFold()
        for idx, rep in fleet.stream(source(), window=window):
            state["done"] += 1
            fold.add(idx, rep)
    assert fold.n_done == n
    assert state["peak"] <= window
    assert fleet.last_scaling["peak_window"] <= window
    # reports folded in index order regardless of completion order
    assert [r.command for r in fold.reports] == \
        [f"echo{i}" for i in range(n)]


def test_stream_autoscale_matches_fixed_totals_bitwise():
    """Elasticity must not change the answer: an autoscaled 1→3 pool folds
    the same aggregate bits as a fixed 3-worker pool, scales up on queue
    depth, and parks back at its floor when the stream drains."""
    bundles = [_echo_bundle(i) for i in range(30)]
    with _EchoFleet(3) as fixed:
        ref = _fold_stream(fixed, list(bundles))
    with _EchoFleet(1, autoscale=True, scale_max=3) as elastic:
        out = _fold_stream(elastic, iter(bundles), window=8)
        assert elastic.scale_ups >= 1
        assert elastic.scale_downs >= 1
        assert len(elastic._peers) == 1              # parked at the floor
    assert out.totals == ref.totals                  # bit-identical
    assert out.serial_s == ref.serial_s
    assert out.n_done == ref.n_done == 30
    sc = elastic.last_scaling
    assert sc["scale_ups"] == elastic.scale_ups
    assert 1 <= sc["peak_workers"] <= 3
    assert sc["peak_queue_depth"] >= 1


def test_fleet_config_validates_and_pickles():
    cfg = FleetConfig.process(max_workers=8, autoscale=True, min_workers=2,
                              window=16, timeout=30.0)
    assert pickle.loads(pickle.dumps(cfg)) == cfg
    assert cfg.scale_min == 2
    assert FleetConfig.remote(["h:1"]).hosts == ("h:1",)   # normalized
    with pytest.raises(ValueError):
        FleetConfig(executor="carrier-pigeon")
    with pytest.raises(ValueError):                  # hosts without remote
        FleetConfig(hosts=("h:1",))
    with pytest.raises(ValueError, match="process"):  # mesh on threads
        FleetConfig(mesh_spec=MeshSpec(shape=(2,), axes=("model",)))
    with pytest.raises(ValueError):                  # remote with no agents
        FleetConfig(executor="remote")
    with pytest.raises(ValueError):                  # agents without listen
        FleetConfig.remote(["h:1"], agents=2)
    with pytest.raises(ValueError):                  # floor without autoscale
        FleetConfig.process(min_workers=2)
    with pytest.raises(ValueError):                  # floor above ceiling
        FleetConfig.process(max_workers=2, autoscale=True, min_workers=3)
    with pytest.raises(ValueError):
        FleetConfig(window=0)
    with pytest.raises(ValueError):                  # threads can't scale
        FleetConfig(executor="thread", autoscale=True)


def test_fleet_config_folds_legacy_kwargs_equivalently():
    from repro.fleet.config import UNSET
    with pytest.warns(DeprecationWarning, match="deprecated"):
        folded = FleetConfig.fold(
            None, dict(executor="process", max_workers=3, timeout=5.0),
            caller="test")
    assert folded == FleetConfig.process(max_workers=3, timeout=5.0)
    with warnings.catch_warnings():                  # silence ≠ deprecation
        warnings.simplefilter("error")
        assert FleetConfig.fold(None, dict(executor=UNSET, hosts=UNSET),
                                caller="test") == FleetConfig()
    with pytest.raises(ValueError, match="both"):    # one surface at a time
        FleetConfig.fold(FleetConfig(), dict(max_workers=2), caller="test")
    with pytest.raises(TypeError):
        FleetConfig.fold(None, dict(bogus=1), caller="test")


def test_emulate_many_accepts_config_and_generator():
    em = _em()
    profs = [_profile([_rv(flops=FPI * (i + 1))], command=f"s{i}")
             for i in range(6)]
    with warnings.catch_warnings():                  # config= never warns
        warnings.simplefilter("error")
        ref = em.emulate_many(profs, config=FleetConfig.thread(max_workers=1))
        streamed = em.emulate_many(
            (p for p in profs),
            config=FleetConfig.thread(max_workers=1, window=2),
            collect="totals")
    assert streamed.n_replayed == ref.n_replayed == 6
    assert streamed.reports == []                    # totals mode drops them
    assert streamed.totals == ref.totals             # bit-identical fold
    assert streamed.n_samples == ref.n_samples == 6
    assert ref.summary()["total_flops"] == ref.totals.flops
    with pytest.raises(ValueError, match="collect"):
        em.emulate_many(profs, collect="everything")


# ---------------------------------------------------------------------------
# process executor (spawns real workers)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.subproc
def test_process_fleet_bit_identical_and_collectives_execute():
    """The ISSUE 3 acceptance contract: a mixed_fleet job set replayed by
    the process executor consumes bit-identical totals per profile, and a
    profile with a collective leg issues collective dispatches on the
    workers' own meshes."""
    em = _em()
    profiles = [generate("mixed_fleet", total_samples=6, seed=1),
                generate("mixed_fleet", total_samples=6, seed=2),
                generate("training_scan", n_steps=4, ckpt_every=2,
                         flops_per_step=4e7, hbm_per_step=2e6,
                         ckpt_bytes=2 << 20),
                _mixed("coll", ici=4e6)]
    refs = [em.emulate(p, fused=True) for p in profiles]
    em.storage.cleanup()
    fleet = em.emulate_many(profiles, max_workers=2, executor="process",
                            mesh_spec=MeshSpec(shape=(2,), axes=("model",)))
    assert fleet.n_profiles == len(profiles)
    assert fleet.max_workers == 2
    assert fleet.cache_stats["worker_deaths"] == 0
    for ref, rep in zip(refs, fleet.reports):
        assert rep.mode == "fused"
        assert rep.consumed == ref.consumed          # bit-identical
        assert rep.n_samples == ref.n_samples
    coll = fleet.reports[-1]
    assert coll.consumed.ici_total == 4e6
    assert coll.n_collective_dispatches > 0          # it really executed
    # fleet summary surfaces the new I/O fields
    s = coll.summary()
    assert s["ici_bytes"] == 4e6 and "storage_read_bytes" in s


@pytest.mark.slow
@pytest.mark.subproc
def test_process_fleet_survives_worker_death_and_reports_errors():
    em = _em()
    bundles = [bundle_profile(em, _mixed(i)) for i in range(6)]
    with ProcessFleet(2, WorkerSpec(emulator=em.spec())) as pf:
        pf.warmup()
        os.kill(pf.pids[0], signal.SIGKILL)          # one worker dies
        reports = pf.run(bundles)
        assert len(reports) == len(bundles)          # nothing lost
        assert pf.worker_deaths >= 1
        ref = em.emulate(_mixed(0), fused=True)
        em.storage.cleanup()
        assert all(r.consumed == ref.consumed for r in reports)
        # a malformed bundle is a loud failure, not a hang — and the
        # worker survives it.  Good bundles are in flight when the run
        # raises, so the follow-up run also proves a raised run's
        # stragglers neither leak into the next run's results nor
        # permanently occupy their workers.
        bad = ScheduleBundle(command="bad", payload={"version": 99})
        with pytest.raises(RuntimeError, match="bad"):
            pf.run([bad] + bundles)
        again = pf.run(bundles[:2])                  # pool still serves
        assert [r.command for r in again] == \
            [b.command for b in bundles[:2]]
        assert [r.consumed for r in again] == \
            [r.consumed for r in reports[:2]]


@pytest.mark.slow
@pytest.mark.subproc
def test_process_fleet_streamed_autoscale_matches_fixed():
    """The ISSUE 6 acceptance contract on real workers: a lazy profile
    source replayed by an elastic 1→2 pool folds aggregate totals
    bit-identical to a fixed 2-worker pool over the same profiles, with
    the scale record surfaced in FleetReport.scaling."""
    em = _em()
    profs = [_mixed(i) for i in range(6)]
    fixed = em.emulate_many(profs, config=FleetConfig.process(max_workers=2),
                            collect="totals")
    elastic = em.emulate_many(
        (p for p in profs),                          # no len(): a stream
        config=FleetConfig.process(max_workers=2, autoscale=True,
                                   min_workers=1, window=4),
        collect="totals")
    assert elastic.totals == fixed.totals            # bit-identical
    assert elastic.n_replayed == fixed.n_replayed == len(profs)
    assert elastic.reports == [] == fixed.reports
    assert elastic.scaling["scale_ups"] >= 1         # it really grew
    assert 1 <= elastic.scaling["peak_workers"] <= 2
    assert elastic.scaling["peak_window"] <= 4
